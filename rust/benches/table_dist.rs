//! Bench: the distributed fault-surviving stencil (§V-B over simulated
//! localities, the Fig 4–5 scenario) — survival rate, recovery latency,
//! and distribution overhead vs. the single-runtime run, across eight
//! arms (pool reference, fault-free cluster, unrecovered kill, then
//! queue-drain, replay, replicate, first-result-wins team, and
//! adaptive-replicate recovery).
//!
//!   cargo run --release --bin table_dist -- [--smoke] [--json PATH]
//!   cargo bench --bench table_dist
//!
//! Env: RHPX_BENCH_SCALE (default 0.01 → 10 iterations, the floor),
//!      RHPX_BENCH_REPEATS (default 3).

use rhpx::harness::{emit, table_dist, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table_dist.csv".into()),
        ..Default::default()
    };
    let rows = table_dist::run_table_dist(&opts);
    emit(&table_dist::to_table(&rows), &opts);
    cli.emit("table_dist", table_dist::to_json(&rows));
}
