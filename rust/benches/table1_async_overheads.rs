//! Bench: regenerate Table I (amortized per-task overhead of resilient
//! async variants vs core count, 200µs grain, no failures).
//!
//!   cargo run --release --bin table1_async_overheads -- [--smoke] [--json PATH]
//!   cargo bench --bench table1_async_overheads
//!
//! Env: RHPX_BENCH_SCALE (default 0.01 of the paper's 1M tasks),
//!      RHPX_BENCH_REPEATS (default 3). `--smoke` overrides both down to
//!      a seconds-scale run.

use rhpx::harness::{emit, table1, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table1.csv".into()),
        ..Default::default()
    };
    let cores: Vec<usize> = if cli.smoke {
        vec![1, 2]
    } else {
        table1::default_cores()
    };
    let t = table1::run_table1(&opts, &cores, 3);
    emit(&t, &opts);
    cli.emit("table1_async_overheads", t.to_json());
}
