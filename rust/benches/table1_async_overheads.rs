//! Bench: regenerate Table I (amortized per-task overhead of resilient
//! async variants vs core count, 200µs grain, no failures) plus the
//! executor-path comparison table (decorator-routed launches vs the free
//! functions, including the adaptive-budget executor).
//!
//!   cargo run --release --bin table1_async_overheads -- [--smoke] [--json PATH]
//!   cargo bench --bench table1_async_overheads
//!
//! Env: RHPX_BENCH_SCALE (default 0.01 of the paper's 1M tasks),
//!      RHPX_BENCH_REPEATS (default 3). `--smoke` overrides both down to
//!      a seconds-scale run.
//!
//! JSON shape: `results.free_functions` is the paper's Table I;
//! `results.executor_path` pairs each free-function variant with its
//! decorator twin so the decorator tax is visible in CI artifacts.

use rhpx::harness::{emit, table1, HarnessOpts};
use rhpx::metrics::{BenchCli, JsonValue};

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table1.csv".into()),
        ..Default::default()
    };
    let cores: Vec<usize> = if cli.smoke {
        vec![1, 2]
    } else {
        table1::default_cores()
    };
    let t = table1::run_table1(&opts, &cores, 3);
    emit(&t, &opts);
    let exec_opts = HarnessOpts { csv: Some("bench_table1_executor.csv".into()), ..opts.clone() };
    let te = table1::run_table1_executor(&exec_opts, &cores, 3);
    emit(&te, &exec_opts);
    cli.emit(
        "table1_async_overheads",
        JsonValue::obj([
            ("free_functions".to_string(), t.to_json()),
            ("executor_path".to_string(), te.to_json()),
        ]),
    );
}
