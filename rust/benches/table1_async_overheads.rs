//! Bench: regenerate Table I (amortized per-task overhead of resilient
//! async variants vs core count, 200µs grain, no failures).
//!
//!   cargo bench --bench table1_async_overheads
//!
//! Env: RHPX_BENCH_SCALE (default 0.01 of the paper's 1M tasks),
//!      RHPX_BENCH_REPEATS (default 3).

use rhpx::harness::{emit, table1, HarnessOpts};

fn main() {
    let opts = HarnessOpts {
        scale: std::env::var("RHPX_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.01),
        repeats: std::env::var("RHPX_BENCH_REPEATS").ok().and_then(|s| s.parse().ok()).unwrap_or(3),
        csv: Some("bench_table1.csv".into()),
        ..Default::default()
    };
    let t = table1::run_table1(&opts, &table1::default_cores(), 3);
    emit(&t, &opts);
}
