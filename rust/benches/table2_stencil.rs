//! Bench: regenerate Table II (1D stencil wall time, no failures: pure
//! dataflow / replay without+with checksums / replicate; cases A and B).
//!
//!   cargo bench --bench table2_stencil
//!
//! Env: RHPX_BENCH_SCALE (default 0.005 of 8192 iterations),
//!      RHPX_BENCH_BACKEND=pjrt to run on the AOT JAX/Pallas kernel.

use rhpx::harness::{emit, table2, HarnessOpts, KernelBackend};
use rhpx::runtime::ArtifactStore;

fn main() {
    let opts = HarnessOpts {
        scale: std::env::var("RHPX_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.005),
        repeats: std::env::var("RHPX_BENCH_REPEATS").ok().and_then(|s| s.parse().ok()).unwrap_or(3),
        csv: Some("bench_table2.csv".into()),
        ..Default::default()
    };
    let backend = if std::env::var("RHPX_BENCH_BACKEND").as_deref() == Ok("pjrt") {
        KernelBackend::Pjrt(
            ArtifactStore::open(std::path::Path::new("artifacts"))
                .expect("run `make artifacts` first"),
        )
    } else {
        KernelBackend::Native
    };
    let t = table2::run_table2(&opts, &backend, 3);
    emit(&t, &opts);
}
