//! Bench: regenerate Table II (1D stencil wall time, no failures: pure
//! dataflow / replay without+with checksums / replicate; cases A and B).
//!
//!   cargo run --release --bin table2_stencil -- [--smoke] [--json PATH]
//!   cargo bench --bench table2_stencil
//!
//! Env: RHPX_BENCH_SCALE (default 0.005 of 8192 iterations),
//!      RHPX_BENCH_BACKEND=pjrt to run on the AOT JAX/Pallas kernel
//!      (requires the PJRT engine and `make artifacts`; falls back to
//!      native with a note otherwise — the JSON payload records which
//!      backend actually ran).

use rhpx::harness::{emit, table2, HarnessOpts, KernelBackend};
use rhpx::metrics::{BenchCli, JsonValue};
use rhpx::runtime::ArtifactStore;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.005),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table2.csv".into()),
        ..Default::default()
    };
    let want_pjrt = std::env::var("RHPX_BENCH_BACKEND").as_deref() == Ok("pjrt");
    let (backend, backend_label) = if want_pjrt {
        let store = ArtifactStore::open(std::path::Path::new("artifacts"))
            .expect("scan artifacts dir");
        if rhpx::runtime::pjrt_available() && !store.is_empty() {
            (KernelBackend::Pjrt(store), "pjrt")
        } else {
            eprintln!(
                "note: PJRT unavailable (engine or artifacts missing) — using native kernel"
            );
            (KernelBackend::Native, "native (pjrt requested, unavailable)")
        }
    } else {
        (KernelBackend::Native, "native")
    };
    let t = table2::run_table2(&opts, &backend, 3);
    emit(&t, &opts);
    cli.emit(
        "table2_stencil",
        JsonValue::obj([
            ("backend".to_string(), JsonValue::from(backend_label)),
            ("table".to_string(), t.to_json()),
        ]),
    );
}
