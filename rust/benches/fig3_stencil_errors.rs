//! Bench: regenerate Fig 3a/3b (1D stencil % extra execution time vs
//! error probability, cases A and B, replay without+with checksums).
//!
//!   cargo run --release --bin fig3_stencil_errors -- [--smoke] [--json PATH]
//!   cargo bench --bench fig3_stencil_errors

use rhpx::harness::{emit, fig3, HarnessOpts, KernelBackend};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.003),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_fig3.csv".into()),
        ..Default::default()
    };
    let probs: Vec<f64> = if cli.smoke {
        vec![0.0, 5.0]
    } else {
        fig3::default_probabilities()
    };
    let t = fig3::run_fig3(&opts, &KernelBackend::Native, &probs, 5);
    emit(&t, &opts);
    cli.emit("fig3_stencil_errors", t.to_json());
}
