//! Bench: regenerate Fig 3a/3b (1D stencil % extra execution time vs
//! error probability, cases A and B, replay without+with checksums).
//!
//!   cargo bench --bench fig3_stencil_errors

use rhpx::harness::{emit, fig3, HarnessOpts, KernelBackend};

fn main() {
    let opts = HarnessOpts {
        scale: std::env::var("RHPX_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.003),
        repeats: std::env::var("RHPX_BENCH_REPEATS").ok().and_then(|s| s.parse().ok()).unwrap_or(3),
        csv: Some("bench_fig3.csv".into()),
        ..Default::default()
    };
    let t = fig3::run_fig3(&opts, &KernelBackend::Native, &fig3::default_probabilities(), 5);
    emit(&t, &opts);
}
