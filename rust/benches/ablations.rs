//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A1. Replication factor sweep — cost of replicate(n) vs n.
//!  A2. Grain-size sweep — where the paper's "minimal overhead for
//!      tasks ≥ 200µs" claim breaks down.
//!  A3. Replay-within-replicate (future-work feature) vs plain
//!      replicate under failures.
//!  A4. Coordinated C/R vs task replay — redone work and wall time.
//!  A5. PJRT vs native kernel dispatch cost on the stencil task.
//!
//!   cargo run --release --bin ablations -- [--smoke] [--json PATH]
//!   cargo bench --bench ablations

use rhpx::checkpoint::{run_with_checkpoints, CheckpointStore, Storage};
use rhpx::failure::FaultInjector;
use rhpx::metrics::{BenchCli, JsonValue, Table, Timer};
use rhpx::resilience;
use rhpx::runtime::ArtifactStore;
use rhpx::stencil::{self, Backend, StencilParams};
use rhpx::workload::{run, Variant, WorkloadParams};
use rhpx::{Runtime, TaskResult};

fn a1_replication_factor(rt: &Runtime, scale: f64) -> Table {
    let tasks = ((200_000.0 * scale) as usize).max(500);
    let params = WorkloadParams { tasks, grain_ns: 50_000, ..Default::default() };
    let mut t = Table::new(
        "A1: replicate(n) per-task cost, 50µs grain, no failures",
        &["n", "per_task_us", "overhead_us"],
    );
    for n in [1, 2, 3, 4, 6, 8] {
        let rep = run(rt, Variant::Replicate { n }, &params);
        t.add([
            n.to_string(),
            format!("{:.3}", rep.per_task_us),
            format!("{:.3}", rep.overhead_us),
        ]);
    }
    print!("{}", t.render());
    t
}

fn a2_grain_sweep(rt: &Runtime, scale: f64) -> Table {
    let mut t = Table::new(
        "A2: replay(3) relative overhead vs task grain (paper claims ~free at 200µs)",
        &["grain_us", "plain_us", "replay_us", "overhead_pct"],
    );
    for grain_us in [1u64, 10, 50, 100, 200, 500] {
        let tasks = (((400_000 / grain_us.max(1)) as f64 * scale * 10.0) as usize).max(200);
        let params = WorkloadParams { tasks, grain_ns: grain_us * 1000, ..Default::default() };
        let plain = run(rt, Variant::Plain, &params);
        let replay = run(rt, Variant::Replay { n: 3 }, &params);
        let pct = 100.0 * (replay.per_task_us - plain.per_task_us) / (grain_us as f64);
        t.add([
            grain_us.to_string(),
            format!("{:.3}", plain.per_task_us),
            format!("{:.3}", replay.per_task_us),
            format!("{pct:.2}"),
        ]);
    }
    print!("{}", t.render());
    t
}

fn a3_replicate_replay(rt: &Runtime, scale: f64) -> Table {
    let n_launches = ((50_000.0 * scale) as usize).max(200);
    let p = 0.20; // heavy failures: where the nested replay pays off
    let mut t = Table::new(
        "A3: replicate(3) vs replicate(3)+replay(3) under 20% failures",
        &["scheme", "launch_errors", "wall_s"],
    );
    for (label, nested) in [("replicate(3)", false), ("replicate(3)+replay(3)", true)] {
        let inj = FaultInjector::with_probability(p, 7);
        let timer = Timer::start();
        let mut errors = 0u64;
        for _ in 0..n_launches {
            let i = inj.clone();
            let body = move || -> TaskResult<i32> {
                i.draw("a3")?;
                Ok(1)
            };
            let f = if nested {
                resilience::async_replicate_replay::<
                    i32,
                    TaskResult<i32>,
                    _,
                    fn(&[i32]) -> Option<i32>,
                >(
                    rt, 3, 3, None, body,
                )
            } else {
                resilience::async_replicate(rt, 3, body)
            };
            if f.get().is_err() {
                errors += 1;
            }
        }
        t.add([label.to_string(), errors.to_string(), format!("{:.3}", timer.elapsed_secs())]);
    }
    print!("{}", t.render());
    println!("(nested replay should drive launch_errors to ~0: p_fail^9 vs p_fail^3)\n");
    t
}

fn a4_cr_vs_replay(rt: &Runtime, scale: f64) -> Table {
    let iterations = ((2_000.0 * scale * 10.0) as u64).max(100);
    let n_sub = 8;
    let p = 0.02;
    let mut t = Table::new(
        "A4: coordinated C/R vs task replay (redone task-equivalents)",
        &["scheme", "wall_s", "redone_tasks", "rollbacks"],
    );
    // C/R with disk snapshots
    let dir = std::env::temp_dir().join(format!("rhpx_ablation_cr_{}", std::process::id()));
    let store = CheckpointStore::new(Storage::Disk(dir.clone()));
    let inj = FaultInjector::with_probability(p, 99);
    let mut state = vec![0.0f64; 4096];
    let timer = Timer::start();
    let cr = run_with_checkpoints(&mut state, iterations, 10, &store, |_, s| {
        for _ in 0..n_sub {
            inj.draw("a4-cr")?;
        }
        for v in s.iter_mut() {
            *v += 1.0;
        }
        Ok(())
    })
    .expect("cr failed");
    t.add([
        "coordinated C/R(disk)".to_string(),
        format!("{:.3}", timer.elapsed_secs()),
        (cr.redone * n_sub as u64).to_string(),
        cr.rollbacks.to_string(),
    ]);
    let _ = std::fs::remove_dir_all(&dir);

    // task replay on the same workload
    let inj = FaultInjector::with_probability(p, 99);
    let timer = Timer::start();
    for _ in 0..iterations {
        let futs: Vec<_> = (0..n_sub)
            .map(|_| {
                let i = inj.clone();
                resilience::async_replay(rt, 50, move || -> TaskResult<()> {
                    i.draw("a4-replay")?;
                    Ok(())
                })
            })
            .collect();
        for f in futs {
            f.get().expect("replay exhausted");
        }
    }
    t.add([
        "task replay".to_string(),
        format!("{:.3}", timer.elapsed_secs()),
        inj.counters().injected().to_string(),
        "0".to_string(),
    ]);
    print!("{}", t.render());
    t
}

fn a5_pjrt_vs_native(rt: &Runtime, scale: f64) -> Option<Table> {
    if !rhpx::runtime::pjrt_available() {
        println!("A5: skipped (PJRT engine not compiled in; see rust/Cargo.toml)\n");
        return None;
    }
    let store = match ArtifactStore::open(std::path::Path::new("artifacts")) {
        Ok(s) if !s.is_empty() => s,
        _ => {
            println!("A5: skipped (run `make artifacts` first)\n");
            return None;
        }
    };
    let iters = ((8192.0 * scale * 0.2) as usize).max(4);
    let base = StencilParams {
        n_sub: 8,
        nx: 1000,
        iterations: iters,
        steps: 16,
        courant: 0.9,
        ..StencilParams::tiny()
    };
    let mut t = Table::new(
        "A5: stencil kernel dispatch — native Rust vs AOT JAX/Pallas via PJRT",
        &["backend", "wall_s", "tasks/s"],
    );
    for (label, backend) in [
        ("native", Backend::Native),
        ("pjrt", Backend::pjrt(&store, base.nx, base.steps).expect("artifact")),
    ] {
        let params = StencilParams { backend, ..base.clone() };
        let (_, rep) = stencil::run(rt, &params).expect("run failed");
        t.add([
            label.to_string(),
            format!("{:.3}", rep.wall_secs),
            format!("{:.0}", rep.tasks as f64 / rep.wall_secs),
        ]);
    }
    print!("{}", t.render());
    Some(t)
}

fn main() {
    let cli = BenchCli::parse();
    let scale = cli.scale_from_env(0.01);
    let rt = Runtime::builder().build();
    println!("== ablations (scale {}) on {} workers ==\n", scale, rt.workers());
    let mut sections: Vec<(String, JsonValue)> = Vec::new();
    sections.push(("a1_replication_factor".into(), a1_replication_factor(&rt, scale).to_json()));
    sections.push(("a2_grain_sweep".into(), a2_grain_sweep(&rt, scale).to_json()));
    sections.push(("a3_replicate_replay".into(), a3_replicate_replay(&rt, scale).to_json()));
    sections.push(("a4_cr_vs_replay".into(), a4_cr_vs_replay(&rt, scale).to_json()));
    sections.push((
        "a5_pjrt_vs_native".into(),
        a5_pjrt_vs_native(&rt, scale).map_or(JsonValue::Null, |t| t.to_json()),
    ));
    cli.emit("ablations", JsonValue::obj(sections));
}
