//! Bench: the resilience-strategy ablation around task-level
//! checkpoint/restart — re-executed work, snapshot bytes, and recovery
//! latency for replay vs checkpoint:K (AGAS and disk backends) vs the
//! coordinated global-C/R strawman, under one scheduled locality kill.
//!
//!   cargo run --release --bin table_ckpt -- [--smoke] [--json PATH]
//!   cargo bench --bench table_ckpt
//!
//! Env: RHPX_BENCH_SCALE (default 0.01 → 10 iterations, the floor),
//!      RHPX_BENCH_REPEATS (default 3).

use rhpx::harness::{emit, table_ckpt, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table_ckpt.csv".into()),
        ..Default::default()
    };
    let rows = table_ckpt::run_table_ckpt(&opts);
    emit(&table_ckpt::to_table(&rows), &opts);
    cli.emit("table_ckpt", table_ckpt::to_json(&rows));
}
