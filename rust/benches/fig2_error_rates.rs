//! Bench: regenerate Fig 2a/2b (extra execution time per task vs error
//! probability; replay grows ~linearly, replicate stays flat).
//!
//!   cargo run --release --bin fig2_error_rates -- [--smoke] [--json PATH]
//!   cargo bench --bench fig2_error_rates

use rhpx::harness::{emit, fig2, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_fig2.csv".into()),
        ..Default::default()
    };
    let probs: Vec<f64> = if cli.smoke {
        vec![0.0, 5.0]
    } else {
        fig2::default_probabilities()
    };
    let t = fig2::run_fig2(&opts, &probs);
    emit(&t, &opts);
    cli.emit("fig2_error_rates", t.to_json());
}
