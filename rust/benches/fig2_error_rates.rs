//! Bench: regenerate Fig 2a/2b (extra execution time per task vs error
//! probability; replay grows ~linearly, replicate stays flat).
//!
//!   cargo bench --bench fig2_error_rates

use rhpx::harness::{emit, fig2, HarnessOpts};

fn main() {
    let opts = HarnessOpts {
        scale: std::env::var("RHPX_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.01),
        repeats: std::env::var("RHPX_BENCH_REPEATS").ok().and_then(|s| s.parse().ok()).unwrap_or(3),
        csv: Some("bench_fig2.csv".into()),
        ..Default::default()
    };
    let t = fig2::run_fig2(&opts, &fig2::default_probabilities());
    emit(&t, &opts);
}
