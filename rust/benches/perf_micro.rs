//! Zero-grain launch-overhead microbench (the §Perf instrument in
//! EXPERIMENTS.md): per-launch cost of each API with no task work,
//! isolating pure runtime overhead.

// zero-grain overhead microbench: pure per-launch runtime cost
use rhpx::{Runtime, async_};
use rhpx::resilience::{async_replay, async_replicate};

use rhpx::metrics::Timer;

fn main() {
    let rt = Runtime::builder().workers(1).build();
    let n = 200_000;
    // async_
    let t = Timer::start();
    let mut fs = Vec::with_capacity(1024);
    for _ in 0..n {
        fs.push(async_(&rt, || 1i32));
        if fs.len() == 1024 { for f in fs.drain(..) { let _ = f.get(); } }
    }
    for f in fs { let _ = f.get(); }
    println!("async_      : {:.0} ns/launch", t.elapsed_secs()*1e9/n as f64);
    // replay
    let t = Timer::start();
    let mut fs = Vec::with_capacity(1024);
    for _ in 0..n {
        fs.push(async_replay(&rt, 3, || 1i32));
        if fs.len() == 1024 { for f in fs.drain(..) { let _ = f.get(); } }
    }
    for f in fs { let _ = f.get(); }
    println!("replay(3)   : {:.0} ns/launch", t.elapsed_secs()*1e9/n as f64);
    // replicate
    let n2 = n/3;
    let t = Timer::start();
    let mut fs = Vec::with_capacity(1024);
    for _ in 0..n2 {
        fs.push(async_replicate(&rt, 3, || 1i32));
        if fs.len() == 1024 { for f in fs.drain(..) { let _ = f.get(); } }
    }
    for f in fs { let _ = f.get(); }
    println!("replicate(3): {:.0} ns/launch", t.elapsed_secs()*1e9/n2 as f64);
    // dataflow chain
    let t = Timer::start();
    let mut f = async_(&rt, || 0i64);
    for _ in 0..n/4 {
        f = rhpx::dataflow(&rt, |v: Vec<i64>| v[0]+1, vec![f]);
    }
    let _ = f.get();
    println!("dataflow    : {:.0} ns/link", t.elapsed_secs()*1e9/(n/4) as f64);
    // stencil-shaped dataflow (3 deps, Chunk-sized payload clones)
    let params = rhpx::stencil::StencilParams { n_sub: 8, nx: 64, iterations: 500, steps: 4, courant: 0.9, window: 16, ..rhpx::stencil::StencilParams::tiny() };
    let t = Timer::start();
    let (_, rep) = rhpx::stencil::run(&rt, &params).unwrap();
    println!("stencil task: {:.0} ns/task ({} tasks)", t.elapsed_secs()*1e9/rep.tasks as f64, rep.tasks);
}
