//! Zero-grain launch-overhead microbench (the §Perf instrument in
//! EXPERIMENTS.md): per-launch cost of each API with no task work,
//! isolating pure runtime overhead.
//!
//!   cargo run --release --bin perf_micro -- [--smoke] [--million] [--json PATH]
//!   cargo bench --bench perf_micro -- --smoke --json BENCH_perf_micro.json
//!
//! `--million` runs the paper-scale 1M-task loop (the grain-size claim of
//! the paper is about per-launch cost at exactly this scale); the default
//! full run uses 200k launches, `--smoke` 20k.
//!
//! Emits one `ns_per_launch` number per API (`async_`, `async_replay`,
//! `async_replicate`, `dataflow`, `stencil_task`) plus a `when_all`
//! join-width sweep (`when_all_8/64/512/4096`: amortized ns per
//! dependency through the atomic-countdown join) — the baseline every
//! scheduler/future/resilience optimization is diffed against (see
//! `BENCH_baseline/` and `make bench-diff`).
//!
//! Launch-path rows additionally report p50/p99/p999 of the *individual*
//! submit latency through a [`LatencyHistogram`] — tail latency is what
//! a mean hides, and scheduler regressions usually live in the tail
//! (a lock convoy leaves the mean almost untouched while p999 explodes).
//! Rows whose cost is only meaningful amortized (the join sweep, the
//! stencil run) carry `null` percentiles in the JSON.

use std::time::Instant;

use rhpx::metrics::{BenchCli, JsonValue, LatencyHistogram, Timer};
use rhpx::resilience::{async_replay, async_replicate};
use rhpx::{async_, Promise, Runtime};

/// One emitted row: amortized ns per unit plus, for launch-path rows,
/// the per-call submit-latency tail.
struct Row {
    name: String,
    ns_per_launch: f64,
    hist: Option<LatencyHistogram>,
}

impl Row {
    fn plain(name: &str, ns: f64) -> Self {
        Row { name: name.into(), ns_per_launch: ns, hist: None }
    }

    fn tail(&self) -> String {
        match &self.hist {
            Some(h) => format!(
                " (p50 {} p99 {} p999 {} ns)",
                h.quantile(0.50).unwrap_or(0),
                h.quantile(0.99).unwrap_or(0),
                h.quantile(0.999).unwrap_or(0),
            ),
            None => String::new(),
        }
    }
}

/// Launch `n` zero-work tasks through `launch`, retiring in windows of
/// 1024 to bound memory; returns amortized ns per launch plus the
/// histogram of each individual submit call (launch only — retirement
/// is amortized across the window, so it stays out of the tail).
fn measure<F: FnMut(&Runtime) -> rhpx::Future<i32>>(
    rt: &Runtime,
    n: usize,
    mut launch: F,
) -> (f64, LatencyHistogram) {
    let mut hist = LatencyHistogram::new();
    let t = Timer::start();
    let mut fs = Vec::with_capacity(1024);
    for _ in 0..n {
        let t0 = Instant::now();
        fs.push(launch(rt));
        hist.record_duration(t0.elapsed());
        if fs.len() == 1024 {
            for f in fs.drain(..) {
                let _ = f.get();
            }
        }
    }
    for f in fs {
        let _ = f.get();
    }
    (t.elapsed_secs() * 1e9 / n as f64, hist)
}

/// Amortized ns per dependency of a `when_all_results` join of `width`
/// inputs: promises resolve *after* the join is built, so every
/// dependency takes the countdown path (no all-ready shortcut).
fn measure_when_all(width: usize, rounds: usize) -> f64 {
    let t = Timer::start();
    for _ in 0..rounds {
        let mut promises = Vec::with_capacity(width);
        let mut futs = Vec::with_capacity(width);
        for _ in 0..width {
            let (p, f) = Promise::new();
            promises.push(p);
            futs.push(f);
        }
        let all = rhpx::when_all_results(futs);
        for p in promises {
            p.set_value(1i32);
        }
        let r = all.get().expect("join never fails");
        assert_eq!(r.len(), width);
    }
    t.elapsed_secs() * 1e9 / (rounds * width) as f64
}

fn main() {
    let cli = BenchCli::parse();
    let million = std::env::args().any(|a| a == "--million");
    let rt = Runtime::builder().workers(1).build();
    let n = if million {
        1_000_000
    } else if cli.smoke {
        20_000
    } else {
        200_000
    };

    let mut results: Vec<Row> = Vec::new();

    let (ns, hist) = measure(&rt, n, |rt| async_(rt, || 1i32));
    let row = Row { name: "async_".into(), ns_per_launch: ns, hist: Some(hist) };
    println!("async_         : {ns:.0} ns/launch{}", row.tail());
    results.push(row);

    let (ns, hist) = measure(&rt, n, |rt| async_replay(rt, 3, || 1i32));
    let row = Row { name: "async_replay".into(), ns_per_launch: ns, hist: Some(hist) };
    println!("async_replay   : {ns:.0} ns/launch{}", row.tail());
    results.push(row);

    let (ns, hist) = measure(&rt, n / 3, |rt| async_replicate(rt, 3, || 1i32));
    let row = Row { name: "async_replicate".into(), ns_per_launch: ns, hist: Some(hist) };
    println!("async_replicate: {ns:.0} ns/launch{}", row.tail());
    results.push(row);

    // dataflow chain: per-link cost of dependency tracking, each link's
    // construction individually histogrammed.
    let links = n / 4;
    let mut hist = LatencyHistogram::new();
    let t = Timer::start();
    let mut f = async_(&rt, || 0i64);
    for _ in 0..links {
        let t0 = Instant::now();
        f = rhpx::dataflow(&rt, |v: Vec<i64>| v[0] + 1, vec![f]);
        hist.record_duration(t0.elapsed());
    }
    let _ = f.get();
    let ns = t.elapsed_secs() * 1e9 / links as f64;
    let row = Row { name: "dataflow".into(), ns_per_launch: ns, hist: Some(hist) };
    println!("dataflow       : {ns:.0} ns/link{}", row.tail());
    results.push(row);

    // when_all join-width sweep: the dependency-completion path at the
    // fan-in widths a real DAG sees (stencil = 3, reductions = wide).
    // Per-dependency cost only exists amortized, so these rows carry no
    // histogram.
    for &width in &[8usize, 64, 512, 4096] {
        // ~n total dependency completions per width, at least 8 rounds.
        let rounds = (n / width).max(8);
        let ns = measure_when_all(width, rounds);
        println!("when_all_{width:<6}: {ns:.0} ns/dep ({rounds} rounds)");
        results.push(Row::plain(&format!("when_all_{width}"), ns));
    }

    // stencil-shaped dataflow (3 deps, Chunk-sized payload clones)
    let iterations = if cli.smoke { 100 } else { 500 };
    let params = rhpx::stencil::StencilParams {
        n_sub: 8,
        nx: 64,
        iterations,
        steps: 4,
        courant: 0.9,
        window: 16,
        ..rhpx::stencil::StencilParams::tiny()
    };
    let t = Timer::start();
    let (_, rep) = rhpx::stencil::run(&rt, &params).unwrap();
    let ns = t.elapsed_secs() * 1e9 / rep.tasks as f64;
    println!("stencil task   : {ns:.0} ns/task ({} tasks)", rep.tasks);
    results.push(Row::plain("stencil_task", ns));

    cli.emit(
        "perf_micro",
        JsonValue::Arr(
            results
                .into_iter()
                .map(|row| {
                    let q = |q: f64| {
                        row.hist
                            .as_ref()
                            .and_then(|h| h.quantile(q))
                            .map(JsonValue::from)
                            .unwrap_or(JsonValue::Null)
                    };
                    JsonValue::obj([
                        ("name".to_string(), JsonValue::from(row.name.clone())),
                        ("ns_per_launch".to_string(), JsonValue::from(row.ns_per_launch)),
                        ("p50_ns".to_string(), q(0.50)),
                        ("p99_ns".to_string(), q(0.99)),
                        ("p999_ns".to_string(), q(0.999)),
                    ])
                })
                .collect(),
        ),
    );
}
