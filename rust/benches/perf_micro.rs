//! Zero-grain launch-overhead microbench (the §Perf instrument in
//! EXPERIMENTS.md): per-launch cost of each API with no task work,
//! isolating pure runtime overhead.
//!
//!   cargo run --release --bin perf_micro -- [--smoke] [--json PATH]
//!   cargo bench --bench perf_micro -- --smoke --json BENCH_perf_micro.json
//!
//! Emits one `ns_per_launch` number per API (`async_`, `async_replay`,
//! `async_replicate`, `dataflow`, `stencil_task`) — the baseline every
//! future scheduler/future/resilience optimization is diffed against.

use rhpx::metrics::{BenchCli, JsonValue, Timer};
use rhpx::resilience::{async_replay, async_replicate};
use rhpx::{async_, Runtime};

/// Launch `n` zero-work tasks through `launch`, retiring in windows of
/// 1024 to bound memory; returns amortized ns per launch.
fn measure<F: FnMut(&Runtime) -> rhpx::Future<i32>>(rt: &Runtime, n: usize, mut launch: F) -> f64 {
    let t = Timer::start();
    let mut fs = Vec::with_capacity(1024);
    for _ in 0..n {
        fs.push(launch(rt));
        if fs.len() == 1024 {
            for f in fs.drain(..) {
                let _ = f.get();
            }
        }
    }
    for f in fs {
        let _ = f.get();
    }
    t.elapsed_secs() * 1e9 / n as f64
}

fn main() {
    let cli = BenchCli::parse();
    let rt = Runtime::builder().workers(1).build();
    let n = if cli.smoke { 20_000 } else { 200_000 };

    let mut results: Vec<(&str, f64)> = Vec::new();

    let ns = measure(&rt, n, |rt| async_(rt, || 1i32));
    println!("async_         : {ns:.0} ns/launch");
    results.push(("async_", ns));

    let ns = measure(&rt, n, |rt| async_replay(rt, 3, || 1i32));
    println!("async_replay   : {ns:.0} ns/launch");
    results.push(("async_replay", ns));

    let ns = measure(&rt, n / 3, |rt| async_replicate(rt, 3, || 1i32));
    println!("async_replicate: {ns:.0} ns/launch");
    results.push(("async_replicate", ns));

    // dataflow chain: per-link cost of dependency tracking.
    let links = n / 4;
    let t = Timer::start();
    let mut f = async_(&rt, || 0i64);
    for _ in 0..links {
        f = rhpx::dataflow(&rt, |v: Vec<i64>| v[0] + 1, vec![f]);
    }
    let _ = f.get();
    let ns = t.elapsed_secs() * 1e9 / links as f64;
    println!("dataflow       : {ns:.0} ns/link");
    results.push(("dataflow", ns));

    // stencil-shaped dataflow (3 deps, Chunk-sized payload clones)
    let iterations = if cli.smoke { 100 } else { 500 };
    let params = rhpx::stencil::StencilParams {
        n_sub: 8,
        nx: 64,
        iterations,
        steps: 4,
        courant: 0.9,
        window: 16,
        ..rhpx::stencil::StencilParams::tiny()
    };
    let t = Timer::start();
    let (_, rep) = rhpx::stencil::run(&rt, &params).unwrap();
    let ns = t.elapsed_secs() * 1e9 / rep.tasks as f64;
    println!("stencil task   : {ns:.0} ns/task ({} tasks)", rep.tasks);
    results.push(("stencil_task", ns));

    cli.emit(
        "perf_micro",
        JsonValue::Arr(
            results
                .into_iter()
                .map(|(name, ns)| {
                    JsonValue::obj([
                        ("name".to_string(), JsonValue::from(name)),
                        ("ns_per_launch".to_string(), JsonValue::from(ns)),
                    ])
                })
                .collect(),
        ),
    );
}
