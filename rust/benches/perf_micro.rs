//! Zero-grain launch-overhead microbench (the §Perf instrument in
//! EXPERIMENTS.md): per-launch cost of each API with no task work,
//! isolating pure runtime overhead.
//!
//!   cargo run --release --bin perf_micro -- [--smoke] [--million] [--json PATH]
//!   cargo bench --bench perf_micro -- --smoke --json BENCH_perf_micro.json
//!
//! `--million` runs the paper-scale 1M-task loop (the grain-size claim of
//! the paper is about per-launch cost at exactly this scale); the default
//! full run uses 200k launches, `--smoke` 20k.
//!
//! Emits one `ns_per_launch` number per API (`async_`, `async_replay`,
//! `async_replicate`, `dataflow`, `stencil_task`) plus a `when_all`
//! join-width sweep (`when_all_8/64/512/4096`: amortized ns per
//! dependency through the atomic-countdown join) — the baseline every
//! scheduler/future/resilience optimization is diffed against (see
//! `BENCH_baseline/` and `make bench-diff`).

use rhpx::metrics::{BenchCli, JsonValue, Timer};
use rhpx::resilience::{async_replay, async_replicate};
use rhpx::{async_, Promise, Runtime};

/// Launch `n` zero-work tasks through `launch`, retiring in windows of
/// 1024 to bound memory; returns amortized ns per launch.
fn measure<F: FnMut(&Runtime) -> rhpx::Future<i32>>(rt: &Runtime, n: usize, mut launch: F) -> f64 {
    let t = Timer::start();
    let mut fs = Vec::with_capacity(1024);
    for _ in 0..n {
        fs.push(launch(rt));
        if fs.len() == 1024 {
            for f in fs.drain(..) {
                let _ = f.get();
            }
        }
    }
    for f in fs {
        let _ = f.get();
    }
    t.elapsed_secs() * 1e9 / n as f64
}

/// Amortized ns per dependency of a `when_all_results` join of `width`
/// inputs: promises resolve *after* the join is built, so every
/// dependency takes the countdown path (no all-ready shortcut).
fn measure_when_all(width: usize, rounds: usize) -> f64 {
    let t = Timer::start();
    for _ in 0..rounds {
        let mut promises = Vec::with_capacity(width);
        let mut futs = Vec::with_capacity(width);
        for _ in 0..width {
            let (p, f) = Promise::new();
            promises.push(p);
            futs.push(f);
        }
        let all = rhpx::when_all_results(futs);
        for p in promises {
            p.set_value(1i32);
        }
        let r = all.get().expect("join never fails");
        assert_eq!(r.len(), width);
    }
    t.elapsed_secs() * 1e9 / (rounds * width) as f64
}

fn main() {
    let cli = BenchCli::parse();
    let million = std::env::args().any(|a| a == "--million");
    let rt = Runtime::builder().workers(1).build();
    let n = if million {
        1_000_000
    } else if cli.smoke {
        20_000
    } else {
        200_000
    };

    let mut results: Vec<(String, f64)> = Vec::new();

    let ns = measure(&rt, n, |rt| async_(rt, || 1i32));
    println!("async_         : {ns:.0} ns/launch");
    results.push(("async_".into(), ns));

    let ns = measure(&rt, n, |rt| async_replay(rt, 3, || 1i32));
    println!("async_replay   : {ns:.0} ns/launch");
    results.push(("async_replay".into(), ns));

    let ns = measure(&rt, n / 3, |rt| async_replicate(rt, 3, || 1i32));
    println!("async_replicate: {ns:.0} ns/launch");
    results.push(("async_replicate".into(), ns));

    // dataflow chain: per-link cost of dependency tracking.
    let links = n / 4;
    let t = Timer::start();
    let mut f = async_(&rt, || 0i64);
    for _ in 0..links {
        f = rhpx::dataflow(&rt, |v: Vec<i64>| v[0] + 1, vec![f]);
    }
    let _ = f.get();
    let ns = t.elapsed_secs() * 1e9 / links as f64;
    println!("dataflow       : {ns:.0} ns/link");
    results.push(("dataflow".into(), ns));

    // when_all join-width sweep: the dependency-completion path at the
    // fan-in widths a real DAG sees (stencil = 3, reductions = wide).
    for &width in &[8usize, 64, 512, 4096] {
        // ~n total dependency completions per width, at least 8 rounds.
        let rounds = (n / width).max(8);
        let ns = measure_when_all(width, rounds);
        println!("when_all_{width:<6}: {ns:.0} ns/dep ({rounds} rounds)");
        results.push((format!("when_all_{width}"), ns));
    }

    // stencil-shaped dataflow (3 deps, Chunk-sized payload clones)
    let iterations = if cli.smoke { 100 } else { 500 };
    let params = rhpx::stencil::StencilParams {
        n_sub: 8,
        nx: 64,
        iterations,
        steps: 4,
        courant: 0.9,
        window: 16,
        ..rhpx::stencil::StencilParams::tiny()
    };
    let t = Timer::start();
    let (_, rep) = rhpx::stencil::run(&rt, &params).unwrap();
    let ns = t.elapsed_secs() * 1e9 / rep.tasks as f64;
    println!("stencil task   : {ns:.0} ns/task ({} tasks)", rep.tasks);
    results.push(("stencil_task".into(), ns));

    cli.emit(
        "perf_micro",
        JsonValue::Arr(
            results
                .into_iter()
                .map(|(name, ns)| {
                    JsonValue::obj([
                        ("name".to_string(), JsonValue::from(name)),
                        ("ns_per_launch".to_string(), JsonValue::from(ns)),
                    ])
                })
                .collect(),
        ),
    );
}
