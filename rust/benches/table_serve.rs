//! Bench: `rhpx serve` under sustained multi-client load — steady-state
//! throughput/latency (p50/p99/p999 from the log-bucketed histogram), an
//! overload arm at ≥4× queue capacity (graceful degradation: bounded
//! queue, explicit rejects, zero lost accepted jobs), and journaled
//! crash-restart recovery (every accepted job completes exactly once).
//!
//!   cargo run --release --bin table_serve -- [--smoke] [--json PATH]
//!   cargo bench --bench table_serve
//!
//! Env: RHPX_BENCH_SCALE (default 0.04 → 4 jobs per client, the floor),
//!      RHPX_BENCH_REPEATS (accepted for interface parity; the arms are
//!      single-shot).

use rhpx::harness::{emit, table_serve, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.04),
        repeats: cli.repeats_from_env(1),
        csv: Some("bench_table_serve.csv".into()),
        ..Default::default()
    };
    let bench = table_serve::run_table_serve(&opts);
    emit(&table_serve::to_table(&bench), &opts);
    cli.emit("table_serve", table_serve::to_json(&bench));
}
