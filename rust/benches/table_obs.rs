//! Bench: the flight recorder's own price — ns/task with the recorder
//! off, on, and on-with-Chrome-export, at 20 µs and 200 µs task grains
//! (see `rhpx::harness::table_obs`). CI asserts the 200 µs trace-on arm
//! stays within 5% of trace-off.
//!
//!   cargo run --release --bin table_obs -- [--smoke] [--json PATH]
//!   cargo bench --bench table_obs
//!
//! Env: RHPX_BENCH_SCALE (default 0.01), RHPX_BENCH_REPEATS (default 3).

use rhpx::harness::{emit, table_obs, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table_obs.csv".into()),
        ..Default::default()
    };
    let rows = table_obs::run_table_obs(&opts);
    emit(&table_obs::to_table(&rows), &opts);
    cli.emit("table_obs", table_obs::to_json(&rows));
}
