//! Bench: the process-backed survival experiment — real spawned worker
//! processes, a literal `SIGKILL` mid-run, heartbeat detection latency,
//! and lineage recovery across {no-resilience, replay:3, team:3,
//! checkpoint:2} arms.
//!
//!   cargo run --release --bin table_proc -- [--smoke] [--json PATH]
//!   cargo bench --bench table_proc
//!
//! Env: RHPX_BENCH_SCALE (default 0.01), RHPX_BENCH_REPEATS (default 3),
//!      RHPX_WORKER_BIN (worker binary override; defaults to the `rhpx`
//!      CLI Cargo just built when run via `cargo bench`, else to the
//!      `rhpx` binary next to this one).

use rhpx::harness::{emit, table_proc, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    // `cargo bench` compiles this target with CARGO_BIN_EXE_rhpx set;
    // the plain `--bin table_proc` build does not, and then the worker
    // resolver falls back to the `rhpx` binary sitting next to this one.
    if std::env::var_os("RHPX_WORKER_BIN").is_none() {
        if let Some(bin) = option_env!("CARGO_BIN_EXE_rhpx") {
            std::env::set_var("RHPX_WORKER_BIN", bin);
        }
    }
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table_proc.csv".into()),
        ..Default::default()
    };
    let rows = table_proc::run_table_proc(&opts);
    emit(&table_proc::to_table(&rows), &opts);
    cli.emit("table_proc", table_proc::to_json(&rows));
}
