//! Bench: the workload zoo under one fault model — per-workload overhead
//! vs survival across five arms (pool reference, unrecovered kill,
//! replay recovery, adaptive-replicate recovery, checkpoint recovery)
//! for every registered `Workload` (1D/2D stencils, fork-join, Jacobi
//! with global reduction, streaming pipeline).
//!
//!   cargo run --release --bin table_zoo -- [--smoke] [--json PATH]
//!   cargo bench --bench table_zoo
//!
//! Env: RHPX_BENCH_SCALE (default 0.01 → zoo scale 1, the floor),
//!      RHPX_BENCH_REPEATS (default 3).

use rhpx::harness::{emit, table_zoo, HarnessOpts};
use rhpx::metrics::BenchCli;

fn main() {
    let cli = BenchCli::parse();
    let opts = HarnessOpts {
        scale: cli.scale_from_env(0.01),
        repeats: cli.repeats_from_env(3),
        csv: Some("bench_table_zoo.csv".into()),
        ..Default::default()
    };
    let rows = table_zoo::run_table_zoo(&opts);
    emit(&table_zoo::to_table(&rows), &opts);
    cli.emit("table_zoo", table_zoo::to_json(&rows));
}
