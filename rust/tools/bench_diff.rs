//! `bench_diff` — per-metric deltas of `BENCH_*.json` files against the
//! committed `BENCH_baseline/` snapshot.
//!
//!   cargo run --release --bin bench_diff -- [--baseline DIR] [FILE...]
//!
//! Defaults: baseline dir `BENCH_baseline`, files `BENCH_perf_micro.json`
//! and `BENCH_table_obs.json`.
//! Dependency-free: reuses the crate's own `metrics::bench_json` parser.
//! Always exits 0 — this is a *report* (CI runs it as a non-blocking
//! step); regressions are surfaced, not enforced, so noisy runners never
//! block a merge. Metrics are flattened to dotted paths; arrays of
//! `{"name": …}` objects (the bench result convention) key by name.

use std::collections::BTreeMap;
use std::path::Path;

use rhpx::metrics::JsonValue;

/// Flatten a bench payload into `metric path → number`.
fn flatten(v: &JsonValue, prefix: &str, out: &mut BTreeMap<String, f64>) {
    let join = |p: &str, k: &str| {
        if p.is_empty() {
            k.to_string()
        } else {
            format!("{p}.{k}")
        }
    };
    match v {
        JsonValue::Num(x) => {
            out.insert(prefix.to_string(), *x);
        }
        // Numeric-looking strings are metrics too: rendered table cells
        // and quantile fields (p50/p99/p999) arrive as strings in some
        // payloads, and skipping them would hide latency regressions.
        JsonValue::Str(s) => {
            if let Ok(x) = s.trim().parse::<f64>() {
                out.insert(prefix.to_string(), x);
            }
        }
        JsonValue::Obj(map) => {
            for (k, val) in map {
                // Envelope/metadata keys are not metrics.
                if prefix.is_empty()
                    && matches!(k.as_str(), "bench" | "smoke" | "schema_version" | "provisional")
                {
                    continue;
                }
                if k == "name" {
                    continue; // already consumed as the path segment
                }
                flatten(val, &join(prefix, k), out);
            }
        }
        JsonValue::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                let seg = item
                    .get("name")
                    .and_then(JsonValue::as_str)
                    .map(str::to_string)
                    .unwrap_or_else(|| i.to_string());
                flatten(item, &join(prefix, &seg), out);
            }
        }
        _ => {}
    }
}

fn load(path: &Path) -> Option<JsonValue> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("bench-diff: cannot read {}: {e}", path.display());
            return None;
        }
    };
    match JsonValue::parse(&text) {
        Ok(v) => Some(v),
        Err(e) => {
            println!("bench-diff: cannot parse {}: {e}", path.display());
            None
        }
    }
}

fn diff_one(baseline_dir: &Path, file: &str) {
    println!("== {file} vs {}/{file} ==", baseline_dir.display());
    let Some(current) = load(Path::new(file)) else {
        println!("   (run `make bench-smoke` or `make bench` first)");
        return;
    };
    let base_path = baseline_dir.join(file);
    let baseline = load(&base_path);
    if baseline.is_none() {
        println!("   no baseline snapshot — capture one with `make bench-baseline`");
    }
    if let Some(b) = &baseline {
        if b.get("provisional").and_then(JsonValue::as_bool) == Some(true) {
            println!(
                "   WARNING: baseline is a provisional placeholder — regenerate it \
                 with `make bench-baseline` on this machine for meaningful deltas"
            );
        }
    }

    let mut cur = BTreeMap::new();
    flatten(&current, "", &mut cur);
    let mut base = BTreeMap::new();
    if let Some(b) = &baseline {
        flatten(b, "", &mut base);
    }

    println!("   {:<44} {:>14} {:>14} {:>9}", "metric", "baseline", "current", "delta");
    for (metric, now) in &cur {
        match base.get(metric) {
            Some(then) if *then != 0.0 => {
                let pct = (now - then) / then * 100.0;
                let marker = if pct <= -5.0 {
                    " (improved)"
                } else if pct >= 5.0 {
                    " (regressed)"
                } else {
                    ""
                };
                println!(
                    "   {metric:<44} {then:>14.1} {now:>14.1} {pct:>+8.1}%{marker}"
                );
            }
            _ => {
                println!("   {metric:<44} {:>14} {now:>14.1} {:>9}", "—", "n/a");
            }
        }
    }
    for metric in base.keys() {
        if !cur.contains_key(metric) {
            println!("   {metric:<44} dropped from current run");
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut baseline_dir = "BENCH_baseline".to_string();
    let mut files: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--baseline" {
            if let Some(d) = args.get(i + 1) {
                baseline_dir = d.clone();
                i += 1;
            }
        } else if !args[i].starts_with("--") {
            files.push(args[i].clone());
        }
        i += 1;
    }
    if files.is_empty() {
        files.push("BENCH_perf_micro.json".to_string());
        files.push("BENCH_table_obs.json".to_string());
    }
    for f in &files {
        diff_one(Path::new(&baseline_dir), f);
    }
}
