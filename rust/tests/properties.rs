//! Property-based tests over the coordinator invariants (mini-proptest
//! harness in `rhpx::testing` — no external crates offline).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use rhpx::resilience::{
    async_replay, async_replicate, async_replicate_vote, vote_majority,
};
use rhpx::stencil::{self, Mode, StencilParams};
use rhpx::testing::{check, gen, PropConfig};
use rhpx::{async_, when_all, Runtime, TaskResult};

/// ∀ worker counts and task counts: every spawned task runs exactly once.
#[test]
fn prop_every_task_runs_exactly_once() {
    check("exactly-once", PropConfig { cases: 24, seed: 0x11 }, |rng| {
        let workers = gen::usize_in(rng, 1, 4);
        let tasks = gen::usize_in(rng, 1, 300);
        let rt = Runtime::builder().workers(workers).build();
        let counter = Arc::new(AtomicUsize::new(0));
        let futs: Vec<_> = (0..tasks)
            .map(|_| {
                let c = Arc::clone(&counter);
                async_(&rt, move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    0u8
                })
            })
            .collect();
        for f in futs {
            f.get().map_err(|e| e.to_string())?;
        }
        let ran = counter.load(Ordering::SeqCst);
        if ran != tasks {
            return Err(format!("{ran} executions for {tasks} tasks"));
        }
        let stats = rt.stats();
        if stats.spawned != tasks as u64 {
            return Err(format!("spawned {} != {tasks}", stats.spawned));
        }
        Ok(())
    });
}

/// ∀ n, failure patterns: replay runs min(first_success, n) attempts and
/// never more than n.
#[test]
fn prop_replay_attempt_bound() {
    check("replay-bound", PropConfig { cases: 48, seed: 0x22 }, |rng| {
        let n = gen::usize_in(rng, 1, 6);
        let fail_first = gen::usize_in(rng, 0, 8);
        let rt = Runtime::builder().workers(2).build();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replay(&rt, n, move || -> TaskResult<u32> {
            if c.fetch_add(1, Ordering::SeqCst) < fail_first {
                Err("boom".into())
            } else {
                Ok(1)
            }
        });
        let result = f.get();
        let attempts = calls.load(Ordering::SeqCst);
        let expected = (fail_first + 1).min(n);
        if attempts != expected {
            return Err(format!(
                "n={n} fail_first={fail_first}: {attempts} attempts, expected {expected}"
            ));
        }
        match result {
            Ok(_) if fail_first < n => Ok(()),
            Err(_) if fail_first >= n => Ok(()),
            other => Err(format!("wrong outcome {other:?} for n={n} fail_first={fail_first}")),
        }
    });
}

/// ∀ n: replicate launches exactly n replicas, eagerly.
#[test]
fn prop_replicate_launch_count() {
    check("replicate-count", PropConfig { cases: 24, seed: 0x33 }, |rng| {
        let n = gen::usize_in(rng, 1, 8);
        let rt = Runtime::builder().workers(2).build();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replicate(&rt, n, move || {
            c.fetch_add(1, Ordering::SeqCst);
            7i32
        });
        f.get().map_err(|e| e.to_string())?;
        rt.wait_idle();
        let launched = calls.load(Ordering::SeqCst);
        if launched != n {
            return Err(format!("launched {launched}, expected {n}"));
        }
        Ok(())
    });
}

/// ∀ minority corruption patterns: majority vote returns the true value.
#[test]
fn prop_vote_defeats_minority_corruption() {
    check("vote-minority", PropConfig { cases: 48, seed: 0x44 }, |rng| {
        let n = 2 * gen::usize_in(rng, 1, 3) + 1; // odd: 3,5,7
        let corrupt = gen::usize_in(rng, 0, n / 2); // strict minority
        let rt = Runtime::builder().workers(2).build();
        let idx = Arc::new(AtomicUsize::new(0));
        let i = Arc::clone(&idx);
        let f = async_replicate_vote(&rt, n, vote_majority, move || {
            // The first `corrupt` replicas silently return garbage.
            if i.fetch_add(1, Ordering::SeqCst) < corrupt {
                -1i64
            } else {
                42i64
            }
        });
        match f.get() {
            Ok(42) => Ok(()),
            other => Err(format!("n={n} corrupt={corrupt}: {other:?}")),
        }
    });
}

/// ∀ completion orders: when_all preserves input order.
#[test]
fn prop_when_all_order_invariant() {
    check("when-all-order", PropConfig { cases: 32, seed: 0x55 }, |rng| {
        let n = gen::usize_in(rng, 1, 40);
        let rt = Runtime::builder().workers(3).build();
        let futs: Vec<_> = (0..n)
            .map(|i| {
                // Randomize completion order via random busy work.
                let spin = gen::usize_in(rng, 0, 500);
                async_(&rt, move || {
                    for _ in 0..spin {
                        std::hint::spin_loop();
                    }
                    i as i64
                })
            })
            .collect();
        let all = when_all(futs).get().map_err(|e| e.to_string())?;
        let expect: Vec<i64> = (0..n as i64).collect();
        if all != expect {
            return Err(format!("order violated: {all:?}"));
        }
        Ok(())
    });
}

/// ∀ small stencil configurations: the global checksum is conserved and
/// replay under injected failures yields the identical result to the
/// failure-free run.
#[test]
fn prop_stencil_replay_equals_pure() {
    check("stencil-replay-exact", PropConfig { cases: 10, seed: 0x66 }, |rng| {
        let n_sub = gen::usize_in(rng, 2, 6);
        let steps = gen::usize_in(rng, 1, 4);
        let nx = gen::usize_in(rng, steps.max(4), 32);
        let iterations = gen::usize_in(rng, 1, 6);
        let rt = Runtime::builder().workers(2).build();
        let base = StencilParams {
            n_sub,
            nx,
            iterations,
            steps,
            courant: 0.9,
            ..StencilParams::tiny()
        };
        let (pure, _) = stencil::run(&rt, &base).map_err(|e| e.to_string())?;
        let resilient = StencilParams {
            mode: Mode::Replay { n: 8 },
            error_rate: Some(1.0),
            ..base
        };
        let (replayed, rep) = stencil::run(&rt, &resilient).map_err(|e| e.to_string())?;
        if rep.launch_errors != 0 {
            return Err(format!("launch errors: {}", rep.launch_errors));
        }
        if pure != replayed {
            return Err("replayed result diverged from pure run".into());
        }
        Ok(())
    });
}

/// ∀ random inputs: the Rust kernel conserves the sum for interior-only
/// updates against the analytic telescoping property of the flux form.
#[test]
fn prop_kernel_linearity() {
    // Lax-Wendroff is linear: K(a·u + b·v) = a·K(u) + b·K(v).
    check("kernel-linearity", PropConfig { cases: 40, seed: 0x77 }, |rng| {
        let steps = gen::usize_in(rng, 1, 5);
        let nx = gen::usize_in(rng, 4, 40);
        let len = nx + 2 * steps;
        let u = gen::vec_f64(rng, len, len, -1.0, 1.0);
        let v = gen::vec_f64(rng, len, len, -1.0, 1.0);
        let a = gen::f64_in(rng, -2.0, 2.0);
        let b = gen::f64_in(rng, -2.0, 2.0);
        let c = gen::f64_in(rng, 0.0, 1.0);
        let combo: Vec<f64> = u.iter().zip(&v).map(|(x, y)| a * x + b * y).collect();
        let k_combo = stencil::kernel::lax_wendroff_multistep(&combo, steps, c);
        let ku = stencil::kernel::lax_wendroff_multistep(&u, steps, c);
        let kv = stencil::kernel::lax_wendroff_multistep(&v, steps, c);
        for i in 0..k_combo.len() {
            let expect = a * ku[i] + b * kv[i];
            if (k_combo[i] - expect).abs() > 1e-9 {
                return Err(format!("linearity violated at {i}: {} vs {expect}", k_combo[i]));
            }
        }
        Ok(())
    });
}

/// ∀ random key/value docs: the TOML-subset parser round-trips values.
#[test]
fn prop_toml_roundtrip() {
    use rhpx::config::toml::{parse, Value};
    check("toml-roundtrip", PropConfig { cases: 64, seed: 0x88 }, |rng| {
        let n = gen::usize_in(rng, 1, 12);
        let mut src = String::from("[s]\n");
        let mut expect: Vec<(String, Value)> = Vec::new();
        for i in 0..n {
            let key = format!("k{i}");
            match gen::usize_in(rng, 0, 2) {
                0 => {
                    let v = gen::usize_in(rng, 0, 1_000_000) as i64 - 500_000;
                    src.push_str(&format!("{key} = {v}\n"));
                    expect.push((key, Value::Int(v)));
                }
                1 => {
                    let v = (gen::f64_in(rng, -100.0, 100.0) * 8.0).round() / 8.0;
                    src.push_str(&format!("{key} = {v:?}\n"));
                    expect.push((key, Value::Float(v)));
                }
                _ => {
                    let v = gen::bool_with(rng, 0.5);
                    src.push_str(&format!("{key} = {v}\n"));
                    expect.push((key, Value::Bool(v)));
                }
            }
        }
        let doc = parse(&src).map_err(|e| e.to_string())?;
        for (key, val) in expect {
            let got = doc.get(&format!("s.{key}")).ok_or(format!("missing {key}"))?;
            match (got, &val) {
                (Value::Float(a), Value::Float(b)) if (a - b).abs() < 1e-9 => {}
                _ if got == &val => {}
                _ => return Err(format!("{key}: {got:?} != {val:?}")),
            }
        }
        Ok(())
    });
}

/// ∀ random stencil domain states: snapshot serialization round-trips
/// bit-identically through every shared store backend — data, stored
/// checksum, and the verify() outcome all survive serialize →
/// persist → load → deserialize.
#[test]
fn prop_snapshot_roundtrip_preserves_stencil_state() {
    use rhpx::checkpoint::{
        DiskSnapshotStore, MemorySnapshotStore, SnapshotData, SnapshotStore,
    };
    use rhpx::stencil::Chunk;

    let dir = std::env::temp_dir().join(format!("rhpx_prop_snap_{}", std::process::id()));
    let disk = DiskSnapshotStore::new(dir.clone());
    let mem = MemorySnapshotStore::new();
    check("snapshot-roundtrip", PropConfig { cases: 32, seed: 0xAA }, |rng| {
        let len = gen::usize_in(rng, 1, 64);
        let data = gen::vec_f64(rng, len, len, -1e3, 1e3);
        // Half the cases carry a deliberately stale checksum — it must
        // survive the round trip (staleness stays detectable).
        let stale = gen::bool_with(rng, 0.5);
        let chunk = if stale {
            Chunk::with_checksum(data, gen::f64_in(rng, -1e6, 1e6))
        } else {
            Chunk::new(data)
        };
        let bytes = chunk.to_bytes();
        for store in [&mem as &dyn SnapshotStore, &disk as &dyn SnapshotStore] {
            store.save("case", &bytes).map_err(|e| e.to_string())?;
            let loaded = store.load("case").ok_or("snapshot vanished")?;
            let back = Chunk::from_bytes(&loaded).ok_or("undecodable snapshot")?;
            if back.data != chunk.data {
                return Err("data diverged through the store".into());
            }
            if back.checksum.to_bits() != chunk.checksum.to_bits() {
                return Err("stored checksum diverged through the store".into());
            }
            if back.verify(1e-9) != chunk.verify(1e-9) {
                return Err("verify() outcome changed across the round trip".into());
            }
        }
        // The nested-vector encoding (global C/R state) round-trips too.
        let rows = gen::usize_in(rng, 1, 4);
        let state: Vec<Vec<f64>> = (0..rows)
            .map(|_| {
                let n = gen::usize_in(rng, 0, 16);
                gen::vec_f64(rng, n, n, -10.0, 10.0)
            })
            .collect();
        if Vec::<Vec<f64>>::from_bytes(&state.to_bytes()).as_ref() != Some(&state) {
            return Err("Vec<Vec<f64>> snapshot round trip diverged".into());
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// ∀ resilience policy specs: `PolicySpec::parse` inverts `token()`
/// exactly — the CLI/harness spec-string grammar and the typed policy
/// are one bijection, so no component can accept a spec another would
/// print differently.
#[test]
fn prop_policy_spec_parse_inverts_token() {
    use rhpx::resilience::executor::{PolicySpec, SnapshotBackend};
    check("policy-spec-roundtrip", PropConfig { cases: 64, seed: 0xBB }, |rng| {
        let n = gen::usize_in(rng, 1, 12);
        let spec = match gen::usize_in(rng, 0, 6) {
            0 => PolicySpec::Replay { n },
            1 => PolicySpec::Replicate { n },
            2 => PolicySpec::Adaptive { ceiling: n },
            3 => PolicySpec::AdaptiveReplicate { ceiling: n },
            4 => PolicySpec::Team { n },
            5 => PolicySpec::Drain,
            _ => {
                let backend = match gen::usize_in(rng, 0, 3) {
                    0 => SnapshotBackend::Auto,
                    1 => SnapshotBackend::Memory,
                    2 => SnapshotBackend::Disk,
                    _ => SnapshotBackend::Agas,
                };
                PolicySpec::Checkpoint { every: n, backend }
            }
        };
        let token = spec.token();
        let parsed = PolicySpec::parse(&token).map_err(|e| e.to_string())?;
        if parsed != spec {
            return Err(format!("{token:?}: parsed {parsed:?} != {spec:?}"));
        }
        if parsed.label() != spec.label() {
            return Err(format!("{token:?}: label diverged across the round trip"));
        }
        // And a token is never ambiguous with garbage: appending junk
        // must fail to parse, not silently truncate.
        if PolicySpec::parse(&format!("{token}:zzz")).is_ok() {
            return Err(format!("{token:?}: trailing junk accepted"));
        }
        Ok(())
    });
}

/// ∀ random cluster shapes, task counts, and kill points: every tracked
/// task body runs exactly once (the lineage ledger's claim/drain
/// arbitration), every future resolves with its own task's value, and
/// the three per-locality counters account for every routing —
/// Σ(executed + rejected + lost) = initial submissions + lost, i.e. each
/// re-materialization is one fresh routing and nothing is double-counted
/// or dropped.
#[test]
fn prop_lineage_exactly_once_under_random_kills() {
    use rhpx::agas::LocalityId;
    use rhpx::distributed::{Cluster, Locality, NetworkConfig};
    use rhpx::TaskResult;

    check("lineage-exactly-once", PropConfig { cases: 12, seed: 0xCC }, |rng| {
        let n_loc = gen::usize_in(rng, 2, 4);
        let tasks = gen::usize_in(rng, 8, 40);
        let kill_before = gen::usize_in(rng, 0, tasks - 1);
        let victim = gen::usize_in(rng, 0, n_loc - 1);

        let cluster = Cluster::new(n_loc, 1, NetworkConfig::default());
        let runs: Arc<Vec<AtomicUsize>> =
            Arc::new((0..tasks).map(|_| AtomicUsize::new(0)).collect());

        let mut futs = Vec::with_capacity(tasks);
        for i in 0..tasks {
            if i == kill_before {
                // The kill lands mid-stream: whatever the victim still
                // has queued must re-materialize onto survivors.
                cluster.kill(LocalityId(victim));
            }
            let target = cluster.next_alive_target();
            let r = Arc::clone(&runs);
            futs.push(cluster.run_on_resilient(
                target,
                None,
                Arc::new(move |_loc: &Locality| -> TaskResult<usize> {
                    r[i].fetch_add(1, Ordering::SeqCst);
                    Ok(i)
                }),
            ));
        }

        for (i, f) in futs.into_iter().enumerate() {
            match f.get() {
                Ok(v) if v == i => {}
                other => return Err(format!("task {i} resolved {other:?}")),
            }
        }
        for (i, r) in runs.iter().enumerate() {
            let n = r.load(Ordering::SeqCst);
            if n != 1 {
                return Err(format!("task {i} ran {n} times (kill@{kill_before} loc{victim})"));
            }
        }

        let (mut executed, mut rejected, mut lost) = (0usize, 0usize, 0usize);
        for id in 0..cluster.len() {
            let loc = cluster.locality(LocalityId(id));
            executed += loc.tasks_executed();
            rejected += loc.tasks_rejected();
            lost += loc.tasks_lost();
        }
        if executed + rejected + lost != tasks + lost {
            return Err(format!(
                "routing accounting broke: executed {executed} + rejected {rejected} \
                 + lost {lost} != submissions {tasks} + lost {lost}"
            ));
        }
        if executed != tasks {
            return Err(format!("{executed} executions for {tasks} tracked tasks"));
        }
        Ok(())
    });
}

/// ∀ team sizes, replica outcomes, and arrival orders: the future
/// resolves with the *first* acceptable result in arrival order, every
/// replica arriving after the win retires (cancellation soundness: its
/// body never runs), a late result never overwrites the resolved value,
/// and a team where nothing wins reports a team-wide error.
#[test]
fn prop_team_cancellation_soundness() {
    use rhpx::resilience::ReplicaTeam;
    use rhpx::TaskError;

    check("team-cancel-sound", PropConfig { cases: 64, seed: 0xDD }, |rng| {
        let n = gen::usize_in(rng, 1, 6);
        // Per replica: 0 = hard failure, 1 = validation-rejected result,
        // 2 = validated success (value = replica index).
        let outcomes: Vec<u8> =
            (0..n).map(|_| gen::usize_in(rng, 0, 2) as u8).collect();
        // Random arrival order (Fisher–Yates).
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = gen::usize_in(rng, 0, i);
            order.swap(i, j);
        }

        let (team, fut) = ReplicaTeam::<usize>::new(n);
        let token = team.token();
        let mut expected_winner: Option<usize> = None;
        let mut expected_retired = 0usize;
        for &idx in &order {
            // The replica protocol: a cancelled replica retires without
            // running its body.
            if token.is_cancelled() {
                expected_retired += 1;
                team.report(Err(TaskError::Cancelled), None);
                continue;
            }
            match outcomes[idx] {
                0 => team.report(Err(TaskError::App("replica crashed".into())), None),
                1 => team.report(Ok(usize::MAX), Some(false)),
                _ => {
                    if expected_winner.is_none() {
                        expected_winner = Some(idx);
                    }
                    team.report(Ok(idx), Some(true));
                }
            }
        }

        if team.outstanding() != 0 {
            return Err(format!("{} replicas never reported", team.outstanding()));
        }
        if team.retired() != expected_retired {
            return Err(format!(
                "retired {} != expected {expected_retired}",
                team.retired()
            ));
        }
        let first = fut.get_copy();
        match expected_winner {
            Some(w) => {
                if first != Ok(w) {
                    return Err(format!(
                        "future resolved {first:?}, expected first winner {w} \
                         (order {order:?}, outcomes {outcomes:?})"
                    ));
                }
                if !token.is_cancelled() {
                    return Err("a win must cancel the token".into());
                }
            }
            None => {
                if first.is_ok() {
                    return Err(format!("no acceptable replica, yet future = {first:?}"));
                }
                if token.is_cancelled() {
                    return Err("nothing won, yet the token is cancelled".into());
                }
            }
        }
        // Stability: every report has already landed; re-reading must
        // return the identical outcome (late writes never overwrite).
        if fut.get_copy() != first {
            return Err("resolved future changed value on re-read".into());
        }
        Ok(())
    });
}

/// ∀ random migration sequences: AGAS locate always reflects the last
/// migrate, and generation counts migrations exactly.
#[test]
fn prop_agas_migration_consistency() {
    use rhpx::agas::{Agas, LocalityId};
    check("agas-migrations", PropConfig { cases: 32, seed: 0x99 }, |rng| {
        let agas = Agas::new();
        let gid = agas.register(LocalityId(0), 0u8);
        let moves = gen::usize_in(rng, 0, 20);
        let mut last = 0usize;
        for _ in 0..moves {
            last = gen::usize_in(rng, 0, 7);
            agas.migrate(gid, LocalityId(last));
        }
        if agas.locate(gid) != Some(LocalityId(if moves == 0 { 0 } else { last })) {
            return Err("locate out of sync".into());
        }
        if agas.generation(gid) != Some(moves as u64) {
            return Err(format!("generation {:?} != {moves}", agas.generation(gid)));
        }
        Ok(())
    });
}
