//! Property-based tests over the coordinator invariants (mini-proptest
//! harness in `rhpx::testing` — no external crates offline).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use rhpx::resilience::{
    async_replay, async_replicate, async_replicate_vote, vote_majority,
};
use rhpx::stencil::{self, Mode, StencilParams};
use rhpx::testing::{check, gen, PropConfig};
use rhpx::{async_, when_all, Runtime, TaskResult};

/// ∀ worker counts and task counts: every spawned task runs exactly once.
#[test]
fn prop_every_task_runs_exactly_once() {
    check("exactly-once", PropConfig { cases: 24, seed: 0x11 }, |rng| {
        let workers = gen::usize_in(rng, 1, 4);
        let tasks = gen::usize_in(rng, 1, 300);
        let rt = Runtime::builder().workers(workers).build();
        let counter = Arc::new(AtomicUsize::new(0));
        let futs: Vec<_> = (0..tasks)
            .map(|_| {
                let c = Arc::clone(&counter);
                async_(&rt, move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    0u8
                })
            })
            .collect();
        for f in futs {
            f.get().map_err(|e| e.to_string())?;
        }
        let ran = counter.load(Ordering::SeqCst);
        if ran != tasks {
            return Err(format!("{ran} executions for {tasks} tasks"));
        }
        let stats = rt.stats();
        if stats.spawned != tasks as u64 {
            return Err(format!("spawned {} != {tasks}", stats.spawned));
        }
        Ok(())
    });
}

/// ∀ n, failure patterns: replay runs min(first_success, n) attempts and
/// never more than n.
#[test]
fn prop_replay_attempt_bound() {
    check("replay-bound", PropConfig { cases: 48, seed: 0x22 }, |rng| {
        let n = gen::usize_in(rng, 1, 6);
        let fail_first = gen::usize_in(rng, 0, 8);
        let rt = Runtime::builder().workers(2).build();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replay(&rt, n, move || -> TaskResult<u32> {
            if c.fetch_add(1, Ordering::SeqCst) < fail_first {
                Err("boom".into())
            } else {
                Ok(1)
            }
        });
        let result = f.get();
        let attempts = calls.load(Ordering::SeqCst);
        let expected = (fail_first + 1).min(n);
        if attempts != expected {
            return Err(format!(
                "n={n} fail_first={fail_first}: {attempts} attempts, expected {expected}"
            ));
        }
        match result {
            Ok(_) if fail_first < n => Ok(()),
            Err(_) if fail_first >= n => Ok(()),
            other => Err(format!("wrong outcome {other:?} for n={n} fail_first={fail_first}")),
        }
    });
}

/// ∀ n: replicate launches exactly n replicas, eagerly.
#[test]
fn prop_replicate_launch_count() {
    check("replicate-count", PropConfig { cases: 24, seed: 0x33 }, |rng| {
        let n = gen::usize_in(rng, 1, 8);
        let rt = Runtime::builder().workers(2).build();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replicate(&rt, n, move || {
            c.fetch_add(1, Ordering::SeqCst);
            7i32
        });
        f.get().map_err(|e| e.to_string())?;
        rt.wait_idle();
        let launched = calls.load(Ordering::SeqCst);
        if launched != n {
            return Err(format!("launched {launched}, expected {n}"));
        }
        Ok(())
    });
}

/// ∀ minority corruption patterns: majority vote returns the true value.
#[test]
fn prop_vote_defeats_minority_corruption() {
    check("vote-minority", PropConfig { cases: 48, seed: 0x44 }, |rng| {
        let n = 2 * gen::usize_in(rng, 1, 3) + 1; // odd: 3,5,7
        let corrupt = gen::usize_in(rng, 0, n / 2); // strict minority
        let rt = Runtime::builder().workers(2).build();
        let idx = Arc::new(AtomicUsize::new(0));
        let i = Arc::clone(&idx);
        let f = async_replicate_vote(&rt, n, vote_majority, move || {
            // The first `corrupt` replicas silently return garbage.
            if i.fetch_add(1, Ordering::SeqCst) < corrupt {
                -1i64
            } else {
                42i64
            }
        });
        match f.get() {
            Ok(42) => Ok(()),
            other => Err(format!("n={n} corrupt={corrupt}: {other:?}")),
        }
    });
}

/// ∀ completion orders: when_all preserves input order.
#[test]
fn prop_when_all_order_invariant() {
    check("when-all-order", PropConfig { cases: 32, seed: 0x55 }, |rng| {
        let n = gen::usize_in(rng, 1, 40);
        let rt = Runtime::builder().workers(3).build();
        let futs: Vec<_> = (0..n)
            .map(|i| {
                // Randomize completion order via random busy work.
                let spin = gen::usize_in(rng, 0, 500);
                async_(&rt, move || {
                    for _ in 0..spin {
                        std::hint::spin_loop();
                    }
                    i as i64
                })
            })
            .collect();
        let all = when_all(futs).get().map_err(|e| e.to_string())?;
        let expect: Vec<i64> = (0..n as i64).collect();
        if all != expect {
            return Err(format!("order violated: {all:?}"));
        }
        Ok(())
    });
}

/// ∀ small stencil configurations: the global checksum is conserved and
/// replay under injected failures yields the identical result to the
/// failure-free run.
#[test]
fn prop_stencil_replay_equals_pure() {
    check("stencil-replay-exact", PropConfig { cases: 10, seed: 0x66 }, |rng| {
        let n_sub = gen::usize_in(rng, 2, 6);
        let steps = gen::usize_in(rng, 1, 4);
        let nx = gen::usize_in(rng, steps.max(4), 32);
        let iterations = gen::usize_in(rng, 1, 6);
        let rt = Runtime::builder().workers(2).build();
        let base = StencilParams {
            n_sub,
            nx,
            iterations,
            steps,
            courant: 0.9,
            ..StencilParams::tiny()
        };
        let (pure, _) = stencil::run(&rt, &base).map_err(|e| e.to_string())?;
        let resilient = StencilParams {
            mode: Mode::Replay { n: 8 },
            error_rate: Some(1.0),
            ..base
        };
        let (replayed, rep) = stencil::run(&rt, &resilient).map_err(|e| e.to_string())?;
        if rep.launch_errors != 0 {
            return Err(format!("launch errors: {}", rep.launch_errors));
        }
        if pure != replayed {
            return Err("replayed result diverged from pure run".into());
        }
        Ok(())
    });
}

/// ∀ random inputs: the Rust kernel conserves the sum for interior-only
/// updates against the analytic telescoping property of the flux form.
#[test]
fn prop_kernel_linearity() {
    // Lax-Wendroff is linear: K(a·u + b·v) = a·K(u) + b·K(v).
    check("kernel-linearity", PropConfig { cases: 40, seed: 0x77 }, |rng| {
        let steps = gen::usize_in(rng, 1, 5);
        let nx = gen::usize_in(rng, 4, 40);
        let len = nx + 2 * steps;
        let u = gen::vec_f64(rng, len, len, -1.0, 1.0);
        let v = gen::vec_f64(rng, len, len, -1.0, 1.0);
        let a = gen::f64_in(rng, -2.0, 2.0);
        let b = gen::f64_in(rng, -2.0, 2.0);
        let c = gen::f64_in(rng, 0.0, 1.0);
        let combo: Vec<f64> = u.iter().zip(&v).map(|(x, y)| a * x + b * y).collect();
        let k_combo = stencil::kernel::lax_wendroff_multistep(&combo, steps, c);
        let ku = stencil::kernel::lax_wendroff_multistep(&u, steps, c);
        let kv = stencil::kernel::lax_wendroff_multistep(&v, steps, c);
        for i in 0..k_combo.len() {
            let expect = a * ku[i] + b * kv[i];
            if (k_combo[i] - expect).abs() > 1e-9 {
                return Err(format!("linearity violated at {i}: {} vs {expect}", k_combo[i]));
            }
        }
        Ok(())
    });
}

/// ∀ random key/value docs: the TOML-subset parser round-trips values.
#[test]
fn prop_toml_roundtrip() {
    use rhpx::config::toml::{parse, Value};
    check("toml-roundtrip", PropConfig { cases: 64, seed: 0x88 }, |rng| {
        let n = gen::usize_in(rng, 1, 12);
        let mut src = String::from("[s]\n");
        let mut expect: Vec<(String, Value)> = Vec::new();
        for i in 0..n {
            let key = format!("k{i}");
            match gen::usize_in(rng, 0, 2) {
                0 => {
                    let v = gen::usize_in(rng, 0, 1_000_000) as i64 - 500_000;
                    src.push_str(&format!("{key} = {v}\n"));
                    expect.push((key, Value::Int(v)));
                }
                1 => {
                    let v = (gen::f64_in(rng, -100.0, 100.0) * 8.0).round() / 8.0;
                    src.push_str(&format!("{key} = {v:?}\n"));
                    expect.push((key, Value::Float(v)));
                }
                _ => {
                    let v = gen::bool_with(rng, 0.5);
                    src.push_str(&format!("{key} = {v}\n"));
                    expect.push((key, Value::Bool(v)));
                }
            }
        }
        let doc = parse(&src).map_err(|e| e.to_string())?;
        for (key, val) in expect {
            let got = doc.get(&format!("s.{key}")).ok_or(format!("missing {key}"))?;
            match (got, &val) {
                (Value::Float(a), Value::Float(b)) if (a - b).abs() < 1e-9 => {}
                _ if got == &val => {}
                _ => return Err(format!("{key}: {got:?} != {val:?}")),
            }
        }
        Ok(())
    });
}

/// ∀ random stencil domain states: snapshot serialization round-trips
/// bit-identically through every shared store backend — data, stored
/// checksum, and the verify() outcome all survive serialize →
/// persist → load → deserialize.
#[test]
fn prop_snapshot_roundtrip_preserves_stencil_state() {
    use rhpx::checkpoint::{
        DiskSnapshotStore, MemorySnapshotStore, SnapshotData, SnapshotStore,
    };
    use rhpx::stencil::Chunk;

    let dir = std::env::temp_dir().join(format!("rhpx_prop_snap_{}", std::process::id()));
    let disk = DiskSnapshotStore::new(dir.clone());
    let mem = MemorySnapshotStore::new();
    check("snapshot-roundtrip", PropConfig { cases: 32, seed: 0xAA }, |rng| {
        let len = gen::usize_in(rng, 1, 64);
        let data = gen::vec_f64(rng, len, len, -1e3, 1e3);
        // Half the cases carry a deliberately stale checksum — it must
        // survive the round trip (staleness stays detectable).
        let stale = gen::bool_with(rng, 0.5);
        let chunk = if stale {
            Chunk::with_checksum(data, gen::f64_in(rng, -1e6, 1e6))
        } else {
            Chunk::new(data)
        };
        let bytes = chunk.to_bytes();
        for store in [&mem as &dyn SnapshotStore, &disk as &dyn SnapshotStore] {
            store.save("case", &bytes).map_err(|e| e.to_string())?;
            let loaded = store.load("case").ok_or("snapshot vanished")?;
            let back = Chunk::from_bytes(&loaded).ok_or("undecodable snapshot")?;
            if back.data != chunk.data {
                return Err("data diverged through the store".into());
            }
            if back.checksum.to_bits() != chunk.checksum.to_bits() {
                return Err("stored checksum diverged through the store".into());
            }
            if back.verify(1e-9) != chunk.verify(1e-9) {
                return Err("verify() outcome changed across the round trip".into());
            }
        }
        // The nested-vector encoding (global C/R state) round-trips too.
        let rows = gen::usize_in(rng, 1, 4);
        let state: Vec<Vec<f64>> = (0..rows)
            .map(|_| {
                let n = gen::usize_in(rng, 0, 16);
                gen::vec_f64(rng, n, n, -10.0, 10.0)
            })
            .collect();
        if Vec::<Vec<f64>>::from_bytes(&state.to_bytes()).as_ref() != Some(&state) {
            return Err("Vec<Vec<f64>> snapshot round trip diverged".into());
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// ∀ resilience policy specs: `PolicySpec::parse` inverts `token()`
/// exactly — the CLI/harness spec-string grammar and the typed policy
/// are one bijection, so no component can accept a spec another would
/// print differently.
#[test]
fn prop_policy_spec_parse_inverts_token() {
    use rhpx::resilience::executor::{PolicySpec, SnapshotBackend};
    check("policy-spec-roundtrip", PropConfig { cases: 64, seed: 0xBB }, |rng| {
        let n = gen::usize_in(rng, 1, 12);
        let spec = match gen::usize_in(rng, 0, 6) {
            0 => PolicySpec::Replay { n },
            1 => PolicySpec::Replicate { n },
            2 => PolicySpec::Adaptive { ceiling: n },
            3 => PolicySpec::AdaptiveReplicate { ceiling: n },
            4 => PolicySpec::Team { n },
            5 => PolicySpec::Drain,
            _ => {
                let backend = match gen::usize_in(rng, 0, 3) {
                    0 => SnapshotBackend::Auto,
                    1 => SnapshotBackend::Memory,
                    2 => SnapshotBackend::Disk,
                    _ => SnapshotBackend::Agas,
                };
                PolicySpec::Checkpoint { every: n, backend }
            }
        };
        let token = spec.token();
        let parsed = PolicySpec::parse(&token).map_err(|e| e.to_string())?;
        if parsed != spec {
            return Err(format!("{token:?}: parsed {parsed:?} != {spec:?}"));
        }
        if parsed.label() != spec.label() {
            return Err(format!("{token:?}: label diverged across the round trip"));
        }
        // And a token is never ambiguous with garbage: appending junk
        // must fail to parse, not silently truncate.
        if PolicySpec::parse(&format!("{token}:zzz")).is_ok() {
            return Err(format!("{token:?}: trailing junk accepted"));
        }
        Ok(())
    });
}

/// ∀ random cluster shapes, task counts, and kill points: every tracked
/// task body runs exactly once (the lineage ledger's claim/drain
/// arbitration), every future resolves with its own task's value, and
/// the three per-locality counters account for every routing —
/// Σ(executed + rejected + lost) = initial submissions + lost, i.e. each
/// re-materialization is one fresh routing and nothing is double-counted
/// or dropped.
#[test]
fn prop_lineage_exactly_once_under_random_kills() {
    use rhpx::agas::LocalityId;
    use rhpx::distributed::{Cluster, Locality, NetworkConfig};
    use rhpx::TaskResult;

    check("lineage-exactly-once", PropConfig { cases: 12, seed: 0xCC }, |rng| {
        let n_loc = gen::usize_in(rng, 2, 4);
        let tasks = gen::usize_in(rng, 8, 40);
        let kill_before = gen::usize_in(rng, 0, tasks - 1);
        let victim = gen::usize_in(rng, 0, n_loc - 1);

        let cluster = Cluster::new(n_loc, 1, NetworkConfig::default());
        let runs: Arc<Vec<AtomicUsize>> =
            Arc::new((0..tasks).map(|_| AtomicUsize::new(0)).collect());

        let mut futs = Vec::with_capacity(tasks);
        for i in 0..tasks {
            if i == kill_before {
                // The kill lands mid-stream: whatever the victim still
                // has queued must re-materialize onto survivors.
                cluster.kill(LocalityId(victim));
            }
            let target = cluster.next_alive_target();
            let r = Arc::clone(&runs);
            futs.push(cluster.run_on_resilient(
                target,
                None,
                Arc::new(move |_loc: &Locality| -> TaskResult<usize> {
                    r[i].fetch_add(1, Ordering::SeqCst);
                    Ok(i)
                }),
            ));
        }

        for (i, f) in futs.into_iter().enumerate() {
            match f.get() {
                Ok(v) if v == i => {}
                other => return Err(format!("task {i} resolved {other:?}")),
            }
        }
        for (i, r) in runs.iter().enumerate() {
            let n = r.load(Ordering::SeqCst);
            if n != 1 {
                return Err(format!("task {i} ran {n} times (kill@{kill_before} loc{victim})"));
            }
        }

        let (mut executed, mut rejected, mut lost) = (0usize, 0usize, 0usize);
        for id in 0..cluster.len() {
            let loc = cluster.locality(LocalityId(id));
            executed += loc.tasks_executed();
            rejected += loc.tasks_rejected();
            lost += loc.tasks_lost();
        }
        if executed + rejected + lost != tasks + lost {
            return Err(format!(
                "routing accounting broke: executed {executed} + rejected {rejected} \
                 + lost {lost} != submissions {tasks} + lost {lost}"
            ));
        }
        if executed != tasks {
            return Err(format!("{executed} executions for {tasks} tracked tasks"));
        }
        Ok(())
    });
}

/// ∀ team sizes, replica outcomes, and arrival orders: the future
/// resolves with the *first* acceptable result in arrival order, every
/// replica arriving after the win retires (cancellation soundness: its
/// body never runs), a late result never overwrites the resolved value,
/// and a team where nothing wins reports a team-wide error.
#[test]
fn prop_team_cancellation_soundness() {
    use rhpx::resilience::ReplicaTeam;
    use rhpx::TaskError;

    check("team-cancel-sound", PropConfig { cases: 64, seed: 0xDD }, |rng| {
        let n = gen::usize_in(rng, 1, 6);
        // Per replica: 0 = hard failure, 1 = validation-rejected result,
        // 2 = validated success (value = replica index).
        let outcomes: Vec<u8> =
            (0..n).map(|_| gen::usize_in(rng, 0, 2) as u8).collect();
        // Random arrival order (Fisher–Yates).
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = gen::usize_in(rng, 0, i);
            order.swap(i, j);
        }

        let (team, fut) = ReplicaTeam::<usize>::new(n);
        let token = team.token();
        let mut expected_winner: Option<usize> = None;
        let mut expected_retired = 0usize;
        for &idx in &order {
            // The replica protocol: a cancelled replica retires without
            // running its body.
            if token.is_cancelled() {
                expected_retired += 1;
                team.report(Err(TaskError::Cancelled), None);
                continue;
            }
            match outcomes[idx] {
                0 => team.report(Err(TaskError::App("replica crashed".into())), None),
                1 => team.report(Ok(usize::MAX), Some(false)),
                _ => {
                    if expected_winner.is_none() {
                        expected_winner = Some(idx);
                    }
                    team.report(Ok(idx), Some(true));
                }
            }
        }

        if team.outstanding() != 0 {
            return Err(format!("{} replicas never reported", team.outstanding()));
        }
        if team.retired() != expected_retired {
            return Err(format!(
                "retired {} != expected {expected_retired}",
                team.retired()
            ));
        }
        let first = fut.get_copy();
        match expected_winner {
            Some(w) => {
                if first != Ok(w) {
                    return Err(format!(
                        "future resolved {first:?}, expected first winner {w} \
                         (order {order:?}, outcomes {outcomes:?})"
                    ));
                }
                if !token.is_cancelled() {
                    return Err("a win must cancel the token".into());
                }
            }
            None => {
                if first.is_ok() {
                    return Err(format!("no acceptable replica, yet future = {first:?}"));
                }
                if token.is_cancelled() {
                    return Err("nothing won, yet the token is cancelled".into());
                }
            }
        }
        // Stability: every report has already landed; re-reading must
        // return the identical outcome (late writes never overwrite).
        if fut.get_copy() != first {
            return Err("resolved future changed value on re-read".into());
        }
        Ok(())
    });
}

mod serve_protocol_props {
    use rhpx::failure::Rng;
    use rhpx::serve::{Frame, FrameError, JobSpec, StatusReport, TaskDesc};
    use rhpx::testing::gen;

    /// Arbitrary UTF-8 strings, multibyte characters included — the
    /// protocol carries workload names, policy specs, and free-text
    /// detail/reason fields.
    pub fn arb_string(rng: &mut Rng) -> String {
        const CHARS: &[char] =
            &['a', 'b', 'z', '_', '-', ':', '.', '/', ' ', '0', '9', 'λ', 'π', '✓'];
        let len = gen::usize_in(rng, 0, 12);
        (0..len).map(|_| CHARS[gen::usize_in(rng, 0, CHARS.len() - 1)]).collect()
    }

    /// Arbitrary opaque payload bytes (task inputs, results, snapshots).
    pub fn arb_bytes(rng: &mut Rng) -> Vec<u8> {
        let len = gen::usize_in(rng, 0, 24);
        (0..len).map(|_| rng.next_u64() as u8).collect()
    }

    /// Arbitrary counter snapshots (the Counters frame payload and the
    /// StatusReport counter list).
    pub fn arb_counters(rng: &mut Rng) -> Vec<(String, u64)> {
        let n = gen::usize_in(rng, 0, 4);
        (0..n).map(|_| (arb_string(rng), rng.next_u64())).collect()
    }

    /// Arbitrary trace events — every kind, full-range payload words.
    pub fn arb_events(rng: &mut Rng) -> Vec<rhpx::trace::Event> {
        use rhpx::trace::EventKind;
        let n = gen::usize_in(rng, 0, 6);
        (0..n)
            .map(|_| rhpx::trace::Event {
                ts_ns: rng.next_u64(),
                kind: EventKind::ALL[gen::usize_in(rng, 0, EventKind::ALL.len() - 1)],
                track: rng.next_u64() as u32,
                a: rng.next_u64(),
                b: rng.next_u64(),
            })
            .collect()
    }

    pub fn arb_frame(rng: &mut Rng) -> Frame {
        match gen::usize_in(rng, 0, 10) {
            0 => Frame::Submit(JobSpec {
                job_id: rng.next_u64(),
                workload: arb_string(rng),
                policy: arb_string(rng),
                scale_milli: rng.next_u64() as u32,
                error_prob_pct: gen::usize_in(rng, 0, 100) as u32,
            }),
            1 => Frame::Ack { job_id: rng.next_u64() },
            2 => Frame::Result {
                job_id: rng.next_u64(),
                ok: gen::bool_with(rng, 0.5),
                checksum_bits: rng.next_u64(),
                detail: arb_string(rng),
            },
            3 => Frame::Status(StatusReport {
                submitted: rng.next_u64(),
                accepted: rng.next_u64(),
                completed: rng.next_u64(),
                failed: rng.next_u64(),
                rejected_queue: rng.next_u64(),
                rejected_breaker: rng.next_u64(),
                queue_depth: rng.next_u64(),
                queue_capacity: rng.next_u64(),
                p50_us: rng.next_u64(),
                p99_us: rng.next_u64(),
                p999_us: rng.next_u64(),
                counters: arb_counters(rng),
            }),
            4 => Frame::Reject {
                job_id: rng.next_u64(),
                retry_after_ms: rng.next_u64(),
                reason: arb_string(rng),
            },
            5 => Frame::Launch(TaskDesc {
                task_id: rng.next_u64(),
                workload: arb_string(rng),
                scale_milli: rng.next_u64() as u32,
                layer: rng.next_u64() as u32,
                index: rng.next_u64() as u32,
                inputs: {
                    let n = gen::usize_in(rng, 0, 3);
                    (0..n).map(|_| arb_bytes(rng)).collect()
                },
            }),
            6 => Frame::TaskResult {
                task_id: rng.next_u64(),
                ok: gen::bool_with(rng, 0.5),
                payload: arb_bytes(rng),
            },
            7 => Frame::Heartbeat { locality: rng.next_u64() as u32, seq: rng.next_u64() },
            8 => Frame::Snapshot { key: arb_string(rng), bytes: arb_bytes(rng) },
            9 => Frame::Trace(rhpx::trace::spool::TraceChunk {
                locality: rng.next_u64() as u32,
                seq: rng.next_u64(),
                dropped: rng.next_u64(),
                events: arb_events(rng),
            }),
            _ => Frame::Counters {
                locality: rng.next_u64() as u32,
                counters: arb_counters(rng),
            },
        }
    }

    /// Classify: every decode failure must be one of the typed variants,
    /// reached without panicking.
    pub fn is_typed(e: &FrameError) -> bool {
        matches!(
            e,
            FrameError::Truncated { .. }
                | FrameError::BadMagic { .. }
                | FrameError::BadVersion { .. }
                | FrameError::UnknownTag { .. }
                | FrameError::Oversize { .. }
                | FrameError::ChecksumMismatch { .. }
                | FrameError::BadPayload { .. }
        )
    }
}

/// ∀ frames: decode(encode(f)) == (f, encoded length), and a stream of
/// two concatenated frames splits at exactly the first frame's boundary
/// — the framing layer never under- or over-consumes.
#[test]
fn prop_serve_frame_roundtrip_identity() {
    use rhpx::serve::Frame;
    use serve_protocol_props::arb_frame;

    check("serve-frame-roundtrip", PropConfig { cases: 128, seed: 0xF1 }, |rng| {
        let frame = arb_frame(rng);
        let bytes = frame.encode();
        let (back, consumed) = Frame::decode(&bytes).map_err(|e| e.to_string())?;
        if back != frame {
            return Err(format!("round trip diverged: {frame:?} -> {back:?}"));
        }
        if consumed != bytes.len() {
            return Err(format!("consumed {consumed} of {} bytes", bytes.len()));
        }

        // Stream of two frames: the first decode stops at the boundary,
        // the remainder decodes as the second frame.
        let second = arb_frame(rng);
        let mut stream = bytes.clone();
        stream.extend_from_slice(&second.encode());
        let (first, cut) = Frame::decode(&stream).map_err(|e| e.to_string())?;
        if first != frame || cut != bytes.len() {
            return Err(format!("stream split at {cut}, expected {}", bytes.len()));
        }
        let (rest, _) = Frame::decode(&stream[cut..]).map_err(|e| e.to_string())?;
        if rest != second {
            return Err("second frame corrupted by the first".into());
        }
        Ok(())
    });
}

/// ∀ frames and cut points: every strict prefix of an encoded frame
/// fails with `Truncated` — never a partial frame, never a panic, and
/// the decoder asks for more bytes rather than misparsing.
#[test]
fn prop_serve_frame_truncation_is_typed() {
    use rhpx::serve::{Frame, FrameError};
    use serve_protocol_props::arb_frame;

    check("serve-frame-truncate", PropConfig { cases: 96, seed: 0xF2 }, |rng| {
        let bytes = arb_frame(rng).encode();
        // One random cut plus the boundary cases.
        let cuts = [0, 1, 7, gen::usize_in(rng, 0, bytes.len() - 1), bytes.len() - 1];
        for cut in cuts {
            match Frame::decode(&bytes[..cut]) {
                Err(FrameError::Truncated { needed, have }) => {
                    if have != cut || needed <= have {
                        return Err(format!("cut {cut}: Truncated{{{needed},{have}}}"));
                    }
                }
                Ok((f, _)) => return Err(format!("cut {cut} decoded a partial frame {f:?}")),
                Err(e) => return Err(format!("cut {cut}: wrong error {e}")),
            }
        }
        Ok(())
    });
}

/// ∀ frames and bit positions: flipping any single bit of the encoding
/// is detected — decode returns a typed error (checksum mismatch, bad
/// header, or bad payload), never Ok and never a panic. The FNV-1a
/// step is a bijection of the running state, so a one-byte change in
/// the covered region always reaches a different trailer.
#[test]
fn prop_serve_frame_bitflip_detected() {
    use rhpx::serve::Frame;
    use serve_protocol_props::{arb_frame, is_typed};

    check("serve-frame-bitflip", PropConfig { cases: 192, seed: 0xF3 }, |rng| {
        let mut bytes = arb_frame(rng).encode();
        let byte = gen::usize_in(rng, 0, bytes.len() - 1);
        let bit = gen::usize_in(rng, 0, 7);
        bytes[byte] ^= 1 << bit;
        match Frame::decode(&bytes) {
            Ok((f, _)) => Err(format!("bit {bit} of byte {byte} flipped, yet decoded {f:?}")),
            Err(e) if is_typed(&e) => Ok(()),
            Err(e) => Err(format!("untyped error {e}")),
        }
    });
}

/// ∀ frames: a foreign protocol version or magic is rejected as exactly
/// that — version skew is detected before any payload is trusted.
#[test]
fn prop_serve_frame_version_and_magic_gate() {
    use rhpx::serve::{Frame, FrameError};
    use serve_protocol_props::arb_frame;

    check("serve-frame-version", PropConfig { cases: 64, seed: 0xF4 }, |rng| {
        let good = arb_frame(rng).encode();

        let mut skewed = good.clone();
        let v = gen::usize_in(rng, 2, 255) as u8; // any version but ours
        skewed[2] = v;
        match Frame::decode(&skewed) {
            Err(FrameError::BadVersion { got }) if got == v => {}
            other => return Err(format!("version {v}: {other:?}")),
        }

        let mut alien = good;
        alien[0] = b'X';
        match Frame::decode(&alien) {
            Err(FrameError::BadMagic { .. }) => Ok(()),
            other => Err(format!("bad magic accepted: {other:?}")),
        }
    });
}

/// ∀ heartbeat frames: the liveness beat of the process substrate
/// round-trips identically, every strict prefix is reported as
/// `Truncated` (a half-received beat is never mistaken for a whole one,
/// which would skew the failure detector), and any single flipped bit is
/// rejected with a typed error — a corrupted beat must never count as
/// proof of life.
#[test]
fn prop_serve_heartbeat_roundtrip_truncation_and_bitflip() {
    use rhpx::serve::{Frame, FrameError};
    use serve_protocol_props::is_typed;

    check("serve-heartbeat", PropConfig { cases: 128, seed: 0xF5 }, |rng| {
        let frame = Frame::Heartbeat {
            locality: rng.next_u64() as u32,
            seq: rng.next_u64(),
        };
        let bytes = frame.encode();
        let (back, consumed) = Frame::decode(&bytes).map_err(|e| e.to_string())?;
        if back != frame || consumed != bytes.len() {
            return Err(format!("round trip diverged: {frame:?} -> {back:?}"));
        }
        // A heartbeat is short enough to check *every* prefix.
        for cut in 0..bytes.len() {
            match Frame::decode(&bytes[..cut]) {
                Err(FrameError::Truncated { needed, have }) if have == cut && needed > cut => {}
                other => return Err(format!("prefix {cut}: {other:?}")),
            }
        }
        let mut flipped = bytes.clone();
        let byte = rhpx::testing::gen::usize_in(rng, 0, flipped.len() - 1);
        let bit = rhpx::testing::gen::usize_in(rng, 0, 7);
        flipped[byte] ^= 1 << bit;
        match Frame::decode(&flipped) {
            Ok((f, _)) => Err(format!("bit {bit} of byte {byte} flipped, yet decoded {f:?}")),
            Err(e) if is_typed(&e) => Ok(()),
            Err(e) => Err(format!("untyped error {e}")),
        }
    });
}

/// ∀ random event streams (arbitrary kinds, timestamps, and payload
/// words, across several tracks, with matched, unmatched, and orphaned
/// exec spans): the Chrome export round-trips through the crate's own
/// JSON parser, every event carries a phase from {B, E, i, M}, and
/// begins balance ends exactly — an orphaned half-span must degrade to
/// an instant, never corrupt the viewer's span stack.
#[test]
fn prop_chrome_export_json_valid_and_balanced() {
    use rhpx::metrics::JsonValue;
    use rhpx::trace::{chrome, Event, EventKind, Track};
    use serve_protocol_props::arb_events;

    check("chrome-export", PropConfig { cases: 48, seed: 0xE7 }, |rng| {
        let n_tracks = gen::usize_in(rng, 1, 3);
        let mut tracks = Vec::new();
        for t in 0..n_tracks {
            // Random noise events (any kind, any timestamp) plus
            // synthesized spans, some deliberately left unclosed — the
            // killed-worker shape.
            let mut events = arb_events(rng);
            let spans = gen::usize_in(rng, 0, 4);
            let mut ts = 0u64;
            for s in 0..spans {
                ts += 10;
                events.push(Event {
                    ts_ns: ts,
                    kind: EventKind::ExecBegin,
                    track: 0,
                    a: s as u64,
                    b: 0,
                });
                if gen::bool_with(rng, 0.7) {
                    ts += 10;
                    events.push(Event {
                        ts_ns: ts,
                        kind: EventKind::ExecEnd,
                        track: 0,
                        a: s as u64,
                        b: 0,
                    });
                }
            }
            events.sort_by_key(|e| e.ts_ns);
            tracks.push(Track {
                pid: gen::usize_in(rng, 1, 4) as u32,
                tid: t as u32 + 1,
                name: format!("lane-{t}"),
                events,
            });
        }
        let rendered = chrome::chrome_trace(&tracks, rng.next_u64() % 5).render();
        let back = JsonValue::parse(&rendered).map_err(|e| e.to_string())?;
        let events = back
            .get("traceEvents")
            .and_then(JsonValue::as_arr)
            .ok_or("no traceEvents array")?;
        let (mut begins, mut ends) = (0u64, 0u64);
        for e in events {
            match e.get("ph").and_then(JsonValue::as_str).ok_or("event without ph")? {
                "B" => begins += 1,
                "E" => ends += 1,
                "i" | "M" => {}
                other => return Err(format!("unexpected phase {other:?}")),
            }
        }
        if begins != ends {
            return Err(format!("{begins} begins vs {ends} ends"));
        }
        Ok(())
    });
}

/// ∀ random migration sequences: AGAS locate always reflects the last
/// migrate, and generation counts migrations exactly.
#[test]
fn prop_agas_migration_consistency() {
    use rhpx::agas::{Agas, LocalityId};
    check("agas-migrations", PropConfig { cases: 32, seed: 0x99 }, |rng| {
        let agas = Agas::new();
        let gid = agas.register(LocalityId(0), 0u8);
        let moves = gen::usize_in(rng, 0, 20);
        let mut last = 0usize;
        for _ in 0..moves {
            last = gen::usize_in(rng, 0, 7);
            agas.migrate(gid, LocalityId(last));
        }
        if agas.locate(gid) != Some(LocalityId(if moves == 0 { 0 } else { last })) {
            return Err("locate out of sync".into());
        }
        if agas.generation(gid) != Some(moves as u64) {
            return Err(format!("generation {:?} != {moves}", agas.generation(gid)));
        }
        Ok(())
    });
}
