//! Integration: the stencil application end-to-end, including the
//! coordinated-C/R baseline comparison the paper motivates in §I.

use rhpx::checkpoint::{run_with_checkpoints, CheckpointStore, Storage};
use rhpx::failure::FaultInjector;
use rhpx::stencil::{self, Domain, Mode, StencilParams};
use rhpx::Runtime;

#[test]
fn stencil_medium_run_exact() {
    let rt = Runtime::builder().workers(2).build();
    let params = StencilParams {
        n_sub: 16,
        nx: 128,
        iterations: 25,
        steps: 8,
        courant: 1.0,
        ..StencilParams::tiny()
    };
    let domain = Domain::sine(params.n_sub, params.nx);
    let (out, rep) = stencil::run(&rt, &params).unwrap();
    assert_eq!(rep.tasks, 400);
    assert_eq!(rep.launch_errors, 0);
    let shift = (params.iterations * params.steps) as f64;
    let exact = domain.exact_sine_shifted(shift);
    for (a, b) in out.iter().zip(exact.iter()) {
        assert!((a - b).abs() < 1e-9);
    }
}

#[test]
fn replicate_vote_defeats_silent_errors_in_stencil() {
    // Silent corruption + replica voting: since replicas re-draw the
    // corruption independently, a corrupted replica is outvoted by the
    // two clean ones.
    // NB: voting is consensus, not magic — if a strict majority of the n
    // replicas of one task corrupt simultaneously (P ≈ C(n,⌈n/2⌉)·p^⌈n/2⌉),
    // the launch legitimately fails with NoConsensus. With n = 5 and
    // p = 0.02 that is ~1e-4 per task; we retry the whole run in the
    // (rare) case the dice land there, since injector streams are
    // thread-timing dependent.
    let rt = Runtime::builder().workers(2).build();
    let base = StencilParams {
        mode: Mode::ReplicateVote { n: 5 },
        silent_rate: Some(0.02),
        ..StencilParams::tiny()
    };
    let domain = Domain::sine(base.n_sub, base.nx);
    let mut done = false;
    for attempt in 0..5 {
        let params = StencilParams { seed: base.seed + attempt, ..base.clone() };
        let Ok((out, rep)) = stencil::run(&rt, &params) else { continue };
        if rep.launch_errors > 0 {
            continue;
        }
        if rep.silent_corruptions == 0 {
            continue; // corruptor must fire for the test to be meaningful
        }
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9, "silent error leaked through voting");
        }
        done = true;
        break;
    }
    assert!(done, "no clean voted run in 5 attempts — voting is broken");
}

/// The paper's core economic argument (§I): under local failures, task
/// replay redoes only the failed task, while coordinated C/R rolls the
/// whole application back to the last global snapshot. Measure redone
/// work on identical failure sequences.
#[test]
fn task_replay_redoes_less_work_than_coordinated_cr() {
    let iterations = 60u64;
    let n_sub = 8usize;
    let p_fail = 0.05;

    // --- coordinated C/R over the same logical workload ---
    let store = CheckpointStore::new(Storage::Memory);
    let inj = FaultInjector::with_probability(p_fail, 1234);
    let mut state: Vec<f64> = vec![0.0; n_sub];
    let cr = run_with_checkpoints(&mut state, iterations, 10, &store, |_, s| {
        // one "iteration" = n_sub subdomain tasks; any task failure is a
        // global failure under coordinated C/R
        for v in s.iter_mut() {
            inj.draw("cr-task")?;
            *v += 1.0;
        }
        Ok(())
    })
    .unwrap();
    assert_eq!(state, vec![iterations as f64; n_sub]);

    // --- task replay over the same workload ---
    let rt = Runtime::builder().workers(2).build();
    let inj2 = FaultInjector::with_probability(p_fail, 1234);
    let mut replay_reexecutions = 0u64;
    for _ in 0..iterations {
        for _ in 0..n_sub {
            let i = inj2.clone();
            let f = rhpx::resilience::async_replay(&rt, 20, move || -> rhpx::TaskResult<()> {
                i.draw("replay-task")?;
                Ok(())
            });
            f.get().unwrap();
        }
    }
    // replay's redone work = injected failures (each failure redoes ONE task)
    replay_reexecutions += inj2.counters().injected();

    // C/R redone work = redone iterations × n_sub tasks each
    let cr_reexecutions = cr.redone * n_sub as u64 + cr.rollbacks; // + failed attempts
    assert!(cr.rollbacks > 0, "C/R must have rolled back at this failure rate");
    assert!(
        cr_reexecutions > replay_reexecutions,
        "C/R redid {cr_reexecutions} task-equivalents, replay only {replay_reexecutions}"
    );
}

#[test]
fn stencil_checkpoint_restart_equivalence() {
    // Running the stencil under C/R yields bit-identical results to the
    // uninterrupted run (rollback must be exact).
    let n_sub = 4;
    let nx = 32;
    let steps = 2;
    let domain0 = Domain::sine(n_sub, nx);

    let advance = |d: &Vec<Vec<f64>>| -> Vec<Vec<f64>> {
        let chunks: Vec<stencil::Chunk> =
            d.iter().map(|v| stencil::Chunk::new(v.clone())).collect();
        (0..n_sub)
            .map(|j| {
                let ext = stencil::build_extended(
                    &chunks[(j + n_sub - 1) % n_sub],
                    &chunks[j],
                    &chunks[(j + 1) % n_sub],
                    steps,
                );
                stencil::kernel::lax_wendroff_multistep(&ext, steps, 1.0)
            })
            .collect()
    };

    // Uninterrupted reference.
    let mut reference: Vec<Vec<f64>> =
        domain0.subdomains.iter().map(|c| c.data.to_vec()).collect();
    for _ in 0..20 {
        reference = advance(&reference);
    }

    // C/R run with injected failures.
    let store = CheckpointStore::new(Storage::Memory);
    let inj = FaultInjector::with_probability(0.15, 77);
    let mut state: Vec<Vec<f64>> =
        domain0.subdomains.iter().map(|c| c.data.to_vec()).collect();
    let rep = run_with_checkpoints(&mut state, 20, 5, &store, |_, s| {
        inj.draw("stencil-cr")?;
        *s = advance(s);
        Ok(())
    })
    .unwrap();
    assert!(rep.rollbacks > 0);
    assert_eq!(state, reference, "C/R result must match uninterrupted run");
}

#[test]
fn large_window_bounds_inflight_memory() {
    // window = 1: full barrier every iteration; still correct.
    let rt = Runtime::builder().workers(2).build();
    let params = StencilParams { window: 1, ..StencilParams::tiny() };
    let (out1, _) = stencil::run(&rt, &params).unwrap();
    let params = StencilParams { window: 1000, ..StencilParams::tiny() };
    let (out2, _) = stencil::run(&rt, &params).unwrap();
    assert_eq!(out1, out2);
}
