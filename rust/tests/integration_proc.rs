//! Integration: the process-backed locality substrate end to end — real
//! spawned `rhpx worker` children over TCP loopback, a literal `SIGKILL`
//! of a child PID mid-run, heartbeat-verdict death detection, and
//! lineage recovery to a bit-identical result.
//!
//! These tests need the `rhpx` CLI binary: Cargo builds it for
//! integration tests and exposes its path as `CARGO_BIN_EXE_rhpx`, which
//! each test pins into `RHPX_WORKER_BIN` before spawning the fleet. The
//! in-process simulated cluster stays the deterministic substrate for
//! schedule-interleaving tests; what is under test *here* is exactly the
//! part the simulation cannot exercise — processes that genuinely die.

use rhpx::distributed::ProcSpec;
use rhpx::resilience::executor::{PolicySpec, SnapshotBackend};
use rhpx::runtime_handle::Runtime;
use rhpx::workloads::{self, run, RunParams, RunReport};

/// The zoo at this scale is small enough that every workload finishes in
/// well under a second per arm even with a ~100 ms heartbeat verdict in
/// the middle.
const SCALE: f64 = 0.01;
const WORKERS: usize = 3;

/// Point the worker resolver at the CLI binary Cargo built for this test
/// run. Safe to call from every test: the value is identical each time.
fn pin_worker_bin() {
    std::env::set_var("RHPX_WORKER_BIN", env!("CARGO_BIN_EXE_rhpx"));
}

/// Milli-quantized scale — what the proc route actually runs at; the
/// pool reference must use the same value for checksums to be
/// comparable.
fn quantized_scale() -> f64 {
    (((SCALE * 1000.0).round() as u32).max(1)) as f64 / 1000.0
}

fn total_tasks(name: &str) -> usize {
    let w = workloads::by_name(name, quantized_scale()).expect("workload registered");
    (0..w.layers()).map(|l| w.layer_tasks(l).len()).sum()
}

/// A spec that SIGKILLs worker 1 a quarter of the way into the stream.
fn kill_spec(name: &str) -> ProcSpec {
    let step = (total_tasks(name) / 4).max(1);
    let mut spec = ProcSpec::parse(&format!("{WORKERS}:kill={step}@1")).expect("spec parses");
    spec.scale_milli = ((SCALE * 1000.0).round() as u32).max(1);
    spec
}

fn run_arm(
    name: &str,
    proc: Option<ProcSpec>,
    resilience: Option<PolicySpec>,
) -> (Vec<f64>, RunReport) {
    let rt = Runtime::builder().workers(2).build();
    let w = workloads::by_name(name, quantized_scale()).expect("workload registered");
    let params = RunParams { resilience, proc, ..RunParams::default() };
    run(&rt, w.as_ref(), &params).expect("run completes")
}

/// The acceptance invariant: every zoo workload under
/// `--resilience replay:3 --cluster proc:3` with a real SIGKILL mid-run
/// completes with survival 1.0 and a final wavefront bit-identical to
/// the fault-free single-runtime pool run.
#[test]
fn every_zoo_workload_survives_a_real_sigkill_under_replay() {
    pin_worker_bin();
    for name in ["stencil1d", "stencil2d", "forkjoin", "jacobi", "stream"] {
        let (reference, _) = run_arm(name, None, None);
        let (out, rep) =
            run_arm(name, Some(kill_spec(name)), Some(PolicySpec::Replay { n: 3 }));
        assert_eq!(rep.kills_applied, 1, "{name}: the scheduled SIGKILL fired");
        assert_eq!(rep.launch_errors, 0, "{name}: no poisoned slots");
        assert!(
            (rep.survival_rate() - 1.0).abs() < f64::EPSILON,
            "{name}: survival {}",
            rep.survival_rate()
        );
        assert_eq!(out, reference, "{name}: recovered output must be bit-identical");
        let dead: Vec<_> = rep.localities.iter().filter(|l| !l.alive_at_end).collect();
        assert_eq!(dead.len(), 1, "{name}: exactly one locality died");
        assert_eq!(dead[0].id, 1, "{name}: the scheduled victim died");
        // The verdict is reached by missed heartbeats, so detection
        // takes real wall-clock time — the number the simulated
        // substrate cannot produce.
        let detect = rep
            .detection_latency_secs
            .unwrap_or_else(|| panic!("{name}: SIGKILL arm must report detection latency"));
        assert!(detect > 0.0, "{name}: detection latency {detect}");
    }
}

/// Negative control: without resilience the run must still terminate —
/// dispatch to the corpse is rejected, in-flight tasks on it are drained
/// as errors at the verdict — and report survival < 1 rather than hang.
#[test]
fn sigkill_without_resilience_degrades_but_never_hangs() {
    pin_worker_bin();
    let (_, rep) = run_arm("stencil1d", Some(kill_spec("stencil1d")), None);
    assert_eq!(rep.kills_applied, 1);
    assert!(rep.launch_errors > 0, "the kill must poison at least one slot");
    assert!(
        rep.survival_rate() < 1.0,
        "survival {} should be degraded",
        rep.survival_rate()
    );
    let lost_or_rejected: usize = rep
        .localities
        .iter()
        .map(|l| l.tasks_lost + l.tasks_rejected)
        .sum();
    assert!(lost_or_rejected > 0, "the dead worker must account for the damage");
}

/// A worker that self-crashes (`std::process::abort` before executing
/// its N-th launch) is recovered exactly like a SIGKILL victim, but no
/// kill instant was ever marked, so detection latency is honestly
/// `None` instead of a fabricated number.
#[test]
fn self_crashing_worker_is_recovered_without_a_fake_detection_sample() {
    pin_worker_bin();
    let mut spec = ProcSpec::parse(&format!("{WORKERS}:crash=2@2")).expect("spec parses");
    spec.scale_milli = ((SCALE * 1000.0).round() as u32).max(1);
    let (reference, _) = run_arm("forkjoin", None, None);
    let (out, rep) = run_arm("forkjoin", Some(spec), Some(PolicySpec::Replay { n: 3 }));
    assert_eq!(rep.launch_errors, 0, "no poisoned slots");
    assert_eq!(out, reference, "recovered output must be bit-identical");
    assert!(
        rep.detection_latency_secs.is_none(),
        "self-crash arms have no SIGKILL mark to measure from: {:?}",
        rep.detection_latency_secs
    );
    assert!(!rep.localities[2].alive_at_end, "the self-crashed worker is dead");
}

/// The checkpoint decorator over the proc substrate: snapshots are
/// persisted (and mirrored onto workers), the kill triggers the eager
/// barrier + cone repair, and the run still converges bit-identically.
#[test]
fn checkpointed_run_survives_a_sigkill_with_snapshots_saved() {
    pin_worker_bin();
    let (reference, _) = run_arm("stencil1d", None, None);
    let (out, rep) = run_arm(
        "stencil1d",
        Some(kill_spec("stencil1d")),
        Some(PolicySpec::Checkpoint { every: 2, backend: SnapshotBackend::Auto }),
    );
    assert_eq!(rep.kills_applied, 1);
    assert_eq!(rep.launch_errors, 0, "no poisoned slots");
    assert!(rep.snapshots.saved > 0, "window barriers must persist snapshots");
    assert_eq!(out, reference, "repaired output must be bit-identical");
    assert!(rep.detection_latency_secs.unwrap_or(0.0) > 0.0);
}

/// Post-mortem forensics: a traced `proc:3` kill run must yield a merged
/// timeline that still contains the SIGKILLed worker's pre-death events
/// (recovered from its fsynced spool — the severed socket never
/// delivered them), the parent's growing heartbeat silence, the death
/// verdict, and the re-materialization of the in-flight task — and the
/// whole thing must export to a well-formed Chrome trace.
///
/// This is the one test in this binary that touches the global trace
/// session; concurrent tests can only *add* parent-side events, and the
/// worker-pid tracks asserted on are fed exclusively by this cluster's
/// spool.
#[test]
fn sigkill_post_mortem_trace_contains_the_victims_final_events() {
    use rhpx::trace::{self, chrome, EventKind, WORKER_PID_BASE};

    pin_worker_bin();
    const VICTIM: u32 = 1;
    let total = total_tasks("stencil1d");
    // Kill halfway through the stream so the victim has completed (and
    // fsynced) launches before dying, with work left to re-materialize.
    let mut spec =
        ProcSpec::parse(&format!("{WORKERS}:kill={}@{VICTIM}", (total / 2).max(1))).unwrap();
    spec.scale_milli = ((SCALE * 1000.0).round() as u32).max(1);
    let spool = std::env::temp_dir().join(format!("rhpx-postmortem-{}", std::process::id()));
    std::fs::create_dir_all(&spool).expect("create spool dir");
    spec.trace_spool = Some(spool.clone());

    trace::enable();
    let (_, rep) = run_arm("stencil1d", Some(spec), Some(PolicySpec::Replay { n: 3 }));
    let (tracks, dropped) = trace::take_tracks();
    trace::disable();
    let _ = std::fs::remove_dir_all(&spool);

    assert_eq!(rep.kills_applied, 1, "the scheduled SIGKILL fired");
    assert!(!rep.localities[VICTIM as usize].alive_at_end, "the victim died");
    assert!(
        rep.localities[VICTIM as usize].tasks_executed > 0,
        "the victim completed launches before the kill: {:?}",
        rep.localities
    );

    // The corpse's own story, recovered from the spool: every launch it
    // completed before the SIGKILL is on its track.
    let victim_events: Vec<_> = tracks
        .iter()
        .filter(|t| t.pid == WORKER_PID_BASE + VICTIM)
        .flat_map(|t| t.events.iter())
        .collect();
    assert!(
        victim_events.iter().any(|e| e.kind == EventKind::ExecBegin),
        "no pre-death events recovered for the victim; tracks: {:?}",
        tracks.iter().map(|t| (t.pid, t.name.clone(), t.events.len())).collect::<Vec<_>>()
    );
    // The worker flushes its spool *after* sending each reply, so a
    // SIGKILL can cost at most the events of the one launch whose reply
    // beat its flush to the wire.
    let begins = victim_events.iter().filter(|e| e.kind == EventKind::ExecBegin).count();
    assert!(
        begins + 1 >= rep.localities[VICTIM as usize].tasks_executed,
        "completed launches must leave spooled ExecBegins: {} begins vs {} executed",
        begins,
        rep.localities[VICTIM as usize].tasks_executed
    );

    // The parent's side of the death: silence grew (HeartbeatMiss), the
    // verdict fell on the victim, and the in-flight task re-materialized.
    let parent: Vec<&rhpx::trace::Event> = tracks
        .iter()
        .filter(|t| t.pid < WORKER_PID_BASE)
        .flat_map(|t| t.events.iter())
        .collect();
    let has = |kind: EventKind, pred: fn(&rhpx::trace::Event) -> bool| {
        parent.iter().any(|e| e.kind == kind && pred(e))
    };
    assert!(has(EventKind::HeartbeatMiss, |e| e.a == VICTIM as u64), "no heartbeat misses");
    assert!(has(EventKind::DeathVerdict, |e| e.a == VICTIM as u64), "no death verdict");
    assert!(
        has(EventKind::Rematerialize, |e| e.b == VICTIM as u64),
        "no re-materialization of the victim's in-flight work"
    );

    // And the merged timeline exports as a loadable Chrome trace with
    // the victim's process in it.
    let out = std::env::temp_dir().join(format!("rhpx-postmortem-{}.json", std::process::id()));
    let summary =
        chrome::export_tracks(out.to_str().unwrap(), &tracks, dropped).expect("export");
    assert!(summary.spans > 0, "{summary:?}");
    let text = std::fs::read_to_string(&out).expect("read trace");
    let _ = std::fs::remove_file(&out);
    let json = rhpx::metrics::JsonValue::parse(&text).expect("trace is valid JSON");
    let events = json.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents");
    let victim_pid = f64::from(WORKER_PID_BASE + VICTIM);
    assert!(
        events.iter().any(|e| matches!(
            e.get("pid"),
            Some(rhpx::metrics::JsonValue::Num(p)) if *p == victim_pid
        )),
        "the killed worker's process is absent from the exported trace"
    );
}

/// Fault-free proc run: pure distribution, no deaths, bit-identical
/// output — the sanity floor under all the kill arms above.
#[test]
fn fault_free_proc_run_matches_the_pool_bit_for_bit() {
    pin_worker_bin();
    let mut spec = ProcSpec::new(WORKERS);
    spec.scale_milli = ((SCALE * 1000.0).round() as u32).max(1);
    let (reference, pool_rep) = run_arm("jacobi", None, None);
    let (out, rep) = run_arm("jacobi", Some(spec), None);
    assert_eq!(out, reference);
    assert_eq!(rep.final_checksum, pool_rep.final_checksum);
    assert_eq!(rep.kills_applied, 0);
    assert!(rep.localities.iter().all(|l| l.alive_at_end));
    assert_eq!(rep.launcher, format!("proc({WORKERS})"));
    let executed: usize = rep.localities.iter().map(|l| l.tasks_executed).sum();
    assert_eq!(executed, rep.tasks, "every task ran on some worker");
}
