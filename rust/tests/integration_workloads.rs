//! Cross-workload acceptance matrix: every registered workload through
//! the unified fault model (the `rhpx run` surface), pinned to the
//! paper's recovery guarantees:
//!
//! * a cluster run with a scheduled locality kill, under `replay:3`,
//!   recovers **bit-identically** to the fault-free pool run — survival
//!   rate 1.0, zero poisoned slots, for every zoo member;
//! * silent data corruption (bit-flip SDC) is caught by checksum
//!   validation and replayed away — while the control arm with
//!   validation off lets the corruption leak into the final wavefront.

use rhpx::resilience::executor::PolicySpec;
use rhpx::stencil::ClusterSpec;
use rhpx::workloads::{self, RunParams};
use rhpx::Runtime;

fn rt() -> Runtime {
    Runtime::builder().workers(2).build()
}

fn cluster(spec: &str) -> ClusterSpec {
    let mut c = ClusterSpec::parse(spec).expect("cluster spec parses");
    c.workers_per_locality = 1;
    c
}

#[test]
fn every_workload_survives_a_locality_kill_bit_identically_under_replay() {
    let rt = rt();
    for (name, _) in workloads::WORKLOADS {
        let w = workloads::by_name(name, 1.0).expect("registry name resolves");

        // Fault-free pool reference.
        let (clean, clean_rep) =
            workloads::run(&rt, w.as_ref(), &RunParams::default()).unwrap();
        assert_eq!(clean_rep.launch_errors, 0, "{name} reference");

        // Cluster, locality 2 of 4 dies at task 10, replay:3 recovers.
        let params = RunParams {
            resilience: Some(PolicySpec::Replay { n: 3 }),
            cluster: Some(cluster("4:kill=10@2")),
            ..RunParams::default()
        };
        let (out, rep) = workloads::run(&rt, w.as_ref(), &params).unwrap();
        assert_eq!(rep.kills_applied, 1, "{name}: the kill must fire");
        assert_eq!(rep.launch_errors, 0, "{name}: replay must recover every slot");
        assert_eq!(rep.survival_rate(), 1.0, "{name}");
        assert!(rep.launcher.starts_with("cluster(4)"), "{name}: {}", rep.launcher);
        assert_eq!(out, clean, "{name}: recovery must be bit-identical to the pool run");
        assert_eq!(
            rep.final_checksum.to_bits(),
            clean_rep.final_checksum.to_bits(),
            "{name}: checksums must match bit-for-bit"
        );
        assert!(
            rep.tasks_reexecuted > 0,
            "{name}: surviving a kill costs re-executed work"
        );
    }
}

#[test]
fn every_workload_survives_a_locality_kill_under_replica_teams() {
    let rt = rt();
    for (name, _) in workloads::WORKLOADS {
        let w = workloads::by_name(name, 1.0).expect("registry name resolves");
        let (clean, clean_rep) =
            workloads::run(&rt, w.as_ref(), &RunParams::default()).unwrap();

        // First-result-wins teams of 3: the replica landing on the
        // corpse is rejected or lost, a sibling wins, losers retire.
        let params = RunParams {
            resilience: Some(PolicySpec::Team { n: 3 }),
            cluster: Some(cluster("4:kill=10@2")),
            ..RunParams::default()
        };
        let (out, rep) = workloads::run(&rt, w.as_ref(), &params).unwrap();
        assert_eq!(rep.kills_applied, 1, "{name}: the kill must fire");
        assert_eq!(rep.launch_errors, 0, "{name}: a team must always produce a winner");
        assert_eq!(rep.survival_rate(), 1.0, "{name}");
        assert_eq!(rep.mode, "exec_team(3)", "{name}");
        assert_eq!(out, clean, "{name}: team recovery must be bit-identical");
        assert_eq!(
            rep.final_checksum.to_bits(),
            clean_rep.final_checksum.to_bits(),
            "{name}: checksums must match bit-for-bit"
        );
        assert!(
            rep.tasks_reexecuted > 0,
            "{name}: replica fan-out is extra routed work by construction"
        );
    }
}

#[test]
fn every_workload_survives_a_kill_with_queue_drain_alone() {
    let rt = rt();
    for (name, _) in workloads::WORKLOADS {
        let w = workloads::by_name(name, 1.0).expect("registry name resolves");
        let (clean, clean_rep) =
            workloads::run(&rt, w.as_ref(), &RunParams::default()).unwrap();

        // No decorator at all: live-only placement + lineage
        // re-materialization of whatever the corpse had queued is the
        // entire recovery story.
        let params = RunParams {
            resilience: Some(PolicySpec::Drain),
            cluster: Some(cluster("4:kill=10@2")),
            ..RunParams::default()
        };
        let (out, rep) = workloads::run(&rt, w.as_ref(), &params).unwrap();
        assert_eq!(rep.kills_applied, 1, "{name}: the kill must fire");
        assert_eq!(
            rep.launch_errors, 0,
            "{name}: every queued task must re-materialize onto a survivor"
        );
        assert_eq!(rep.survival_rate(), 1.0, "{name}");
        assert_eq!(rep.mode, "exec_drain", "{name}");
        assert_eq!(out, clean, "{name}: drained recovery must be bit-identical");
        assert_eq!(
            rep.final_checksum.to_bits(),
            clean_rep.final_checksum.to_bits(),
            "{name}: checksums must match bit-for-bit"
        );
        // The corpse's lost tasks (if the kill caught any in-queue) are
        // fresh routings; the report's accounting must agree with the
        // per-locality counters either way.
        let lost: usize = rep.localities.iter().map(|l| l.tasks_lost).sum();
        let attempts: usize = rep
            .localities
            .iter()
            .map(|l| l.tasks_executed + l.tasks_rejected + l.tasks_lost)
            .sum();
        assert_eq!(
            rep.tasks_reexecuted,
            (attempts as u64).saturating_sub(rep.tasks as u64),
            "{name}: tasks_reexecuted must be derived from the three counters"
        );
        assert_eq!(
            attempts,
            rep.tasks + lost,
            "{name}: Σ(executed+rejected+lost) must equal routings (tasks + lost)"
        );
    }
}

#[test]
fn sdc_is_caught_with_validation_and_leaks_without_it() {
    let rt = rt();
    for (name, _) in workloads::WORKLOADS {
        let w = workloads::by_name(name, 1.0).expect("registry name resolves");
        let (clean, _) = workloads::run(&rt, w.as_ref(), &RunParams::default()).unwrap();

        // Control arm: validation off, heavy corruption — the bit-flips
        // flow through undetected and the final bytes diverge.
        let leaky = RunParams {
            sdc_rate: Some(0.5),
            validate: false,
            ..RunParams::default()
        };
        let (bad, bad_rep) = workloads::run(&rt, w.as_ref(), &leaky).unwrap();
        assert!(bad_rep.silent_corruptions > 0, "{name}: control must corrupt");
        assert_eq!(
            bad_rep.launch_errors, 0,
            "{name}: silent corruption is invisible without validation"
        );
        assert_ne!(bad, clean, "{name}: unvalidated corruption must leak");

        // Guarded arm: checksum validation detects every flip, replay
        // re-executes until a clean result lands — bit-identical output.
        let guarded = RunParams {
            resilience: Some(PolicySpec::Replay { n: 10 }),
            sdc_rate: Some(0.2),
            ..RunParams::default()
        };
        let (good, good_rep) = workloads::run(&rt, w.as_ref(), &guarded).unwrap();
        assert_eq!(good_rep.launch_errors, 0, "{name}: replay must outlast the SDC");
        assert_eq!(good, clean, "{name}: validated recovery must be bit-identical");
        assert!(
            good_rep.silent_corruptions > 0,
            "{name}: the guarded arm must actually have been attacked"
        );
    }
}

#[test]
fn checkpoint_recovers_every_workload_on_the_cluster_route() {
    let rt = rt();
    for (name, _) in workloads::WORKLOADS {
        let w = workloads::by_name(name, 1.0).expect("registry name resolves");
        let (clean, _) = workloads::run(&rt, w.as_ref(), &RunParams::default()).unwrap();

        let params = RunParams {
            resilience: Some(PolicySpec::parse("checkpoint:1").unwrap()),
            cluster: Some(cluster("4:kill=10@2")),
            ..RunParams::default()
        };
        let (out, rep) = workloads::run(&rt, w.as_ref(), &params).unwrap();
        assert_eq!(rep.kills_applied, 1, "{name}");
        assert_eq!(rep.launch_errors, 0, "{name}: checkpoint repair must recover");
        assert_eq!(out, clean, "{name}: restored run must be bit-identical");
        assert!(rep.snapshots.saved > 0, "{name}: snapshots must have been taken");
    }
}
