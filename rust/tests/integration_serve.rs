//! Integration: the `rhpx serve` daemon end to end — crash-restart with
//! exactly-once completion of all accepted work (the ISSUE's headline
//! invariant), and the framed protocol over a real TCP loopback socket.
//!
//! The crash is in-process: `Server::stop` + drop abandons the queue
//! exactly the way a killed daemon would, leaving the journal as the
//! only survivor. The counter algebra from the lineage-ledger work
//! (executions across both lives == accepted jobs, deduped == 0,
//! every id has exactly one cached outcome) is what "exactly once"
//! means here.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use rhpx::checkpoint::{MemorySnapshotStore, SnapshotStore};
use rhpx::serve::{
    BreakerConfig, Frame, JobSpec, ServeConfig, Server, StatusReport, SubmitResponse,
};

fn spec(job_id: u64, workload: &str) -> JobSpec {
    JobSpec {
        job_id,
        workload: workload.into(),
        policy: String::new(),
        scale_milli: 100,
        error_prob_pct: 0,
    }
}

fn manual_cfg() -> ServeConfig {
    ServeConfig {
        queue_capacity: 16,
        executors: 0, // manual stepping: we control exactly when jobs run
        workers: 2,
        retry_after_ms: 5,
        breaker: BreakerConfig::default(),
        seed: 0x1CE,
    }
}

/// Accept K jobs, run some, kill the daemon mid-flight, restart over the
/// same journal: every accepted job completes exactly once, nothing
/// un-acked sneaks in, nothing acked is dropped.
#[test]
fn crash_restart_completes_every_accepted_job_exactly_once() {
    const K: u64 = 8;
    const RAN_BEFORE_CRASH: u64 = 3;

    let journal: Arc<MemorySnapshotStore> = Arc::new(MemorySnapshotStore::new());
    let first = Server::start(manual_cfg(), Arc::clone(&journal) as Arc<dyn SnapshotStore>);

    let mut futures = Vec::new();
    for id in 1..=K {
        match first.submit(spec(id, if id % 2 == 0 { "forkjoin" } else { "stencil1d" })) {
            SubmitResponse::Accepted { future } => futures.push((id, future)),
            other => panic!("job {id} not accepted: {other:?}"),
        }
    }
    // A rejected submission must leave no journal trace to recover.
    assert!(matches!(
        first.submit(spec(99, "no-such-workload")),
        SubmitResponse::Rejected { .. }
    ));

    for _ in 0..RAN_BEFORE_CRASH {
        assert!(first.run_one());
    }
    let before = first.stats();
    assert_eq!(before.accepted, K);
    assert_eq!(before.executions, RAN_BEFORE_CRASH);
    assert_eq!(first.pending() as u64, K - RAN_BEFORE_CRASH);

    // The crash: stop mid-flight and drop. Clients waiting on unfinished
    // jobs observe the broken promise, never a silent hang.
    first.stop();
    for (id, future) in futures {
        let done_before_crash = id <= RAN_BEFORE_CRASH;
        assert_eq!(
            future.get().is_ok(),
            done_before_crash,
            "job {id}: finished jobs resolve, interrupted ones error"
        );
    }
    drop(first);

    // Restart from the journal alone.
    let second = Server::start(manual_cfg(), Arc::clone(&journal) as Arc<dyn SnapshotStore>);
    let after_recover = second.stats();
    assert_eq!(after_recover.recovered_done, RAN_BEFORE_CRASH);
    assert_eq!(after_recover.recovered_pending, K - RAN_BEFORE_CRASH);
    assert!(second.outcome(99).is_none(), "rejected job was never journaled");
    for id in 1..=RAN_BEFORE_CRASH {
        assert!(second.outcome(id).is_some(), "done job {id} answers from cache, not re-run");
    }

    while second.run_one() {}

    // Exactly once, by counter algebra across both lives.
    let after = second.stats();
    assert_eq!(
        before.executions + after.executions,
        K,
        "every accepted job ran exactly once across both incarnations"
    );
    assert_eq!(after.deduped, 0);
    for id in 1..=K {
        let outcome = second.outcome(id).unwrap_or_else(|| panic!("job {id} silently dropped"));
        assert!(outcome.ok, "job {id}: {outcome:?}");
    }

    // Resubmitting any completed id answers from the cache without
    // touching the executor.
    for id in 1..=K {
        assert!(matches!(
            second.submit(spec(id, "stencil1d")),
            SubmitResponse::AlreadyDone { .. }
        ));
    }
    assert_eq!(second.stats().executions, after.executions, "no re-execution on resubmit");
    second.stop();
}

/// A second crash while recovered jobs are still queued must not
/// double-run anything: Accepted journal records are idempotent.
#[test]
fn double_crash_still_exactly_once() {
    let journal: Arc<MemorySnapshotStore> = Arc::new(MemorySnapshotStore::new());

    let first = Server::start(manual_cfg(), Arc::clone(&journal) as Arc<dyn SnapshotStore>);
    for id in 1..=4 {
        assert!(matches!(first.submit(spec(id, "stream")), SubmitResponse::Accepted { .. }));
    }
    first.stop();
    drop(first);

    // Second life: recover, run one, crash again.
    let second = Server::start(manual_cfg(), Arc::clone(&journal) as Arc<dyn SnapshotStore>);
    assert_eq!(second.stats().recovered_pending, 4);
    assert!(second.run_one());
    second.stop();
    drop(second);

    // Third life: only the three unfinished jobs come back as pending.
    let third = Server::start(manual_cfg(), Arc::clone(&journal) as Arc<dyn SnapshotStore>);
    let stats = third.stats();
    assert_eq!(stats.recovered_done, 1);
    assert_eq!(stats.recovered_pending, 3);
    while third.run_one() {}
    assert_eq!(third.stats().executions, 3);
    for id in 1..=4 {
        assert!(third.outcome(id).expect("completed").ok);
    }
    third.stop();
}

/// Read frames off a blocking client socket until `want` frames arrived
/// or the deadline passes.
fn read_frames(stream: &mut TcpStream, want: usize) -> Vec<Frame> {
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut buf = Vec::new();
    let mut frames = Vec::new();
    let mut chunk = [0u8; 4096];
    while frames.len() < want {
        assert!(std::time::Instant::now() < deadline, "timed out: got {frames:?}");
        match stream.read(&mut chunk) {
            Ok(0) => panic!("server closed early: got {frames:?}"),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => panic!("read error: {e}"),
        }
        loop {
            match Frame::decode(&buf) {
                Ok((frame, consumed)) => {
                    buf.drain(..consumed);
                    frames.push(frame);
                }
                Err(rhpx::serve::FrameError::Truncated { .. }) => break,
                Err(e) => panic!("client-side decode error: {e}"),
            }
        }
    }
    frames
}

/// The full wire path: TCP submit → Ack → Result, Status query, and a
/// typed Reject for garbage bytes.
#[test]
fn tcp_loopback_submit_ack_result_and_status() {
    let cfg = ServeConfig { executors: 2, workers: 2, ..ServeConfig::default() };
    let server = Server::start(cfg, Arc::new(MemorySnapshotStore::new()));
    let (addr, _accept) = server.listen("127.0.0.1:0").expect("bind loopback");

    let mut client = TcpStream::connect(addr).expect("connect");
    client.set_read_timeout(Some(Duration::from_millis(100))).unwrap();

    // Two submissions in one write: framing must split them.
    let mut bytes = Frame::Submit(spec(1, "stencil1d")).encode();
    bytes.extend_from_slice(&Frame::Submit(spec(2, "forkjoin")).encode());
    client.write_all(&bytes).unwrap();

    // 2 Acks now, 2 Results as the jobs finish.
    let frames = read_frames(&mut client, 4);
    let acks: Vec<u64> = frames
        .iter()
        .filter_map(|f| match f {
            Frame::Ack { job_id } => Some(*job_id),
            _ => None,
        })
        .collect();
    let results: Vec<(u64, bool)> = frames
        .iter()
        .filter_map(|f| match f {
            Frame::Result { job_id, ok, .. } => Some((*job_id, *ok)),
            _ => None,
        })
        .collect();
    assert_eq!({ let mut a = acks.clone(); a.sort_unstable(); a }, vec![1, 2]);
    let mut done = results.clone();
    done.sort_unstable();
    assert_eq!(done, vec![(1, true), (2, true)]);

    // Status over the same connection.
    client.write_all(&Frame::Status(StatusReport::default()).encode()).unwrap();
    let frames = read_frames(&mut client, 1);
    let Frame::Status(s) = &frames[0] else { panic!("expected status, got {frames:?}") };
    assert_eq!(s.submitted, 2);
    assert_eq!(s.accepted, 2);
    assert_eq!(s.completed, 2);

    // The Status frame now carries observability payload too: latency
    // quantiles measured from the two real executions (monotone by
    // construction) and the named-counter snapshot whose `/serve/...`
    // entries must agree with the headline fields on the same frame.
    assert!(s.p50_us >= 1, "two real jobs ran; the p50 cannot be zero: {s:?}");
    assert!(s.p50_us <= s.p99_us && s.p99_us <= s.p999_us, "{s:?}");
    let counter = |name: &str| {
        s.counters
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("missing counter {name}: {:?}", s.counters))
            .1
    };
    assert_eq!(counter("/serve/count/submitted"), s.submitted);
    assert_eq!(counter("/serve/count/accepted"), s.accepted);
    assert_eq!(counter("/serve/count/completed"), s.completed);
    assert_eq!(counter("/serve/count/executions"), 2);
    assert_eq!(counter("/serve/count/deduped"), 0);

    // Garbage: the server answers with a typed protocol Reject, then
    // hangs up — it never panics and never acts on a corrupt frame.
    let mut second = TcpStream::connect(addr).expect("connect");
    second.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    second.write_all(b"zzzz-not-a-frame-zzzz").unwrap();
    let frames = read_frames(&mut second, 1);
    match &frames[0] {
        Frame::Reject { reason, .. } => assert!(reason.contains("protocol error"), "{reason}"),
        other => panic!("expected protocol reject, got {other:?}"),
    }

    server.stop();
}
