//! Integration: the AOT JAX/Pallas artifact executed through PJRT from
//! Rust must agree with the native Rust kernel — the cross-layer
//! correctness contract (L1/L2 ↔ L3).
//!
//! Requires `make artifacts` to have run (the Makefile `test` target
//! guarantees ordering).

use std::path::Path;

use rhpx::runtime::{execute_f64, warmup, ArtifactStore};
use rhpx::stencil::{kernel, Backend, Mode, StencilParams};
use rhpx::Runtime;

fn store() -> ArtifactStore {
    ArtifactStore::open(Path::new("artifacts"))
        .expect("artifacts/ missing — run `make artifacts` first")
}

#[test]
fn artifact_store_finds_default_configs() {
    let s = store();
    assert!(s.stencil_path(64, 4).is_ok());
    assert!(s.stencil_path(1000, 16).is_ok());
    assert!(s.stencil_path(16000, 128).is_ok());
    assert!(s.stencil_path(8000, 128).is_ok());
}

#[test]
fn pjrt_matches_native_kernel_tiny() {
    let s = store();
    let path = s.stencil_path(64, 4).unwrap();
    let nx = 64;
    let steps = 4;
    let ext: Vec<f64> = (0..nx + 2 * steps)
        .map(|i| (i as f64 * 0.37).sin())
        .collect();
    for c in [0.0, 0.5, 0.9, 1.0] {
        let outs = execute_f64(path, &[&ext, &[c]]).unwrap();
        assert_eq!(outs.len(), 2, "expected (out, checksum) tuple");
        assert_eq!(outs[0].len(), nx);
        assert_eq!(outs[1].len(), 1);
        let native = kernel::lax_wendroff_multistep(&ext, steps, c);
        for (a, b) in outs[0].iter().zip(native.iter()) {
            assert!((a - b).abs() < 1e-11, "c={c}: {a} vs {b}");
        }
        let ck_native = kernel::checksum(&native);
        assert!((outs[1][0] - ck_native).abs() < 1e-9);
    }
}

#[test]
fn pjrt_executable_cache_reuses_compilation() {
    let s = store();
    let path = s.stencil_path(64, 4).unwrap();
    warmup(path).unwrap();
    let n_before = rhpx::runtime::cached_executables();
    let ext = vec![0.5f64; 72];
    for _ in 0..10 {
        execute_f64(path, &[&ext, &[0.9]]).unwrap();
    }
    assert_eq!(rhpx::runtime::cached_executables(), n_before);
}

#[test]
fn stencil_run_on_pjrt_backend_matches_native() {
    let s = store();
    let rt = Runtime::builder().workers(2).build();
    let base = StencilParams {
        n_sub: 4,
        nx: 64,
        iterations: 3,
        steps: 4,
        courant: 1.0,
        ..StencilParams::tiny()
    };
    let (native_out, _) = rhpx::stencil::run(&rt, &base).unwrap();
    let pjrt = StencilParams {
        backend: Backend::pjrt(&s, 64, 4).unwrap(),
        ..base
    };
    let (pjrt_out, rep) = rhpx::stencil::run(&rt, &pjrt).unwrap();
    assert_eq!(rep.launch_errors, 0);
    assert_eq!(native_out.len(), pjrt_out.len());
    for (a, b) in native_out.iter().zip(pjrt_out.iter()) {
        assert!((a - b).abs() < 1e-10, "{a} vs {b}");
    }
}

#[test]
fn stencil_resilient_pjrt_run_with_failures() {
    let s = store();
    let rt = Runtime::builder().workers(2).build();
    let params = StencilParams {
        n_sub: 4,
        nx: 64,
        iterations: 3,
        steps: 4,
        courant: 1.0,
        mode: Mode::Replay { n: 5 },
        error_rate: Some(1.0), // P ≈ 0.37 per task
        backend: Backend::pjrt(&s, 64, 4).unwrap(),
        ..StencilParams::tiny()
    };
    let (_, rep) = rhpx::stencil::run(&rt, &params).unwrap();
    assert!(rep.failures_injected > 0);
    assert_eq!(rep.launch_errors, 0, "replay must absorb failures");
}
