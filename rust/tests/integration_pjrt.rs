//! Integration: the AOT JAX/Pallas artifact executed through PJRT from
//! Rust must agree with the native Rust kernel — the cross-layer
//! correctness contract (L1/L2 ↔ L3).
//!
//! Requires the `pjrt` cargo feature (vendored `xla` crate) *and*
//! `make artifacts` to have run. On a bare checkout — no PJRT engine, no
//! `artifacts/` — every test here skips cleanly (early return with a
//! note on stderr) so tier-1 `cargo test -q` stays green without Python.

use std::path::Path;

use rhpx::runtime::{execute_f64, pjrt_available, warmup, ArtifactStore};
use rhpx::stencil::{kernel, Backend, Mode, StencilParams};
use rhpx::Runtime;

/// The artifact store, or `None` (with a skip note) when this build or
/// checkout cannot execute PJRT artifacts.
fn store() -> Option<ArtifactStore> {
    if !pjrt_available() {
        eprintln!("skipping PJRT test: engine not compiled in (see rust/Cargo.toml)");
        return None;
    }
    match ArtifactStore::open(Path::new("artifacts")) {
        Ok(s) if !s.is_empty() => Some(s),
        _ => {
            eprintln!("skipping PJRT test: artifacts/ missing — run `make artifacts` first");
            None
        }
    }
}

#[test]
fn artifact_store_finds_default_configs() {
    let Some(s) = store() else { return };
    assert!(s.stencil_path(64, 4).is_ok());
    assert!(s.stencil_path(1000, 16).is_ok());
    assert!(s.stencil_path(16000, 128).is_ok());
    assert!(s.stencil_path(8000, 128).is_ok());
}

#[test]
fn pjrt_matches_native_kernel_tiny() {
    let Some(s) = store() else { return };
    let path = s.stencil_path(64, 4).unwrap();
    let nx = 64;
    let steps = 4;
    let ext: Vec<f64> = (0..nx + 2 * steps)
        .map(|i| (i as f64 * 0.37).sin())
        .collect();
    for c in [0.0, 0.5, 0.9, 1.0] {
        let outs = execute_f64(path, &[&ext, &[c]]).unwrap();
        assert_eq!(outs.len(), 2, "expected (out, checksum) tuple");
        assert_eq!(outs[0].len(), nx);
        assert_eq!(outs[1].len(), 1);
        let native = kernel::lax_wendroff_multistep(&ext, steps, c);
        for (a, b) in outs[0].iter().zip(native.iter()) {
            assert!((a - b).abs() < 1e-11, "c={c}: {a} vs {b}");
        }
        let ck_native = kernel::checksum(&native);
        assert!((outs[1][0] - ck_native).abs() < 1e-9);
    }
}

#[test]
fn pjrt_executable_cache_reuses_compilation() {
    let Some(s) = store() else { return };
    let path = s.stencil_path(64, 4).unwrap();
    warmup(path).unwrap();
    let n_before = rhpx::runtime::cached_executables();
    let ext = vec![0.5f64; 72];
    for _ in 0..10 {
        execute_f64(path, &[&ext, &[0.9]]).unwrap();
    }
    assert_eq!(rhpx::runtime::cached_executables(), n_before);
}

#[test]
fn stencil_run_on_pjrt_backend_matches_native() {
    let Some(s) = store() else { return };
    let rt = Runtime::builder().workers(2).build();
    let base = StencilParams {
        n_sub: 4,
        nx: 64,
        iterations: 3,
        steps: 4,
        courant: 1.0,
        ..StencilParams::tiny()
    };
    let (native_out, _) = rhpx::stencil::run(&rt, &base).unwrap();
    let pjrt = StencilParams {
        backend: Backend::pjrt(&s, 64, 4).unwrap(),
        ..base
    };
    let (pjrt_out, rep) = rhpx::stencil::run(&rt, &pjrt).unwrap();
    assert_eq!(rep.launch_errors, 0);
    assert_eq!(native_out.len(), pjrt_out.len());
    for (a, b) in native_out.iter().zip(pjrt_out.iter()) {
        assert!((a - b).abs() < 1e-10, "{a} vs {b}");
    }
}

#[test]
fn stencil_resilient_pjrt_run_with_failures() {
    let Some(s) = store() else { return };
    let rt = Runtime::builder().workers(2).build();
    let params = StencilParams {
        n_sub: 4,
        nx: 64,
        iterations: 3,
        steps: 4,
        courant: 1.0,
        mode: Mode::Replay { n: 5 },
        error_rate: Some(1.0), // P ≈ 0.37 per task
        backend: Backend::pjrt(&s, 64, 4).unwrap(),
        ..StencilParams::tiny()
    };
    let (_, rep) = rhpx::stencil::run(&rt, &params).unwrap();
    assert!(rep.failures_injected > 0);
    assert_eq!(rep.launch_errors, 0, "replay must absorb failures");
}

#[test]
fn bare_checkout_skips_cleanly_without_engine() {
    // The inverse contract: when PJRT is NOT available, the probe used by
    // every test above must say so instead of panicking.
    if pjrt_available() {
        return;
    }
    assert!(store().is_none());
    // And direct execution reports a descriptive runtime error.
    let err = execute_f64(Path::new("artifacts/whatever.hlo.txt"), &[&[0.0]]).unwrap_err();
    assert!(err.to_string().contains("PJRT"), "{err}");
}
