//! Contended stress tests for the lock-free hot paths: the Chase–Lev
//! deque under a steal storm, the atomic-countdown `when_all_results`
//! join at 100k dependencies resolved from multiple threads, and the
//! promise-set vs. continuation-attach race. All sized to stay well
//! inside `cargo test -q` time budgets (each test is < ~2s on a laptop
//! core).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use rhpx::scheduler::{Job, WorkQueue};
use rhpx::{async_, when_all_results, Promise, Runtime, TaskResult};

/// Steal storm directly on the deque: one owner thread pushes and pops,
/// several thief threads steal concurrently, and every job must run
/// exactly once (per-job once-flags catch both losses and duplicates).
#[test]
fn deque_steal_storm_runs_every_job_exactly_once() {
    const JOBS: usize = 50_000;
    const THIEVES: usize = 4;

    let q = Arc::new(WorkQueue::new());
    let ran: Arc<Vec<AtomicUsize>> =
        Arc::new((0..JOBS).map(|_| AtomicUsize::new(0)).collect());
    let executed = Arc::new(AtomicUsize::new(0));
    let done_pushing = Arc::new(AtomicBool::new(false));

    let thieves: Vec<_> = (0..THIEVES)
        .map(|_| {
            let q = Arc::clone(&q);
            let executed = Arc::clone(&executed);
            let done = Arc::clone(&done_pushing);
            std::thread::spawn(move || {
                loop {
                    match q.steal() {
                        Some(job) => {
                            job();
                            executed.fetch_add(1, Ordering::SeqCst);
                        }
                        None => {
                            if done.load(Ordering::SeqCst) && q.is_empty() {
                                break;
                            }
                            std::hint::spin_loop();
                        }
                    }
                }
            })
        })
        .collect();

    // Owner: push everything, interleaving pops (LIFO side under fire).
    // SAFETY (owner-side calls): this test thread is the deque's only
    // owner; the spawned threads exclusively use the safe `steal` side.
    for i in 0..JOBS {
        let ran = Arc::clone(&ran);
        let job: Job = Box::new(move || {
            let prev = ran[i].fetch_add(1, Ordering::SeqCst);
            assert_eq!(prev, 0, "job {i} ran twice");
        });
        unsafe { q.push(job) };
        if i % 3 == 0 {
            if let Some(job) = unsafe { q.pop() } {
                job();
                executed.fetch_add(1, Ordering::SeqCst);
            }
        }
    }
    // Owner drains what the thieves leave behind.
    while let Some(job) = unsafe { q.pop() } {
        job();
        executed.fetch_add(1, Ordering::SeqCst);
    }
    done_pushing.store(true, Ordering::SeqCst);
    for t in thieves {
        t.join().unwrap();
    }
    // Late arrivals between the owner's last pop and the flag: none can
    // exist (owner pushed everything before the flag), but drain anyway.
    while let Some(job) = unsafe { q.pop() } {
        job();
        executed.fetch_add(1, Ordering::SeqCst);
    }

    assert_eq!(executed.load(Ordering::SeqCst), JOBS);
    for (i, flag) in ran.iter().enumerate() {
        let times = flag.load(Ordering::SeqCst);
        assert_eq!(times, 1, "job {i} ran {times} times");
    }
}

/// The scheduler end-to-end under multi-threaded external submission:
/// external threads hammer the lock-free injector while the workers
/// drain through their deques; every task runs exactly once.
#[test]
fn pool_survives_multi_threaded_submission_storm() {
    const SUBMITTERS: usize = 4;
    const PER_THREAD: usize = 10_000;

    let rt = Runtime::builder().workers(3).build();
    let count = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..SUBMITTERS)
        .map(|_| {
            let rt = rt.clone();
            let count = Arc::clone(&count);
            std::thread::spawn(move || {
                let futs: Vec<_> = (0..PER_THREAD)
                    .map(|_| {
                        let count = Arc::clone(&count);
                        async_(&rt, move || {
                            count.fetch_add(1, Ordering::Relaxed);
                            1i32
                        })
                    })
                    .collect();
                for f in futs {
                    assert_eq!(f.get(), Ok(1));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    rt.wait_idle();
    assert_eq!(count.load(Ordering::Relaxed), SUBMITTERS * PER_THREAD);
    let stats = rt.stats();
    assert_eq!(stats.completed, stats.spawned);
    assert!(stats.spawned >= (SUBMITTERS * PER_THREAD) as u64);
}

/// `when_all_results` with 100k dependencies resolved from multiple
/// threads: the atomic countdown must deliver every slot exactly once,
/// in index order, with the join firing exactly when the last dependency
/// lands — and zero mutex acquisitions on the completion path.
#[test]
fn when_all_100k_dependencies_resolved_from_multiple_threads() {
    const DEPS: usize = 100_000;
    const SETTERS: usize = 4;

    let mut promises = Vec::with_capacity(DEPS);
    let mut futs = Vec::with_capacity(DEPS);
    for _ in 0..DEPS {
        let (p, f) = Promise::<usize>::new();
        promises.push(p);
        futs.push(f);
    }
    let all = when_all_results(futs);
    assert!(!all.is_ready());

    // Split the promises across setter threads; each resolves its slice
    // with its dependency's index.
    let mut slices: Vec<Vec<(usize, Promise<usize>)>> =
        (0..SETTERS).map(|_| Vec::with_capacity(DEPS / SETTERS + 1)).collect();
    for (i, p) in promises.into_iter().enumerate() {
        slices[i % SETTERS].push((i, p));
    }
    let setters: Vec<_> = slices
        .into_iter()
        .map(|slice| {
            std::thread::spawn(move || {
                for (i, p) in slice {
                    p.set_value(i);
                }
            })
        })
        .collect();
    for s in setters {
        s.join().unwrap();
    }

    let results: Vec<TaskResult<usize>> = all.get().expect("join never fails");
    assert_eq!(results.len(), DEPS);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(*r, Ok(i), "slot {i} holds the wrong dependency");
    }
}

/// Promise-set vs. continuation-attach race: one thread sets the value
/// while another attaches a continuation. Whatever the interleaving
/// (pending attach, inline attach during the NOTIFY phase, inline attach
/// after READY), the continuation must fire exactly once with the value.
#[test]
fn promise_set_vs_continuation_attach_race() {
    const ROUNDS: usize = 2_000;
    let fired = Arc::new(AtomicUsize::new(0));
    for round in 0..ROUNDS {
        let (p, f) = Promise::<usize>::new();
        let fired = Arc::clone(&fired);
        let f2 = f.clone();
        std::thread::scope(|s| {
            s.spawn(move || {
                p.set_value(round);
            });
            s.spawn(move || {
                f2.on_ready(move |r| {
                    assert_eq!(*r, Ok(round));
                    fired.fetch_add(1, Ordering::SeqCst);
                });
            });
        });
        assert_eq!(f.get_copy(), Ok(round));
    }
    assert_eq!(fired.load(Ordering::SeqCst), ROUNDS, "every continuation fires exactly once");
}

/// AGAS migration under concurrent lookup: a migrator thread re-homes
/// one object through a fixed schedule (migration k lands on locality
/// k % HOMES) while reader threads hammer `locate_with_generation`.
/// Invariants: the generation each reader observes is monotonically
/// non-decreasing, and the (home, generation) pair is always
/// *consistent* — the home matches the schedule for that exact
/// generation, so no reader ever sees a new home with a stale
/// generation (or vice versa). The object stays resolvable throughout.
#[test]
fn agas_migrate_under_concurrent_lookup_has_no_stale_home_reads() {
    use rhpx::agas::{Agas, LocalityId};

    const HOMES: usize = 8;
    const MIGRATIONS: u64 = 5_000;
    const READERS: usize = 4;

    let agas = Agas::new();
    // Initial home = schedule(0), so home == LocalityId(gen % HOMES)
    // holds from generation 0 onward.
    let gid = agas.register(LocalityId(0), vec![42i64]);
    let done = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..READERS)
        .map(|_| {
            let agas = agas.clone();
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut last_gen = 0u64;
                let mut observed = 0u64;
                loop {
                    // Sample the done flag *before* reading, so the final
                    // pass still observes (and checks) the end state.
                    let finished = done.load(Ordering::Acquire);
                    let (home, generation) =
                        agas.locate_with_generation(gid).expect("object never unregistered");
                    assert!(
                        generation >= last_gen,
                        "generation went backwards: {generation} < {last_gen}"
                    );
                    assert_eq!(
                        home,
                        LocalityId((generation % HOMES as u64) as usize),
                        "stale-home read: home {home:?} does not match generation {generation}"
                    );
                    assert_eq!(*agas.resolve::<Vec<i64>>(gid).unwrap(), vec![42]);
                    last_gen = generation;
                    observed += 1;
                    if finished {
                        break;
                    }
                }
                observed
            })
        })
        .collect();

    for k in 1..=MIGRATIONS {
        agas.migrate(gid, LocalityId((k % HOMES as u64) as usize));
    }
    done.store(true, Ordering::Release);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader made no observations");
    }
    assert_eq!(agas.generation(gid), Some(MIGRATIONS));
    assert_eq!(agas.migrations(), MIGRATIONS);
    assert_eq!(agas.locate(gid), Some(LocalityId((MIGRATIONS % HOMES as u64) as usize)));
}

/// Concurrent `get` (helping/parking) against a setter thread, plus
/// continuation chains racing the set — the end-to-end shape the
/// dataflow hot path exercises.
#[test]
fn concurrent_get_and_then_chains_under_race() {
    const ROUNDS: usize = 500;
    for round in 0..ROUNDS {
        let (p, f) = Promise::<i64>::new();
        let chained = f.then(|r| r.clone().map(|v| v + 1));
        let waiter = {
            let f = f.clone();
            std::thread::spawn(move || f.get_copy())
        };
        p.set_value(round as i64);
        assert_eq!(waiter.join().unwrap(), Ok(round as i64));
        assert_eq!(chained.get(), Ok(round as i64 + 1));
    }
}
