//! Integration: distributed resiliency over simulated localities,
//! including the §V-B acceptance scenario — the dataflow stencil
//! surviving a scheduled locality death with zero poisoned subdomains
//! and a checksum identical to the single-runtime run.

use std::sync::Arc;

use rhpx::agas::LocalityId;
use rhpx::distributed::{
    async_replay_distributed, async_replicate_distributed, Cluster, DistBody, NetworkConfig,
};
use rhpx::resilience::vote_majority;
use rhpx::stencil::{self, ClusterSpec, ExecPolicy, StencilParams};
use rhpx::{Runtime, TaskError, TaskResult};

#[test]
fn cluster_with_latency_completes_many_tasks() {
    let cl = Cluster::new(3, 1, NetworkConfig { latency_us: 10 });
    let futs: Vec<_> = (0..30)
        .map(|i| cl.run_on(LocalityId(i % 3), move |_| Ok::<_, TaskError>(i)))
        .collect();
    let sum: usize = futs.into_iter().map(|f| f.get().unwrap()).sum();
    assert_eq!(sum, (0..30).sum::<usize>());
}

#[test]
fn replay_migrates_work_off_failed_node_mid_run() {
    let cl = Cluster::new(3, 1, NetworkConfig::default());
    // Phase 1: all localities healthy.
    let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
    for _ in 0..6 {
        assert!(async_replay_distributed(&cl, 3, Arc::clone(&body)).get().is_ok());
    }
    // Phase 2: locality 1 dies; every launch must still succeed by
    // walking the ring.
    cl.kill(LocalityId(1));
    for _ in 0..12 {
        let got = async_replay_distributed(&cl, 3, Arc::clone(&body)).get().unwrap();
        assert_ne!(got, 1, "task reported execution on a dead locality");
    }
    // Phase 3: locality rejoins.
    cl.revive(LocalityId(1));
    let mut saw_one = false;
    for _ in 0..12 {
        if async_replay_distributed(&cl, 3, Arc::clone(&body)).get().unwrap() == 1 {
            saw_one = true;
        }
    }
    assert!(saw_one, "revived locality never received work");
}

#[test]
fn distributed_vote_with_node_specific_corruption() {
    // Locality 0 computes garbage (a "bad node"); majority vote over
    // replicas on distinct localities masks it.
    let cl = Cluster::new(3, 1, NetworkConfig::default());
    let body: DistBody<i64> = Arc::new(|loc| {
        if loc.id().0 == 0 {
            Ok(-999) // silent corruption on node 0
        } else {
            Ok(42)
        }
    });
    for _ in 0..6 {
        let f =
            async_replicate_distributed(&cl, 3, Some(Arc::new(vote_majority)), Arc::clone(&body));
        assert_eq!(f.get(), Ok(42));
    }
}

#[test]
fn distributed_state_via_agas() {
    // A counter object registered in AGAS, updated from tasks on
    // different localities.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let cl = Cluster::new(2, 1, NetworkConfig::default());
    let gid = cl.agas().register(LocalityId(0), AtomicUsize::new(0));
    let futs: Vec<_> = (0..10)
        .map(|i| {
            let agas = cl.agas().clone();
            cl.run_on(LocalityId(i % 2), move |_| -> TaskResult<()> {
                agas.resolve::<AtomicUsize>(gid)
                    .ok_or(TaskError::App("missing".into()))?
                    .fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
        })
        .collect();
    for f in futs {
        f.get().unwrap();
    }
    assert_eq!(
        cl.agas().resolve::<AtomicUsize>(gid).unwrap().load(Ordering::SeqCst),
        10
    );
    // Migrate the object and keep using it.
    cl.agas().migrate(gid, LocalityId(1));
    assert_eq!(cl.agas().locate(gid), Some(LocalityId(1)));
}

/// The acceptance scenario end-to-end: `rhpx stencil --cluster
/// 4:kill=10@2 --resilience replay:3` completes with zero poisoned
/// subdomains and the single-runtime checksum, while the same
/// configuration without `--resilience` reports poisoned subdomains.
#[test]
fn cluster_stencil_survives_scheduled_locality_death() {
    let rt = Runtime::builder().workers(2).build();
    let base = StencilParams::tiny();
    let (pool_out, pool_rep) = stencil::run(&rt, &base).unwrap();

    // Recovered arm: replay(3) over the 4-locality cluster.
    let recovered = StencilParams {
        cluster: Some(ClusterSpec::parse("4:kill=10@2").unwrap()),
        resilience: Some(ExecPolicy::Replay { n: 3 }),
        ..base.clone()
    };
    let (out, rep) = stencil::run(&rt, &recovered).unwrap();
    assert_eq!(rep.kills_applied, 1, "the scheduled kill must fire");
    assert!(!rep.localities[2].alive_at_end, "locality 2 must stay dead");
    assert_eq!(rep.launch_errors, 0, "zero poisoned subdomains");
    assert_eq!(rep.survival_rate(), 1.0);
    assert_eq!(out, pool_out, "recovered run must match the single-runtime gather");
    assert_eq!(rep.final_checksum, pool_rep.final_checksum);

    // Control arm: same fault, no resilience — the failure cone must
    // reach the final wavefront.
    let control = StencilParams {
        cluster: Some(ClusterSpec::parse("4:kill=10@2").unwrap()),
        ..base.clone()
    };
    let (_, rep) = stencil::run(&rt, &control).unwrap();
    assert!(rep.launch_errors > 0, "unrecovered kill must poison subdomains");
    assert!(rep.survival_rate() < 1.0);
    assert!(rep.localities[2].tasks_rejected > 0);
}

/// Adaptive replication width over the cluster: the quiet-state fan-out
/// already spans two distinct localities, so the scheduled death is
/// masked without any retry, and the observed failures drive the policy.
#[test]
fn cluster_stencil_adaptive_replicate_masks_locality_death() {
    let rt = Runtime::builder().workers(2).build();
    let base = StencilParams::tiny();
    let (pool_out, _) = stencil::run(&rt, &base).unwrap();
    let params = StencilParams {
        cluster: Some(ClusterSpec::parse("4:kill=10@2").unwrap()),
        resilience: Some(ExecPolicy::AdaptiveReplicate { ceiling: 4 }),
        ..base
    };
    let (out, rep) = stencil::run(&rt, &params).unwrap();
    assert_eq!(rep.launch_errors, 0);
    assert_eq!(rep.mode, "exec_adaptive_replicate(max 4)");
    assert_eq!(out, pool_out);
}

/// With no fault schedule, the cluster route is numerically transparent:
/// same checksum as the pool route, every locality did work.
#[test]
fn cluster_stencil_equivalent_to_pool_without_faults() {
    let rt = Runtime::builder().workers(2).build();
    let base = StencilParams::tiny();
    let (pool_out, pool_rep) = stencil::run(&rt, &base).unwrap();
    let params = StencilParams {
        cluster: Some(ClusterSpec::parse("3").unwrap()),
        ..base
    };
    let (out, rep) = stencil::run(&rt, &params).unwrap();
    assert_eq!(out, pool_out);
    assert_eq!(rep.final_checksum, pool_rep.final_checksum);
    assert_eq!(rep.launch_errors, 0);
    assert_eq!(rep.kills_applied, 0);
    assert_eq!(rep.localities.len(), 3);
    assert!(rep.localities.iter().all(|l| l.tasks_executed > 0));
}

/// The lineage-ledger accounting invariant, pinned directly against the
/// tracked submission protocol: build a backlog on one locality (its
/// single worker is parked on a gate, so at most one task can have
/// claimed its epoch), kill it mid-drain, and check that
///
/// * every queued-but-unexecuted task is counted `lost` and
///   re-materializes onto a survivor (all futures still resolve, each
///   body runs exactly once);
/// * the three per-locality counters sum to the number of *routings* —
///   logical submissions plus one fresh routing per lost task.
#[test]
fn killed_backlog_is_counted_lost_and_the_three_counters_sum_to_routings() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Condvar, Mutex};

    const TASKS: usize = 8;
    let cl = Cluster::new(3, 1, NetworkConfig::default());
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let runs: Arc<Vec<AtomicUsize>> =
        Arc::new((0..TASKS).map(|_| AtomicUsize::new(0)).collect());

    // Pin every submission on locality 1. Its worker claims at most one
    // epoch before parking on the gate; the rest sit in the ledger.
    let futs: Vec<_> = (0..TASKS)
        .map(|i| {
            let gate = Arc::clone(&gate);
            let runs = Arc::clone(&runs);
            cl.run_on_resilient(
                LocalityId(1),
                None,
                Arc::new(move |_loc: &rhpx::distributed::Locality| -> TaskResult<usize> {
                    let (open, cv) = &*gate;
                    let mut open = open.lock().unwrap();
                    while !*open {
                        open = cv.wait(open).unwrap();
                    }
                    drop(open);
                    runs[i].fetch_add(1, Ordering::SeqCst);
                    Ok(i)
                }),
            )
        })
        .collect();

    // Kill the backlogged locality: the drain must find the unclaimed
    // entries (at least TASKS - 1 of them) and relaunch them inline on
    // the survivors before `kill` returns.
    cl.kill(LocalityId(1));
    let lost = cl.locality(LocalityId(1)).tasks_lost();
    assert!(lost >= TASKS - 1, "backlog must be drained as lost, got {lost}");
    assert_eq!(
        cl.drain_latency_secs().len(),
        1,
        "one kill with pending work -> one drain-latency sample"
    );

    // Open the gate; every future must now resolve with its own value,
    // whether its task ran on the corpse (claimed pre-kill) or was
    // re-materialized onto a survivor.
    {
        let (open, cv) = &*gate;
        *open.lock().unwrap() = true;
        cv.notify_all();
    }
    for (i, f) in futs.into_iter().enumerate() {
        assert_eq!(f.get(), Ok(i), "task {i} must survive the kill");
    }
    for (i, r) in runs.iter().enumerate() {
        assert_eq!(r.load(Ordering::SeqCst), 1, "task {i} must run exactly once");
    }

    // Counter algebra over the whole cluster: nothing was rejected (the
    // pin target was alive at submit, relaunches land on survivors), so
    // executed == logical submissions, and the three counters sum to the
    // routings — submissions plus one fresh routing per lost task.
    let executed: usize = (0..3).map(|i| cl.locality(LocalityId(i)).tasks_executed()).sum();
    let rejected: usize = (0..3).map(|i| cl.locality(LocalityId(i)).tasks_rejected()).sum();
    let total_lost: usize = (0..3).map(|i| cl.locality(LocalityId(i)).tasks_lost()).sum();
    assert_eq!(executed, TASKS, "every logical submission executes exactly once");
    assert_eq!(rejected, 0, "no routing in this schedule targets a known corpse");
    assert_eq!(
        executed + rejected + total_lost,
        TASKS + total_lost,
        "sum(executed, rejected, lost) must equal routings"
    );
}

#[test]
fn dead_majority_defeats_replication_but_not_bigger_n() {
    let cl = Cluster::new(4, 1, NetworkConfig::default());
    cl.kill(LocalityId(0));
    cl.kill(LocalityId(1));
    cl.kill(LocalityId(2));
    let body: DistBody<i64> = Arc::new(|_| Ok(5));
    // n=4 covers all localities; exactly one is alive -> plain replicate
    // still succeeds (first OK wins).
    let f = async_replicate_distributed(&cl, 4, None, Arc::clone(&body));
    assert_eq!(f.get(), Ok(5));
    // majority vote over 4 replicas with 3 dead: ballot has one entry ->
    // majority of 1 -> wins.
    let f = async_replicate_distributed(&cl, 4, Some(Arc::new(vote_majority)), body);
    assert_eq!(f.get(), Ok(5));
}
