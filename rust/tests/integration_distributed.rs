//! Integration: distributed resiliency over simulated localities.

use std::sync::Arc;

use rhpx::agas::LocalityId;
use rhpx::distributed::{
    async_replay_distributed, async_replicate_distributed, Cluster, DistBody, NetworkConfig,
};
use rhpx::resilience::vote_majority;
use rhpx::{TaskError, TaskResult};

#[test]
fn cluster_with_latency_completes_many_tasks() {
    let cl = Cluster::new(3, 1, NetworkConfig { latency_us: 10 });
    let futs: Vec<_> = (0..30)
        .map(|i| cl.run_on(LocalityId(i % 3), move |_| Ok::<_, TaskError>(i)))
        .collect();
    let sum: usize = futs.into_iter().map(|f| f.get().unwrap()).sum();
    assert_eq!(sum, (0..30).sum::<usize>());
}

#[test]
fn replay_migrates_work_off_failed_node_mid_run() {
    let cl = Cluster::new(3, 1, NetworkConfig::default());
    // Phase 1: all localities healthy.
    let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
    for _ in 0..6 {
        assert!(async_replay_distributed(&cl, 3, Arc::clone(&body)).get().is_ok());
    }
    // Phase 2: locality 1 dies; every launch must still succeed by
    // walking the ring.
    cl.kill(LocalityId(1));
    for _ in 0..12 {
        let got = async_replay_distributed(&cl, 3, Arc::clone(&body)).get().unwrap();
        assert_ne!(got, 1, "task reported execution on a dead locality");
    }
    // Phase 3: locality rejoins.
    cl.revive(LocalityId(1));
    let mut saw_one = false;
    for _ in 0..12 {
        if async_replay_distributed(&cl, 3, Arc::clone(&body)).get().unwrap() == 1 {
            saw_one = true;
        }
    }
    assert!(saw_one, "revived locality never received work");
}

#[test]
fn distributed_vote_with_node_specific_corruption() {
    // Locality 0 computes garbage (a "bad node"); majority vote over
    // replicas on distinct localities masks it.
    let cl = Cluster::new(3, 1, NetworkConfig::default());
    let body: DistBody<i64> = Arc::new(|loc| {
        if loc.id().0 == 0 {
            Ok(-999) // silent corruption on node 0
        } else {
            Ok(42)
        }
    });
    for _ in 0..6 {
        let f = async_replicate_distributed(&cl, 3, Some(Arc::new(vote_majority)), Arc::clone(&body));
        assert_eq!(f.get(), Ok(42));
    }
}

#[test]
fn distributed_state_via_agas() {
    // A counter object registered in AGAS, updated from tasks on
    // different localities.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let cl = Cluster::new(2, 1, NetworkConfig::default());
    let gid = cl.agas().register(LocalityId(0), AtomicUsize::new(0));
    let futs: Vec<_> = (0..10)
        .map(|i| {
            let agas = cl.agas().clone();
            cl.run_on(LocalityId(i % 2), move |_| -> TaskResult<()> {
                agas.resolve::<AtomicUsize>(gid)
                    .ok_or(TaskError::App("missing".into()))?
                    .fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
        })
        .collect();
    for f in futs {
        f.get().unwrap();
    }
    assert_eq!(
        cl.agas().resolve::<AtomicUsize>(gid).unwrap().load(Ordering::SeqCst),
        10
    );
    // Migrate the object and keep using it.
    cl.agas().migrate(gid, LocalityId(1));
    assert_eq!(cl.agas().locate(gid), Some(LocalityId(1)));
}

#[test]
fn dead_majority_defeats_replication_but_not_bigger_n() {
    let cl = Cluster::new(4, 1, NetworkConfig::default());
    cl.kill(LocalityId(0));
    cl.kill(LocalityId(1));
    cl.kill(LocalityId(2));
    let body: DistBody<i64> = Arc::new(|_| Ok(5));
    // n=4 covers all localities; exactly one is alive -> plain replicate
    // still succeeds (first OK wins).
    let f = async_replicate_distributed(&cl, 4, None, Arc::clone(&body));
    assert_eq!(f.get(), Ok(5));
    // majority vote over 4 replicas with 3 dead: ballot has one entry ->
    // majority of 1 -> wins.
    let f = async_replicate_distributed(&cl, 4, Some(Arc::new(vote_majority)), body);
    assert_eq!(f.get(), Ok(5));
}
