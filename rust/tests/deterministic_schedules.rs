//! Scripted concurrency interleavings, replayed deterministically.
//!
//! Every test here decomposes a concurrency protocol into named logical
//! threads of discrete steps and replays one *chosen* interleaving with
//! [`rhpx::testing::det`] — virtual time, one OS thread, zero races to
//! win or lose. Where `tests/stress_concurrency.rs` hammers real
//! threads and hopes the schedule of interest occurs, these scripts
//! *force* it, identically on every run:
//!
//! * steal-vs-pop arbitration on the Chase–Lev deque's last element,
//!   both orders;
//! * buffer growth with a thief mid-stream (retired-buffer path);
//! * injector push vs. `take_all` orderings;
//! * kill-mid-drain orderings on the lineage ledger (claim-then-drain
//!   and drain-then-claim — the exactly-once arbitration);
//! * replica-team cancel-vs-resolve, both orders (a loser's late result
//!   never lands);
//! * flight-recorder ring record-vs-drain orderings, and wraparound
//!   where drain timing decides whether overwrite-oldest costs events
//!   (the loss is always counted, never silent).
//!
//! CI runs this file with `--test-threads=1`: the schedules are already
//! deterministic, serial execution keeps their traces readable when one
//! fails.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use rhpx::resilience::ReplicaTeam;
use rhpx::scheduler::{Injector, Lineage, LineageLedger, WorkQueue};
use rhpx::serve::{Admission, AdmissionGate, BreakerConfig, CircuitBreaker, Decision};
use rhpx::testing::det::{step, Interleaver};
use rhpx::TaskError;

/// A job that bumps `runs[id]` when executed — ownership of a job is
/// observable as exactly one bump.
fn counting_job(runs: &Arc<Vec<AtomicUsize>>, id: usize) -> rhpx::scheduler::Job {
    let runs = Arc::clone(runs);
    Box::new(move || {
        runs[id].fetch_add(1, Ordering::Relaxed);
    })
}

fn run_counts(n: usize) -> Arc<Vec<AtomicUsize>> {
    Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect())
}

// ---------------------------------------------------------------------
// Chase–Lev deque: steal vs. pop on the last element, both orders
// ---------------------------------------------------------------------

#[test]
fn det_steal_vs_pop_last_element_owner_first() {
    let q = WorkQueue::new();
    let runs = run_counts(1);
    // SAFETY: all owner-side calls happen on this one OS thread.
    unsafe { q.push(counting_job(&runs, 0)) };

    let winner: RefCell<Vec<&'static str>> = RefCell::new(Vec::new());
    let mut il = Interleaver::new();
    il.spawn(
        "owner",
        vec![step(|_| {
            if let Some(j) = unsafe { q.pop() } {
                j();
                winner.borrow_mut().push("owner");
            }
        })],
    );
    il.spawn(
        "thief",
        vec![step(|_| {
            if let Some(j) = q.steal() {
                j();
                winner.borrow_mut().push("thief");
            }
        })],
    );

    il.run_script("owner thief").unwrap();
    assert_eq!(*winner.borrow(), vec!["owner"], "pop first: the owner wins the element");
    assert_eq!(runs[0].load(Ordering::Relaxed), 1, "exactly-once");
    assert!(q.is_empty());
}

#[test]
fn det_steal_vs_pop_last_element_thief_first() {
    let q = WorkQueue::new();
    let runs = run_counts(1);
    unsafe { q.push(counting_job(&runs, 0)) };

    let winner: RefCell<Vec<&'static str>> = RefCell::new(Vec::new());
    let mut il = Interleaver::new();
    il.spawn(
        "owner",
        vec![step(|_| {
            if let Some(j) = unsafe { q.pop() } {
                j();
                winner.borrow_mut().push("owner");
            }
        })],
    );
    il.spawn(
        "thief",
        vec![step(|_| {
            if let Some(j) = q.steal() {
                j();
                winner.borrow_mut().push("thief");
            }
        })],
    );

    // Same threads, opposite order: the thief must win and the owner's
    // pop must find the deque empty — never a double execution.
    il.run_script("thief owner").unwrap();
    assert_eq!(*winner.borrow(), vec!["thief"], "steal first: the thief wins the element");
    assert_eq!(runs[0].load(Ordering::Relaxed), 1, "exactly-once");
    assert!(q.is_empty());
}

// ---------------------------------------------------------------------
// Chase–Lev deque: buffer growth with a thief mid-stream
// ---------------------------------------------------------------------

#[test]
fn det_buffer_growth_mid_steal_loses_no_jobs() {
    // 64 is the deque's initial capacity: the second push batch forces
    // `grow` *after* the thief has advanced top, exercising the
    // retired-buffer copy with live jobs on both sides of the boundary.
    const FIRST: usize = 64;
    const SECOND: usize = 10;
    const STOLEN_BEFORE_GROW: usize = 3;
    const TOTAL: usize = FIRST + SECOND;

    let q = WorkQueue::new();
    let runs = run_counts(TOTAL);

    let mut il = Interleaver::new();
    il.spawn(
        "owner",
        vec![
            step(|_| {
                for id in 0..FIRST {
                    unsafe { q.push(counting_job(&runs, id)) };
                }
            }),
            step(|_| {
                // bottom − top ≥ capacity here, so this batch grows the
                // buffer while the thief's 3 steals are already banked.
                for id in FIRST..TOTAL {
                    unsafe { q.push(counting_job(&runs, id)) };
                }
            }),
        ],
    );
    il.spawn(
        "thief",
        (0..STOLEN_BEFORE_GROW)
            .map(|_| {
                step(|_| {
                    let j = q.steal().expect("deque is non-empty before the grow");
                    j();
                })
            })
            .collect::<Vec<_>>(),
    );

    il.run_script("owner thief thief thief owner").unwrap();

    // Drain the survivors from both ends, strictly alternating: pops
    // (LIFO, newest first) interleaved with steals (FIFO, oldest first)
    // until the two frontiers meet on the grown buffer.
    let remaining = TOTAL - STOLEN_BEFORE_GROW;
    il.spawn(
        "owner",
        (0..remaining)
            .map(|_| {
                step(|_| {
                    if let Some(j) = unsafe { q.pop() } {
                        j();
                    }
                })
            })
            .collect::<Vec<_>>(),
    );
    il.spawn(
        "thief",
        (0..remaining)
            .map(|_| {
                step(|_| {
                    if let Some(j) = q.steal() {
                        j();
                    }
                })
            })
            .collect::<Vec<_>>(),
    );
    il.run_remaining();
    assert!(il.is_drained());

    assert!(q.is_empty(), "every job must have been handed out");
    for (id, r) in runs.iter().enumerate() {
        assert_eq!(
            r.load(Ordering::Relaxed),
            1,
            "job {id} must run exactly once across the grow"
        );
    }
}

// ---------------------------------------------------------------------
// Injector (Treiber stack): push vs. take_all orderings
// ---------------------------------------------------------------------

#[test]
fn det_injector_push_vs_take_all_orderings() {
    let inj = Injector::new();
    let order: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let push_job = |id: usize| -> rhpx::scheduler::Job {
        let order = Arc::clone(&order);
        Box::new(move || order.lock().unwrap().push(id))
    };

    let batches: RefCell<Vec<usize>> = RefCell::new(Vec::new());
    let mut il = Interleaver::new();
    il.spawn(
        "producer",
        vec![
            step(|_| inj.push(push_job(1))),
            step(|_| inj.push(push_job(2))),
            step(|_| inj.push(push_job(3))),
        ],
    );
    il.spawn(
        "consumer",
        vec![
            // First take_all races ahead of any push: empty batch.
            step(|_| batches.borrow_mut().push(inj.take_all().map(|j| j()).count())),
            // Second lands between pushes 2 and 3: two jobs, newest
            // first (stack order).
            step(|_| batches.borrow_mut().push(inj.take_all().map(|j| j()).count())),
            // Third collects the straggler.
            step(|_| batches.borrow_mut().push(inj.take_all().map(|j| j()).count())),
        ],
    );

    il.run_script("consumer producer producer consumer producer consumer").unwrap();

    assert_eq!(*batches.borrow(), vec![0, 2, 1], "batch sizes follow the interleaving");
    // Stack order within a batch: [2, 1] then [3]; union exactly once.
    assert_eq!(*order.lock().unwrap(), vec![2, 1, 3]);
    assert!(inj.is_empty());
}

// ---------------------------------------------------------------------
// Lineage ledger: kill-mid-drain orderings (the exactly-once gate)
// ---------------------------------------------------------------------

/// A ledger with `n` recorded epochs whose relaunch closures log into
/// `relaunched` — the shape `Cluster::kill` drains.
fn seeded_ledger(n: u64, relaunched: &Arc<Mutex<Vec<u64>>>) -> LineageLedger {
    let ledger = LineageLedger::new();
    for epoch in 0..n {
        let log = Arc::clone(relaunched);
        ledger.record(
            Lineage { origin: 2, parent: None, epoch },
            Box::new(move || log.lock().unwrap().push(epoch)),
        );
    }
    ledger
}

#[test]
fn det_kill_drain_after_claim_respects_the_claim() {
    let relaunched: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let ledger = seeded_ledger(4, &relaunched);
    let executed: RefCell<Vec<u64>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    il.spawn(
        "worker",
        vec![step(|_| {
            // The corpse's worker reaches epoch 0 just before the kill.
            if ledger.claim(0) {
                executed.borrow_mut().push(0);
            }
        })],
    );
    il.spawn(
        "kill",
        vec![step(|_| {
            for (_lineage, relaunch) in ledger.drain() {
                relaunch();
            }
        })],
    );

    il.run_script("worker kill").unwrap();

    // Claim won epoch 0, so the drain must hand out only 1..4 — in
    // epoch order (the ledger is a BTreeMap precisely for this).
    assert_eq!(*executed.borrow(), vec![0]);
    assert_eq!(*relaunched.lock().unwrap(), vec![1, 2, 3]);
    assert!(ledger.is_empty());
}

#[test]
fn det_kill_drain_before_claim_wins_the_epoch() {
    let relaunched: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let ledger = seeded_ledger(4, &relaunched);
    let executed: RefCell<Vec<u64>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    il.spawn(
        "worker",
        vec![step(|_| {
            // The worker wakes up *after* the kill drained its queue:
            // the claim must lose and the body must not run here.
            if ledger.claim(0) {
                executed.borrow_mut().push(0);
            }
        })],
    );
    il.spawn(
        "kill",
        vec![step(|_| {
            for (_lineage, relaunch) in ledger.drain() {
                relaunch();
            }
        })],
    );

    // Same threads, opposite order.
    il.run_script("kill worker").unwrap();

    assert!(executed.borrow().is_empty(), "a drained epoch must not execute on the corpse");
    assert_eq!(*relaunched.lock().unwrap(), vec![0, 1, 2, 3]);
    assert!(ledger.is_empty());
}

// ---------------------------------------------------------------------
// Circuit breaker: Open → HalfOpen transitions on the virtual clock
// ---------------------------------------------------------------------

/// Breaker tuning for scripted tests: trips on the second failure,
/// 3-tick base cooldown, zero jitter so every retry hint is exact.
fn scripted_breaker() -> CircuitBreaker {
    CircuitBreaker::new(BreakerConfig {
        failure_threshold: 2,
        cooldown_ticks: 3,
        max_doublings: 4,
        jitter_ticks: 0,
        seed: 1,
    })
}

#[test]
fn det_breaker_opens_then_halfopens_only_after_the_cooldown_tick() {
    let br = scripted_breaker();
    let admissions: RefCell<Vec<Admission>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    il.spawn(
        "service",
        vec![
            step(|clock| br.on_failure("stencil1d", clock.now())),
            step(|clock| br.on_failure("stencil1d", clock.now())),
        ],
    );
    il.spawn(
        "client",
        vec![
            // Tick 0 (just tripped, until = 3): rejected, full cooldown.
            step(|clock| {
                admissions.borrow_mut().push(br.allow("stencil1d", clock.now()));
                clock.advance(2);
            }),
            // Tick 2: still open, hint counts down.
            step(|clock| {
                admissions.borrow_mut().push(br.allow("stencil1d", clock.now()));
                clock.advance(1);
            }),
            // Tick 3, exactly the cooldown boundary: the probe slot.
            step(|clock| {
                admissions.borrow_mut().push(br.allow("stencil1d", clock.now()));
            }),
        ],
    );

    il.run_script("service service client client client").unwrap();

    assert_eq!(
        *admissions.borrow(),
        vec![
            Admission::Reject { retry_after_ticks: 3 },
            Admission::Reject { retry_after_ticks: 1 },
            Admission::Probe,
        ],
        "Open admits nothing before the cooldown tick, the probe exactly at it"
    );
    assert!(!br.is_open("other", u64::MAX), "classes stay independent");
}

#[test]
fn det_breaker_probe_success_vs_rival_both_interleavings() {
    // Interleaving A: the rival's request lands while the probe is
    // still in flight — it must be rejected, one probe at a time.
    // Interleaving B: the rival lands after the probe's success — the
    // class is Closed again and the rival is admitted.
    for (script, expect_rival) in [
        (
            "probe rival settle rival",
            vec![
                Admission::Reject { retry_after_ticks: 3 },
                Admission::Admit,
            ],
        ),
        ("probe settle rival rival", vec![Admission::Admit, Admission::Admit]),
    ] {
        let br = scripted_breaker();
        br.on_failure("w", 0);
        br.on_failure("w", 0); // Open until tick 3
        let rival_saw: RefCell<Vec<Admission>> = RefCell::new(Vec::new());
        let probe_got: RefCell<Option<Admission>> = RefCell::new(None);

        let mut il = Interleaver::new();
        il.spawn("probe", {
            let br = &br;
            let probe_got = &probe_got;
            vec![step(move |clock| {
                clock.advance(3); // cooldown elapses
                *probe_got.borrow_mut() = Some(br.allow("w", clock.now()));
            })]
        });
        il.spawn("settle", {
            let br = &br;
            vec![step(move |clock| br.on_success("w", clock.now()))]
        });
        il.spawn("rival", {
            let br = &br;
            let rival_saw = &rival_saw;
            (0..2)
                .map(|_| {
                    step(move |clock| {
                        rival_saw.borrow_mut().push(br.allow("w", clock.now()));
                    })
                })
                .collect::<Vec<_>>()
        });

        il.run_script(script).unwrap();

        assert_eq!(*probe_got.borrow(), Some(Admission::Probe), "script {script:?}");
        assert_eq!(*rival_saw.borrow(), expect_rival, "script {script:?}");
        assert_eq!(br.opens("w"), 0, "probe success resets the backoff ({script:?})");
    }
}

#[test]
fn det_breaker_probe_failure_reopens_with_doubled_cooldown() {
    let br = scripted_breaker();
    let outcomes: RefCell<Vec<Admission>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    il.spawn(
        "service",
        vec![
            step(|clock| br.on_failure("w", clock.now())),
            step(|clock| br.on_failure("w", clock.now())), // trip #1: until 3
            step(|clock| br.on_failure("w", clock.now())), // probe fails → trip #2
        ],
    );
    il.spawn(
        "client",
        vec![
            step(|clock| {
                clock.advance(3);
                outcomes.borrow_mut().push(br.allow("w", clock.now())); // the probe
            }),
            // Right after the failed probe: cooldown doubled to 6,
            // so the hint from tick 3 is the full 6 ticks.
            step(|clock| {
                outcomes.borrow_mut().push(br.allow("w", clock.now()));
                clock.advance(6);
            }),
            // Tick 9 = 3 + 6: the doubled cooldown elapses, next probe.
            step(|clock| {
                outcomes.borrow_mut().push(br.allow("w", clock.now()));
            }),
        ],
    );

    il.run_script("service service client service client client").unwrap();

    assert_eq!(
        *outcomes.borrow(),
        vec![
            Admission::Probe,
            Admission::Reject { retry_after_ticks: 6 },
            Admission::Probe,
        ],
        "probe failure reopens at exactly double the base cooldown"
    );
    assert_eq!(br.opens("w"), 2, "two trips: the original and the failed probe");
}

// ---------------------------------------------------------------------
// Admission gate: two clients racing the last slot, both orders
// ---------------------------------------------------------------------

#[test]
fn det_admission_last_slot_race_admits_exactly_one() {
    for script in ["a b", "b a"] {
        let gate = AdmissionGate::new(3, 7);
        assert!(matches!(gate.try_admit(), Decision::Admitted));
        assert!(matches!(gate.try_admit(), Decision::Admitted)); // 1 slot left

        let decisions: RefCell<Vec<(&'static str, Decision)>> = RefCell::new(Vec::new());
        let mut il = Interleaver::new();
        for name in ["a", "b"] {
            let gate = &gate;
            let decisions = &decisions;
            il.spawn(
                name,
                vec![step(move |_| {
                    decisions.borrow_mut().push((name, gate.try_admit()));
                })],
            );
        }
        il.run_script(script).unwrap();

        let decisions = decisions.borrow();
        let admitted: Vec<&str> =
            decisions.iter().filter(|(_, d)| matches!(d, Decision::Admitted)).map(|(n, _)| *n).collect();
        let rejected: Vec<&str> = decisions
            .iter()
            .filter(|(_, d)| matches!(d, Decision::Rejected { retry_after_ms: 7 }))
            .map(|(n, _)| *n)
            .collect();
        let first = script.split(' ').next().unwrap();
        assert_eq!(admitted, vec![first], "script {script:?}: first requester takes the last slot");
        assert_eq!(rejected.len(), 1, "script {script:?}: the loser gets typed backpressure");
        assert_eq!(gate.depth(), 3, "gate is full either way");

        // Releasing one slot re-opens admission — backpressure, not ban.
        gate.release();
        assert!(matches!(gate.try_admit(), Decision::Admitted));
    }
}

// ---------------------------------------------------------------------
// Heartbeat monitor: verdict boundaries on the virtual clock
// ---------------------------------------------------------------------

/// The monitor's whole contract is clock arithmetic, so the virtual
/// clock pins its boundaries exactly: period 10, K = 3 → a locality is
/// declared dead at precisely 30 ticks of silence, not 29.
#[test]
fn det_monitor_declares_dead_exactly_at_k_missed_periods() {
    use rhpx::agas::LocalityId;
    use rhpx::distributed::HeartbeatMonitor;

    let mon = RefCell::new(HeartbeatMonitor::new(1, 10, 3, 0));
    let polls: RefCell<Vec<(u64, Vec<LocalityId>)>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    il.spawn(
        "worker",
        vec![step(|clock| {
            clock.advance(5);
            assert!(mon.borrow_mut().beat(LocalityId(0), clock.now()), "a live beat lands");
        })],
    );
    il.spawn(
        "monitor",
        vec![
            // Tick 34: 29 ticks of silence — one short of the deadline.
            step(|clock| {
                clock.advance(29);
                polls.borrow_mut().push((clock.now(), mon.borrow_mut().poll(clock.now())));
            }),
            // Tick 35: exactly K missed periods — the verdict.
            step(|clock| {
                clock.advance(1);
                polls.borrow_mut().push((clock.now(), mon.borrow_mut().poll(clock.now())));
            }),
            // A verdict is reported exactly once.
            step(|clock| {
                polls.borrow_mut().push((clock.now(), mon.borrow_mut().poll(clock.now())));
            }),
        ],
    );
    il.run_script("worker monitor monitor monitor").unwrap();

    assert_eq!(
        *polls.borrow(),
        vec![(34, vec![]), (35, vec![LocalityId(0)]), (35, vec![])],
        "dead at exactly beat + period*K, reported once"
    );
    assert!(mon.borrow().is_dead(LocalityId(0)));
    assert!(mon.borrow().alive_ids().is_empty());
}

/// A late heartbeat racing the death verdict, both orders. Beat first:
/// the beat refreshes the deadline and the poll finds a live worker.
/// Poll first: the verdict lands, is final, and the late beat is
/// refused — a locality never resurrects (its drained tasks have
/// already been re-materialized elsewhere; see
/// `det_kill_drain_before_claim_wins_the_epoch` for why coming back
/// would break exactly-once).
#[test]
fn det_monitor_late_beat_vs_verdict_both_orders() {
    use rhpx::agas::LocalityId;
    use rhpx::distributed::HeartbeatMonitor;

    for (script, beat_accepted, dead) in
        [("time beat poll", true, false), ("time poll beat", false, true)]
    {
        let mon = RefCell::new(HeartbeatMonitor::new(1, 10, 3, 0));
        let beat_landed: RefCell<Option<bool>> = RefCell::new(None);

        let mut il = Interleaver::new();
        // Advance straight to the deadline tick: the next two steps race
        // at the exact instant the verdict becomes available.
        il.spawn("time", vec![step(|clock| clock.advance(30))]);
        il.spawn(
            "beat",
            vec![step(|clock| {
                *beat_landed.borrow_mut() =
                    Some(mon.borrow_mut().beat(LocalityId(0), clock.now()));
            })],
        );
        il.spawn(
            "poll",
            vec![step(|clock| {
                let _ = mon.borrow_mut().poll(clock.now());
            })],
        );
        il.run_script(script).unwrap();

        assert_eq!(
            *beat_landed.borrow(),
            Some(beat_accepted),
            "script {script:?}: beat acceptance follows the race order"
        );
        assert_eq!(
            mon.borrow().is_dead(LocalityId(0)),
            dead,
            "script {script:?}: verdict follows the race order"
        );
    }
}

/// A slow-but-alive worker: every beat arrives one tick inside the
/// deadline, forever. The monitor must never produce a false positive —
/// jitter short of K full missed periods is not death.
#[test]
fn det_monitor_never_declares_a_slow_but_alive_worker() {
    use rhpx::agas::LocalityId;
    use rhpx::distributed::HeartbeatMonitor;

    let mon = RefCell::new(HeartbeatMonitor::new(1, 10, 3, 0));

    let mut il = Interleaver::new();
    il.spawn(
        "worker",
        (0..5)
            .map(|_| {
                step(|clock| {
                    clock.advance(29); // maximally late, still inside 30
                    assert!(mon.borrow_mut().beat(LocalityId(0), clock.now()));
                })
            })
            .collect::<Vec<_>>(),
    );
    il.spawn(
        "monitor",
        (0..5)
            .map(|_| {
                step(|clock| {
                    assert_eq!(
                        mon.borrow_mut().poll(clock.now()),
                        vec![],
                        "no verdict at tick {}",
                        clock.now()
                    );
                })
            })
            .collect::<Vec<_>>(),
    );
    // Strictly alternating: each near-deadline beat is immediately
    // followed by a poll at the same instant.
    il.run_script("worker monitor worker monitor worker monitor worker monitor worker monitor")
        .unwrap();

    assert!(!mon.borrow().is_dead(LocalityId(0)));
    assert_eq!(mon.borrow().alive_ids(), vec![LocalityId(0)]);
}

// ---------------------------------------------------------------------
// Flight-recorder ring: record vs. drain orderings and wraparound
// ---------------------------------------------------------------------

/// Record vs. drain, both orders, on a private ring (no global
/// session). Drain-first sees an empty batch; record-first sees both
/// events, oldest first. Either way nothing is dropped and nothing is
/// delivered twice.
#[test]
fn det_ring_record_vs_drain_both_orders() {
    use rhpx::trace::{EventKind, Ring};

    for (script, expect_batches) in [
        ("writer reader reader", vec![vec![10u64, 20], vec![]]),
        ("reader writer reader", vec![vec![], vec![10, 20]]),
    ] {
        let ring = Ring::new(8, 0);
        let batches: RefCell<Vec<Vec<u64>>> = RefCell::new(Vec::new());

        let mut il = Interleaver::new();
        il.spawn(
            "writer",
            vec![step(|_| {
                ring.record(10, EventKind::ExecBegin, 1, 0);
                ring.record(20, EventKind::ExecEnd, 1, 0);
            })],
        );
        il.spawn(
            "reader",
            (0..2)
                .map(|_| {
                    step(|_| {
                        let d = ring.drain();
                        assert_eq!(d.dropped, 0, "no overwrite in an 8-slot ring");
                        batches
                            .borrow_mut()
                            .push(d.events.iter().map(|e| e.ts_ns).collect());
                    })
                })
                .collect::<Vec<_>>(),
        );
        il.run_script(script).unwrap();

        assert_eq!(*batches.borrow(), expect_batches, "script {script:?}");
        assert_eq!(ring.total(), 2, "script {script:?}");
        assert_eq!(ring.dropped(), 0, "script {script:?}");
    }
}

/// Six records into a four-slot ring before any drain: the two oldest
/// events are overwritten, the drain returns the surviving four in
/// order, and the loss is *counted* — the overwrite-oldest contract is
/// honest, never silent.
#[test]
fn det_ring_wraparound_overwrites_oldest_and_counts_the_loss() {
    use rhpx::trace::{EventKind, Ring};

    let ring = Ring::new(4, 0);
    let drained: RefCell<Vec<u64>> = RefCell::new(Vec::new());
    let lost: RefCell<u64> = RefCell::new(0);

    let mut il = Interleaver::new();
    il.spawn(
        "writer",
        vec![step(|_| {
            for i in 0..6u64 {
                ring.record(i, EventKind::Spawn, i, 0);
            }
        })],
    );
    il.spawn(
        "reader",
        vec![step(|_| {
            let d = ring.drain();
            *lost.borrow_mut() = d.dropped;
            drained.borrow_mut().extend(d.events.iter().map(|e| e.ts_ns));
        })],
    );
    il.run_script("writer reader").unwrap();

    assert_eq!(*drained.borrow(), vec![2, 3, 4, 5], "survivors, oldest first");
    assert_eq!(*lost.borrow(), 2, "the overwritten pair is priced");
    assert_eq!(ring.total(), 6);
    assert_eq!(ring.dropped(), 2);
}

/// The same six records, but the reader drains mid-stream — before the
/// write cursor laps the read cursor. Now nothing is lost: drain timing
/// alone decides whether wraparound costs events, which is exactly the
/// trade the fixed-capacity record path makes.
#[test]
fn det_ring_mid_stream_drain_prevents_the_loss() {
    use rhpx::trace::{EventKind, Ring};

    let ring = Ring::new(4, 0);
    let batches: RefCell<Vec<Vec<u64>>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    il.spawn(
        "writer",
        vec![
            step(|_| {
                for i in 0..3u64 {
                    ring.record(i, EventKind::Spawn, i, 0);
                }
            }),
            step(|_| {
                for i in 3..6u64 {
                    ring.record(i, EventKind::Spawn, i, 0);
                }
            }),
        ],
    );
    il.spawn(
        "reader",
        (0..2)
            .map(|_| {
                step(|_| {
                    let d = ring.drain();
                    assert_eq!(d.dropped, 0, "mid-stream drains stay ahead of the writer");
                    batches
                        .borrow_mut()
                        .push(d.events.iter().map(|e| e.ts_ns).collect());
                })
            })
            .collect::<Vec<_>>(),
    );
    il.run_script("writer reader writer reader").unwrap();

    assert_eq!(*batches.borrow(), vec![vec![0, 1, 2], vec![3, 4, 5]]);
    assert_eq!(ring.total(), 6);
    assert_eq!(ring.dropped(), 0, "same writes as the wraparound test, zero loss");
}

// ---------------------------------------------------------------------
// Replica teams: cancel vs. resolve, both orders
// ---------------------------------------------------------------------

#[test]
fn det_cancel_vs_resolve_winner_reports_first() {
    let (team, fut) = ReplicaTeam::<u64>::new(2);
    let token = team.token();
    let body_runs: RefCell<Vec<&'static str>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    {
        let team_a = Arc::clone(&team);
        let team_b = Arc::clone(&team);
        let token_b = token.clone();
        let body_runs = &body_runs;
        il.spawn(
            "winner",
            vec![step(move |_| {
                body_runs.borrow_mut().push("winner");
                team_a.report(Ok(7), Some(true));
            })],
        );
        il.spawn(
            "loser",
            vec![step(move |_| {
                // The task-body entry check: a cancelled replica retires
                // without running its body.
                if token_b.is_cancelled() {
                    team_b.report(Err(TaskError::Cancelled), None);
                } else {
                    body_runs.borrow_mut().push("loser");
                    team_b.report(Ok(9), Some(true));
                }
            })],
        );
        il.run_script("winner loser").unwrap();
    }

    assert_eq!(fut.get(), Ok(7), "the first validated result resolves the future");
    assert_eq!(*body_runs.borrow(), vec!["winner"], "the loser's body must not run");
    assert!(token.is_cancelled());
    assert_eq!(team.retired(), 1);
    assert_eq!(team.outstanding(), 0);
}

#[test]
fn det_cancel_vs_resolve_late_result_never_lands() {
    let (team, fut) = ReplicaTeam::<u64>::new(2);
    let token = team.token();
    let body_runs: RefCell<Vec<&'static str>> = RefCell::new(Vec::new());

    let mut il = Interleaver::new();
    {
        let team_a = Arc::clone(&team);
        let team_b = Arc::clone(&team);
        let token_b = token.clone();
        let body_runs = &body_runs;
        il.spawn(
            "winner",
            vec![step(move |_| {
                body_runs.borrow_mut().push("winner");
                team_a.report(Ok(7), Some(true));
            })],
        );
        il.spawn(
            "loser",
            vec![step(move |_| {
                // Opposite order: the "loser" thread runs first, before
                // any cancellation exists, so *it* wins the race.
                if token_b.is_cancelled() {
                    team_b.report(Err(TaskError::Cancelled), None);
                } else {
                    body_runs.borrow_mut().push("loser");
                    team_b.report(Ok(9), Some(true));
                }
            })],
        );
        il.run_script("loser winner").unwrap();
    }

    // First result wins; the second (uncancelled, fully computed)
    // result arrives late and must be dropped, not overwrite the value.
    assert_eq!(fut.get(), Ok(9), "the future keeps the first result");
    assert_eq!(*body_runs.borrow(), vec!["loser", "winner"]);
    assert!(token.is_cancelled(), "the win must have cancelled the token");
    assert_eq!(team.retired(), 0, "both bodies ran: nothing retired");
    assert_eq!(team.outstanding(), 0);
}
