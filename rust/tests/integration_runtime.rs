//! Integration: runtime + resilience + workload + config, cross-module.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use rhpx::config::RuntimeConfig;
use rhpx::failure::FaultInjector;
use rhpx::resilience;
use rhpx::workload::{run, Variant, WorkloadParams};
use rhpx::{async_, channel, dataflow, Runtime, TaskError, TaskResult};

#[test]
fn thousands_of_tasks_across_apis() {
    let rt = Runtime::builder().workers(3).build();
    let n = 2_000;
    let counter = Arc::new(AtomicUsize::new(0));
    let futs: Vec<_> = (0..n)
        .map(|i| {
            let c = Arc::clone(&counter);
            match i % 3 {
                0 => async_(&rt, move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i as i64
                }),
                1 => resilience::async_replay(&rt, 3, move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i as i64
                }),
                _ => {
                    let dep = async_(&rt, move || i as i64);
                    dataflow(
                        &rt,
                        move |v: Vec<i64>| {
                            c.fetch_add(1, Ordering::SeqCst);
                            v[0]
                        },
                        vec![dep],
                    )
                }
            }
        })
        .collect();
    let mut sum = 0i64;
    for f in futs {
        sum += f.get().unwrap();
    }
    assert_eq!(sum, (0..n as i64).sum::<i64>());
    assert_eq!(counter.load(Ordering::SeqCst), n);
}

#[test]
fn workload_replay_beats_unprotected_failures() {
    let rt = Runtime::builder().workers(2).build();
    let params = WorkloadParams {
        tasks: 400,
        grain_ns: 2_000,
        error_rate: Some(1.0), // P(fail) ≈ 0.37
        ..Default::default()
    };
    let plain = run(&rt, Variant::Plain, &params);
    // n = 20: P(exhaust) = (e^-1)^20 ≈ 2e-9 per launch — statistically
    // impossible over 400 launches (n = 10 flaked ~2% of runs).
    let replay = run(&rt, Variant::Replay { n: 20 }, &params);
    assert!(plain.launch_errors > 0, "plain must observe failures");
    assert_eq!(replay.launch_errors, 0, "replay(20) must absorb failures");
}

#[test]
fn deep_dependency_chain_with_failures_recovers() {
    let rt = Runtime::builder().workers(2).build();
    let inj = FaultInjector::new(1.5, 42); // P ≈ 0.22
    let mut f = async_(&rt, || 0i64);
    for _ in 0..200 {
        let inj = inj.clone();
        f = resilience::dataflow_replay(
            &rt,
            10,
            move |v: &[i64]| -> TaskResult<i64> {
                inj.draw("chain")?;
                Ok(v[0] + 1)
            },
            vec![f],
        );
    }
    assert_eq!(f.get(), Ok(200));
    assert!(inj.counters().injected() > 0);
}

#[test]
fn channels_pipeline_through_workers() {
    let rt = Runtime::builder().workers(2).build();
    let (tx, rx) = channel::<i64>();
    // producer task
    let txc = tx.clone();
    rhpx::apply(&rt, move || {
        for i in 0..50 {
            txc.send(i);
        }
    });
    // consumer graph: sum the first 50
    let mut sum = 0;
    for _ in 0..50 {
        sum += rx.recv().get().unwrap();
    }
    assert_eq!(sum, (0..50).sum::<i64>());
}

#[test]
fn runtime_from_config_file() {
    let dir = std::env::temp_dir().join(format!("rhpx_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rhpx.toml");
    std::fs::write(&path, "[runtime]\nworkers = 2\nreplay_attempts = 7\n").unwrap();
    let cfg = RuntimeConfig::load(Some(&path)).unwrap();
    let rt = Runtime::from_config(cfg);
    assert_eq!(rt.workers(), 2);
    assert_eq!(rt.config().replay_attempts, 7);
    let f = async_(&rt, || 1i32);
    assert_eq!(f.get(), Ok(1));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mixed_resilient_dag() {
    // replay feeding replicate feeding vote — APIs compose through
    // ordinary futures.
    let rt = Runtime::builder().workers(2).build();
    let a = resilience::async_replay(&rt, 3, || 10i64);
    let b = resilience::dataflow_replicate(&rt, 3, |v: &[i64]| v[0] * 2, vec![a]);
    let c = resilience::dataflow_replicate_vote(
        &rt,
        3,
        resilience::vote_majority,
        |v: &[i64]| v[0] + 1,
        vec![b],
    );
    assert_eq!(c.get(), Ok(21));
}

#[test]
fn resilience_error_taxonomy_end_to_end() {
    let rt = Runtime::builder().workers(2).build();
    // Exhausted
    let f = resilience::async_replay(&rt, 2, || -> TaskResult<i32> { Err("x".into()) });
    let err = f.get().unwrap_err();
    assert!(matches!(
        err,
        TaskError::Resilience(e)
            if matches!(*e, rhpx::ResilienceError::Exhausted { attempts: 2, .. })
    ));
    // AllReplicasFailed
    let f = resilience::async_replicate(&rt, 2, || -> TaskResult<i32> { Err("y".into()) });
    let err = f.get().unwrap_err();
    assert!(matches!(
        err,
        TaskError::Resilience(e)
            if matches!(*e, rhpx::ResilienceError::AllReplicasFailed { replicas: 2, .. })
    ));
    // ValidationFailed
    let f = resilience::async_replicate_validate(&rt, 2, |_: &i32| false, || 1i32);
    let err = f.get().unwrap_err();
    assert!(matches!(
        err,
        TaskError::Resilience(e)
            if matches!(*e, rhpx::ResilienceError::ValidationFailed { replicas: 2 })
    ));
}

#[test]
fn scheduler_steals_across_workers() {
    // Push a burst from the main thread (injector) and verify it drains
    // with multiple workers picking up tasks.
    let rt = Runtime::builder().workers(4).build();
    let barrier = Arc::new(std::sync::Barrier::new(1));
    let _ = barrier;
    let counter = Arc::new(AtomicUsize::new(0));
    for _ in 0..500 {
        let c = Arc::clone(&counter);
        rhpx::apply(&rt, move || {
            rhpx::metrics::busy_wait_ns(10_000);
            c.fetch_add(1, Ordering::SeqCst);
        });
    }
    rt.wait_idle();
    assert_eq!(counter.load(Ordering::SeqCst), 500);
    assert_eq!(rt.stats().completed, 500);
}
