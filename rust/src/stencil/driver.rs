//! The resilient 1D stencil driver (§V-B).
//!
//! Builds the dataflow DAG of the benchmark: one task per (subdomain,
//! iteration), each task depending on its own subdomain and its two
//! neighbors from the previous iteration, advancing `steps` time levels
//! per iteration through the ghost-region kernel. The launch API used
//! per task is selected by [`Mode`] — the exact configurations of
//! Table II and Fig 3 (pure dataflow / replay without and with checksums
//! / replicate), plus this repo's extensions.
//!
//! The driver is generic over *where* tasks run: the same DAG launches on
//! a single runtime's pool (the default) or, with
//! [`StencilParams::cluster`] set, round-robin across the localities of a
//! simulated [`Cluster`](crate::distributed::Cluster) — with a
//! deterministic [`FaultSchedule`](crate::distributed::FaultSchedule)
//! killing localities mid-run and the `--resilience` executor decorators
//! recovering the affected subdomains. That is the paper's extreme-scale
//! scenario (Fig 4–5): subdomain tasks surviving locality death. See
//! `docs/FAULT_MODEL.md` for which fault each knob injects and which API
//! recovers it.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::agas::LocalityId;
use crate::api::dataflow;
use crate::checkpoint::store::SnapshotStore;
use crate::checkpoint::{DiskSnapshotStore, MemorySnapshotStore};
use crate::distributed::{ClusterExecutor, ClusterSpec, KillEvent};
use crate::error::{TaskError, TaskResult};
use crate::failure::FaultInjector;
use crate::future::Future;
use crate::metrics::Timer;
use crate::resilience::checkpoint::{
    AgasSnapshotStore, CheckpointExecutor, SnapshotCounts, Snapshots,
};
use crate::resilience::executor::{
    BuiltExecutor, PoolExecutor, ResilientExecutor, SnapshotBackend, TaskLauncher, TaskValidator,
};
use crate::resilience::{
    dataflow_replay, dataflow_replay_validate, dataflow_replicate, dataflow_replicate_replay,
    dataflow_replicate_validate, dataflow_replicate_vote, vote_majority,
};
use crate::runtime::ArtifactStore;
use crate::runtime_handle::Runtime;

use super::domain::{build_extended, Chunk, Domain};
use super::kernel;

/// Which launch API each stencil task uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Plain `dataflow` — Table II's "Pure Dataflow" baseline.
    Pure,
    /// `dataflow_replay(n)` — "Replay without checksums".
    Replay { n: usize },
    /// `dataflow_replay_validate(n, checksum)` — "Replay with checksums".
    ReplayChecksum { n: usize },
    /// `dataflow_replicate(n)` — "Replicate without checksums".
    Replicate { n: usize },
    /// `dataflow_replicate_validate(n, checksum)`.
    ReplicateChecksum { n: usize },
    /// `dataflow_replicate_vote(n, majority)` — silent-error consensus.
    ReplicateVote { n: usize },
    /// Replicate-of-replays extension (§Future-Work).
    ReplicateReplay { n: usize, replays: usize },
}

impl Mode {
    pub fn label(&self) -> String {
        match self {
            Mode::Pure => "pure_dataflow".into(),
            Mode::Replay { n } => format!("replay({n})"),
            Mode::ReplayChecksum { n } => format!("replay_checksum({n})"),
            Mode::Replicate { n } => format!("replicate({n})"),
            Mode::ReplicateChecksum { n } => format!("replicate_checksum({n})"),
            Mode::ReplicateVote { n } => format!("replicate_vote({n})"),
            Mode::ReplicateReplay { n, replays } => format!("replicate_replay({n},{replays})"),
        }
    }
}

/// Executor-routed resilience for the whole driver (CLI `--resilience`):
/// instead of selecting a resilient *call* per task ([`Mode`]), the
/// driver swaps in a resilient executor decorator and every task launch
/// goes through it unchanged — checksum validation included, so the
/// executor observes both thrown and silent errors. The adaptive
/// variants publish perfcounters under `/resilience/stencil/...`:
/// `Adaptive` tunes a replay budget, `AdaptiveReplicate` tunes the eager
/// replication width (CLI `adaptive_replicate[:CEIL]`).
pub use crate::resilience::executor::PolicySpec as ExecPolicy;

/// The adaptive *replay* route's minimum budget. Generous on purpose:
/// replay attempts cost nothing unless a task actually fails, and a low
/// floor would let early tasks exhaust before the policy has observed
/// anything. A user-requested ceiling below this still wins (the floor
/// is clamped to the ceiling in [`ExecPolicy::build`]). The adaptive
/// *replicate* route ignores this and pins its floor at
/// [`crate::resilience::executor::ADAPTIVE_REPLICATE_FLOOR`], since
/// replicas are eager compute.
const ADAPTIVE_FLOOR: usize = 5;

/// Replication factor of the AGAS snapshot backend on the cluster
/// checkpoint route: two replicas on distinct live localities, so a
/// single locality death never loses a snapshot (the survivor is
/// re-homed off the corpse via `Agas::migrate`). Backends with factor 1
/// (testable directly through
/// [`crate::resilience::checkpoint::AgasSnapshotStore::new`]) *do* lose
/// snapshots on a kill, which is what forces deeper delta replay.
const AGAS_SNAPSHOT_REPLICAS: usize = 2;

/// Attempt budget for one repair execution during checkpoint recovery.
/// Repairs route over live localities only, so the budget exists for
/// *injected* failures (exceptions, silent corruption) re-striking the
/// repair itself, not for dead-locality routing.
const REPAIR_ATTEMPTS: usize = 5;

/// Which kernel executes the math.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust reference kernel.
    Native,
    /// The AOT JAX/Pallas artifact through PJRT (the production path).
    Pjrt { artifact: PathBuf },
}

impl Backend {
    /// Resolve the PJRT backend from an artifact store.
    pub fn pjrt(store: &ArtifactStore, nx: usize, steps: usize) -> TaskResult<Backend> {
        Ok(Backend::Pjrt { artifact: store.stencil_path(nx, steps)?.to_path_buf() })
    }
}

/// Stencil run parameters. Paper cases (Table II):
/// * case A: 128 subdomains × 16000 points;
/// * case B: 256 subdomains × 8000 points;
/// both: 8192 iterations, 128 time steps per iteration.
#[derive(Clone)]
pub struct StencilParams {
    pub n_sub: usize,
    pub nx: usize,
    pub iterations: usize,
    /// Time steps advanced per task (= ghost cells per side).
    pub steps: usize,
    /// Courant number (c = 1 makes Lax-Wendroff an exact shift).
    pub courant: f64,
    pub mode: Mode,
    /// When set, every task is routed through the corresponding executor
    /// decorator instead of the per-call [`Mode`] free functions.
    pub resilience: Option<ExecPolicy>,
    /// When set, the DAG runs distributed: tasks are placed round-robin
    /// across the localities of a simulated cluster, the spec's fault
    /// schedule kills localities at deterministic task indices, and
    /// [`StencilParams::resilience`] (built over the cluster launcher)
    /// is what recovers the affected subdomains. [`Mode`] must be
    /// [`Mode::Pure`] on this route — per-call resilient functions are
    /// bound to a single runtime.
    pub cluster: Option<ClusterSpec>,
    pub backend: Backend,
    /// Exception-style failures: error-rate factor x, P = e^{-x}.
    pub error_rate: Option<f64>,
    /// Silent-corruption probability per task (checksum-detectable).
    pub silent_rate: Option<f64>,
    pub seed: u64,
    /// Barrier every `window` iterations to bound in-flight tasks.
    pub window: usize,
    /// Checksum validation tolerance.
    pub tol: f64,
}

impl StencilParams {
    /// Paper case A geometry, scaled by `scale` (1 = full paper size).
    pub fn case_a(scale: f64) -> Self {
        StencilParams {
            n_sub: 128,
            nx: 16_000,
            iterations: ((8192.0 * scale) as usize).max(1),
            steps: 128,
            courant: 0.9,
            mode: Mode::Pure,
            resilience: None,
            cluster: None,
            backend: Backend::Native,
            error_rate: None,
            silent_rate: None,
            seed: 0xA,
            window: 16,
            tol: 1e-6,
        }
    }

    /// Paper case B geometry, scaled by `scale`.
    pub fn case_b(scale: f64) -> Self {
        StencilParams {
            n_sub: 256,
            nx: 8_000,
            iterations: ((8192.0 * scale) as usize).max(1),
            steps: 128,
            seed: 0xB,
            ..Self::case_a(scale)
        }
    }

    /// A small configuration for tests and quick examples.
    pub fn tiny() -> Self {
        StencilParams {
            n_sub: 8,
            nx: 64,
            iterations: 10,
            steps: 4,
            courant: 1.0,
            mode: Mode::Pure,
            resilience: None,
            cluster: None,
            backend: Backend::Native,
            error_rate: None,
            silent_rate: None,
            seed: 0x7,
            window: 4,
            tol: 1e-6,
        }
    }

    /// Total number of top-level tasks the run launches.
    pub fn total_tasks(&self) -> usize {
        self.n_sub * self.iterations
    }
}

/// Per-locality placement/survival introspection for cluster runs.
#[derive(Debug, Clone)]
pub struct LocalityReport {
    pub id: usize,
    /// Task bodies this locality actually ran.
    pub tasks_executed: usize,
    /// Attempts rejected because the locality was dead.
    pub tasks_rejected: usize,
    /// Tracked tasks that died in this locality's queue when it was
    /// killed — each was re-materialized onto a survivor from its
    /// lineage record, so a lost task is recovered work, not a failure.
    pub tasks_lost: usize,
    pub alive_at_end: bool,
    /// The global task index at which the fault schedule killed it.
    pub killed_at_task: Option<usize>,
}

/// Outcome of a stencil run.
#[derive(Debug, Clone)]
pub struct StencilReport {
    pub mode: String,
    /// The substrate tasks ran on: `pool(N)` or `cluster(N)`.
    pub launcher: String,
    pub wall_secs: f64,
    pub tasks: usize,
    /// Subdomains in the final wavefront (the survival denominator).
    pub subdomains: usize,
    pub failures_injected: u64,
    pub silent_corruptions: u64,
    /// Tasks whose resilient launch ultimately failed (DAG poisoned).
    pub launch_errors: u64,
    /// Scheduled locality kills that actually fired.
    pub kills_applied: usize,
    /// Mean time from a kill firing to the next window barrier draining
    /// (the DAG has provably flowed past the fault), when kills fired.
    /// On the pool checkpoint route (no kills) it is the mean repair
    /// duration instead.
    pub recovery_latency_secs: Option<f64>,
    /// One entry per locality on the cluster route; empty on the pool
    /// route.
    pub localities: Vec<LocalityReport>,
    /// Work done beyond one execution per DAG node: on cluster routes,
    /// locality attempts (bodies executed + dead-locality rejections)
    /// in excess of the task count — replay retries, eager replicas,
    /// checkpoint repairs; on pool routes, extra task-body executions.
    pub tasks_reexecuted: u64,
    /// Snapshot-store traffic (all zeroes outside the checkpoint
    /// strategy): snapshots saved/restored, serialized bytes persisted,
    /// snapshots lost to locality death.
    pub snapshots: SnapshotCounts,
    pub final_checksum: f64,
}

impl StencilReport {
    /// Fraction of final-wavefront subdomains that survived (1.0 = no
    /// poisoned subdomains).
    pub fn survival_rate(&self) -> f64 {
        if self.subdomains == 0 {
            return 1.0;
        }
        (self.subdomains as u64).saturating_sub(self.launch_errors) as f64
            / self.subdomains as f64
    }
}

/// Run the stencil; returns the final global state and the report.
///
/// Single-runtime route: a run where *every* subdomain is poisoned
/// returns the first error (the run itself is broken). Cluster route:
/// total poisoning is a legitimate measured outcome of the fault
/// experiment (survival rate 0), so the report is always returned.
pub fn run(rt: &Runtime, params: &StencilParams) -> TaskResult<(Vec<f64>, StencilReport)> {
    assert!(params.steps <= params.nx, "ghost region larger than subdomain");
    // The checkpoint strategy owns its own window/snapshot/restart loop;
    // every other policy goes through the shared DAG loop below.
    if let Some(ExecPolicy::Checkpoint { every, backend }) = params.resilience {
        if params.window == 0 {
            return Err(TaskError::Runtime(
                "checkpoint:K needs window > 0: snapshots are taken at window barriers".into(),
            ));
        }
        return match &params.cluster {
            None => run_pool_ckpt(rt, params, every, backend),
            Some(spec) => run_cluster_ckpt(params, spec, every, backend),
        };
    }
    match &params.cluster {
        None => run_pool(rt, params),
        Some(spec) => run_cluster(params, spec),
    }
}

/// The single-runtime route (today's Table II / Fig 3 path).
fn run_pool(rt: &Runtime, params: &StencilParams) -> TaskResult<(Vec<f64>, StencilReport)> {
    let injector = FaultInjector::new(params.error_rate.unwrap_or(0.0), params.seed);
    let corruptor = SilentCorruptor::new(params.silent_rate, params.seed ^ 0xDEAD);
    let body_runs = Arc::new(AtomicU64::new(0));
    let domain = Domain::sine(params.n_sub, params.nx);
    let route: Option<BuiltExecutor> =
        params.resilience.map(|p| p.build(rt, "stencil", ADAPTIVE_FLOOR));

    let timer = Timer::start();
    let (final_domain, launch_errors, first_error) = run_dag(
        params,
        &domain,
        |_task_idx| {},
        |deps| launch_task(rt, params, &route, &injector, &corruptor, &body_runs, deps),
        || {},
    );
    let wall = timer.elapsed_secs();

    let report = StencilReport {
        mode: params
            .resilience
            .map(|p| p.label())
            .unwrap_or_else(|| params.mode.label()),
        launcher: route
            .as_ref()
            .map(|ex| ex.base_label())
            .unwrap_or_else(|| format!("pool({})", rt.workers())),
        wall_secs: wall,
        tasks: params.total_tasks(),
        subdomains: params.n_sub,
        failures_injected: injector.counters().injected(),
        silent_corruptions: corruptor.count(),
        launch_errors,
        kills_applied: 0,
        recovery_latency_secs: None,
        localities: Vec::new(),
        tasks_reexecuted: body_runs
            .load(Ordering::Relaxed)
            .saturating_sub(params.total_tasks() as u64),
        snapshots: SnapshotCounts::default(),
        final_checksum: final_domain.global_checksum(),
    };
    match first_error {
        Some(e) if launch_errors as usize == params.n_sub => Err(e),
        // Sharded gather: one copy task per subdomain on the run's own
        // pool; bit-identical to the serial gather.
        _ => Ok((final_domain.gather_on(rt), report)),
    }
}

/// The distributed route: the same DAG, every task launched through a
/// cluster-backed executor, with the spec's fault schedule applied at
/// deterministic task indices.
fn run_cluster(
    params: &StencilParams,
    spec: &ClusterSpec,
) -> TaskResult<(Vec<f64>, StencilReport)> {
    reject_per_call_modes_on_cluster(params)?;
    let injector = FaultInjector::new(params.error_rate.unwrap_or(0.0), params.seed);
    let corruptor = SilentCorruptor::new(params.silent_rate, params.seed ^ 0xDEAD);
    let body_runs = Arc::new(AtomicU64::new(0));
    let domain = Domain::sine(params.n_sub, params.nx);
    let cluster = spec.build();
    // `--resilience drain` recovers queued work through the lineage
    // drain alone, so new placements must avoid corpses entirely.
    let exec = if params.resilience.map(|p| p.routes_alive_only()).unwrap_or(false) {
        ClusterExecutor::alive_routed(&cluster)
    } else {
        ClusterExecutor::new(&cluster)
    };
    let route: BuiltExecutor<ClusterExecutor> = match params.resilience {
        Some(p) => p.build_over(exec, "stencil", ADAPTIVE_FLOOR),
        None => BuiltExecutor::Single(exec),
    };

    let mut schedule = spec.schedule.clone();
    let mut kills_applied: Vec<KillEvent> = Vec::new();
    // Kills awaiting their recovery-latency measurement (taken at the
    // next window barrier, when the wavefront containing the fault has
    // provably drained). RefCell: both the per-task hook and the barrier
    // hook touch it.
    let pending: std::cell::RefCell<Vec<Timer>> = std::cell::RefCell::new(Vec::new());
    let mut latencies: Vec<f64> = Vec::new();

    let timer = Timer::start();
    let (final_domain, launch_errors, _first_error) = run_dag(
        params,
        &domain,
        |task_idx| {
            for ev in schedule.advance(task_idx, &cluster) {
                kills_applied.push(ev);
                pending.borrow_mut().push(Timer::start());
            }
        },
        |deps| launch_via(&route, params, &injector, &corruptor, &body_runs, deps),
        || {
            for t in pending.borrow_mut().drain(..) {
                latencies.push(t.elapsed_secs());
            }
        },
    );
    // Kills in the final (un-barriered) window recover by the gather.
    for t in pending.borrow_mut().drain(..) {
        latencies.push(t.elapsed_secs());
    }
    let wall = timer.elapsed_secs();

    let localities = locality_reports(&cluster, &kills_applied);

    // Prefer the direct drain-to-reschedule measurement when a kill
    // actually drained queued tracked tasks; fall back to the
    // kill→barrier measure otherwise.
    let drain = cluster.drain_latency_secs();
    let recovery = if drain.is_empty() { mean_secs(&latencies) } else { mean_secs(&drain) };

    let report = StencilReport {
        mode: params
            .resilience
            .map(|p| p.label())
            .unwrap_or_else(|| params.mode.label()),
        launcher: route.base_label(),
        wall_secs: wall,
        tasks: params.total_tasks(),
        subdomains: params.n_sub,
        failures_injected: injector.counters().injected(),
        silent_corruptions: corruptor.count(),
        launch_errors,
        kills_applied: kills_applied.len(),
        recovery_latency_secs: recovery,
        tasks_reexecuted: cluster_reexecuted(&localities, params.total_tasks()),
        snapshots: SnapshotCounts::default(),
        localities,
        final_checksum: final_domain.global_checksum(),
    };
    // Serial gather on the cluster route: there is no single runtime to
    // shard onto (each locality owns its own pool), and the cluster-vs-
    // pool equivalence tests compare against the pool route's sharded
    // gather — identical bytes either way.
    Ok((final_domain.gather(), report))
}

/// The shared DAG loop: build the (subdomain, iteration) dataflow with
/// `launch` (called once per task), invoking `before_task` with the
/// global task index before each launch (the fault schedule's clock) and
/// `after_barrier` after each window barrier drains. Returns the final
/// domain (poisoned subdomains as zero placeholders), the poisoned
/// count, and the first error observed.
fn run_dag<S, L, B>(
    params: &StencilParams,
    domain: &Domain,
    mut before_task: S,
    mut launch: L,
    mut after_barrier: B,
) -> (Domain, u64, Option<TaskError>)
where
    S: FnMut(usize),
    L: FnMut(Vec<Future<Chunk>>) -> Future<Chunk>,
    B: FnMut(),
{
    let n_sub = params.n_sub;
    let mut futs: Vec<Future<Chunk>> = domain
        .subdomains
        .iter()
        .map(|c| Future::ready(Ok(c.clone())))
        .collect();
    // Cached wavefront buffer: the two vectors ping-pong across
    // iterations instead of allocating a fresh Vec per wavefront.
    let mut next: Vec<Future<Chunk>> = Vec::with_capacity(n_sub);

    for iter in 0..params.iterations {
        for j in 0..n_sub {
            before_task(iter * n_sub + j);
            let deps = vec![
                futs[(j + n_sub - 1) % n_sub].clone(),
                futs[j].clone(),
                futs[(j + 1) % n_sub].clone(),
            ];
            next.push(launch(deps));
        }
        std::mem::swap(&mut futs, &mut next);
        next.clear(); // release the previous wavefront's future handles
        if params.window > 0 && (iter + 1) % params.window == 0 {
            // Bound in-flight work: block until this wavefront is done.
            for f in &futs {
                f.wait();
            }
            after_barrier();
        }
    }

    let mut launch_errors = 0u64;
    let mut final_domain = Domain { n_sub: params.n_sub, nx: params.nx, subdomains: Vec::new() };
    let mut first_error: Option<TaskError> = None;
    for f in futs {
        match f.get() {
            Ok(chunk) => final_domain.subdomains.push(chunk),
            Err(e) => {
                // A poisoned subdomain (resilience exhausted): keep the
                // gather shape with a zero placeholder and count it.
                launch_errors += 1;
                if first_error.is_none() {
                    first_error = Some(e);
                }
                final_domain.subdomains.push(Chunk::new(vec![0.0; params.nx]));
            }
        }
    }
    (final_domain, launch_errors, first_error)
}

/// Mean of a latency sample, `None` when empty.
fn mean_secs(latencies: &[f64]) -> Option<f64> {
    if latencies.is_empty() {
        None
    } else {
        Some(latencies.iter().sum::<f64>() / latencies.len() as f64)
    }
}

/// Cluster-route re-execution accounting: locality attempts (bodies
/// executed + dead-locality rejections + in-queue deaths) in excess of
/// one per DAG node. Each lost task re-materializes on a survivor as a
/// fresh routing, so Σ(executed + rejected + lost) counts every routing.
fn cluster_reexecuted(localities: &[LocalityReport], tasks: usize) -> u64 {
    let attempts: usize = localities
        .iter()
        .map(|l| l.tasks_executed + l.tasks_rejected + l.tasks_lost)
        .sum();
    (attempts as u64).saturating_sub(tasks as u64)
}

/// Per-locality placement/survival breakdown of a finished cluster run
/// (shared by every cluster route so the report semantics cannot
/// diverge).
fn locality_reports(
    cluster: &crate::distributed::Cluster,
    kills_applied: &[KillEvent],
) -> Vec<LocalityReport> {
    (0..cluster.len())
        .map(|i| {
            let loc = cluster.locality(LocalityId(i));
            LocalityReport {
                id: i,
                tasks_executed: loc.tasks_executed(),
                tasks_rejected: loc.tasks_rejected(),
                tasks_lost: loc.tasks_lost(),
                alive_at_end: loc.is_alive(),
                killed_at_task: kills_applied.iter().find(|e| e.loc.0 == i).map(|e| e.step),
            }
        })
        .collect()
}

/// Shared guard of the cluster routes: per-call [`Mode`]s are bound to
/// a single runtime and cannot run distributed.
fn reject_per_call_modes_on_cluster(params: &StencilParams) -> TaskResult<()> {
    if params.mode != Mode::Pure {
        return Err(TaskError::Runtime(
            "cluster route ignores per-call modes: per-call resilient functions are bound \
             to a single runtime; select the policy with `resilience` instead"
                .into(),
        ));
    }
    Ok(())
}

/// The shared per-task kernel body: draw the fault injector, advance the
/// ghost-extended subdomain through the backend kernel, maybe corrupt
/// the output silently, and attach the checksum. `runs` counts every
/// invocation (the pool routes' re-execution accounting).
fn task_body(
    params: &StencilParams,
    injector: &FaultInjector,
    corruptor: &SilentCorruptor,
    runs: &Arc<AtomicU64>,
) -> impl Fn(&[Chunk]) -> TaskResult<Chunk> + Clone + Send + Sync + 'static {
    let steps = params.steps;
    let courant = params.courant;
    let backend = params.backend.clone();
    let injector = injector.clone();
    let corruptor = corruptor.clone();
    let runs = Arc::clone(runs);
    move |vals: &[Chunk]| -> TaskResult<Chunk> {
        runs.fetch_add(1, Ordering::Relaxed);
        injector.draw("stencil-task")?;
        let ext = build_extended(&vals[0], &vals[1], &vals[2], steps);
        let (mut out, cksum) = match &backend {
            Backend::Native => {
                // Hand the ghost-extended buffer over by value: the
                // kernel ping-pongs in place instead of re-copying it.
                let out = kernel::lax_wendroff_multistep_owned(ext, steps, courant);
                let ck = kernel::checksum(&out);
                (out, ck)
            }
            Backend::Pjrt { artifact } => {
                let c_arr = [courant];
                let mut vecs = crate::runtime::execute_f64(artifact, &[&ext, &c_arr])?;
                if vecs.len() != 2 || vecs[1].len() != 1 {
                    return Err(TaskError::Runtime(format!(
                        "stencil artifact returned unexpected shape: {:?}",
                        vecs.iter().map(|v| v.len()).collect::<Vec<_>>()
                    )));
                }
                let ck = vecs[1][0];
                (std::mem::take(&mut vecs[0]), ck)
            }
        };
        corruptor.maybe_corrupt(&mut out);
        Ok(Chunk::with_checksum(out, cksum))
    }
}

/// Launch one task through an executor route over any launcher — the
/// seam that makes the driver substrate-generic: the same call serves
/// the pool decorators and the cluster decorators.
fn launch_via<E: TaskLauncher>(
    route: &BuiltExecutor<E>,
    params: &StencilParams,
    injector: &FaultInjector,
    corruptor: &SilentCorruptor,
    runs: &Arc<AtomicU64>,
    deps: Vec<Future<Chunk>>,
) -> Future<Chunk> {
    let body = task_body(params, injector, corruptor, runs);
    let tol = params.tol;
    route.dataflow_validate(move |c: &Chunk| c.verify(tol), move |v: &[Chunk]| body(v), deps)
}

/// Launch one stencil task on the single runtime through the configured
/// API variant (or the executor route, when one is active).
#[allow(clippy::too_many_arguments)]
fn launch_task(
    rt: &Runtime,
    params: &StencilParams,
    route: &Option<BuiltExecutor>,
    injector: &FaultInjector,
    corruptor: &SilentCorruptor,
    runs: &Arc<AtomicU64>,
    deps: Vec<Future<Chunk>>,
) -> Future<Chunk> {
    // Executor-routed launches: the call is always the same dataflow;
    // the policy lives entirely in the executor.
    if let Some(ex) = route {
        return launch_via(ex, params, injector, corruptor, runs, deps);
    }

    let body = task_body(params, injector, corruptor, runs);
    let tol = params.tol;
    let validate = move |c: &Chunk| c.verify(tol);

    match params.mode {
        Mode::Pure => dataflow(rt, move |v: Vec<Chunk>| body(&v), deps),
        Mode::Replay { n } => dataflow_replay(rt, n, move |v: &[Chunk]| body(v), deps),
        Mode::ReplayChecksum { n } => {
            dataflow_replay_validate(rt, n, validate, move |v: &[Chunk]| body(v), deps)
        }
        Mode::Replicate { n } => dataflow_replicate(rt, n, move |v: &[Chunk]| body(v), deps),
        Mode::ReplicateChecksum { n } => {
            dataflow_replicate_validate(rt, n, validate, move |v: &[Chunk]| body(v), deps)
        }
        Mode::ReplicateVote { n } => {
            dataflow_replicate_vote(rt, n, vote_majority, move |v: &[Chunk]| body(v), deps)
        }
        Mode::ReplicateReplay { n, replays } => {
            dataflow_replicate_replay(rt, n, replays, move |v: &[Chunk]| body(v), deps)
        }
    }
}

// ---------------------------------------------------------------------
// The checkpoint/restart route (--resilience checkpoint:K)
// ---------------------------------------------------------------------

/// Snapshot key for the wavefront state of subdomain `j` after task
/// layer `iter` (`-1` = the initial state, persisted before the run so
/// the first period always has a durable restore base).
fn ckpt_key(iter: isize, j: usize) -> String {
    format!("ckpt_{iter}_{j}")
}

/// What one checkpointed DAG run produced.
struct CkptOutcome {
    domain: Domain,
    /// Final-wavefront subdomains still poisoned after repair (repair
    /// itself exhausted — e.g. every locality dead).
    launch_errors: u64,
    /// Wall time of each repair pass (pool-route recovery latency).
    repair_latencies: Vec<f64>,
}

/// The checkpointed DAG loop. Differences from [`run_dag`]:
///
/// * tasks at *snapshot layers* (every `every` windows, aligned to the
///   window barriers) launch through
///   [`CheckpointExecutor::dataflow_checkpointed_validate`], so their
///   validated results are persisted in-band (and a restart pass would
///   flow straight past them on store hits);
/// * the current window's futures are retained (the `grid`), and every
///   barrier runs a repair pass over them: exactly the *failed* tasks —
///   the failure cone — are re-executed, layer by layer, from
///   dependencies drawn from already-repaired values, surviving
///   results, and (for the window-entry layer) the snapshot store;
/// * `before_task` returns `true` when a fault event fired at that
///   launch index, which forces an *eager* barrier at the end of the
///   current layer — the failure-detector-triggered recovery that keeps
///   the cone from dilating across a whole window.
#[allow(clippy::too_many_arguments)]
fn run_ckpt_dag<E: TaskLauncher>(
    params: &StencilParams,
    every: usize,
    exec: &CheckpointExecutor<E>,
    domain: &Domain,
    injector: &FaultInjector,
    corruptor: &SilentCorruptor,
    body_runs: &Arc<AtomicU64>,
    mut before_task: impl FnMut(usize) -> bool,
    mut after_barrier: impl FnMut(),
) -> TaskResult<CkptOutcome> {
    let n_sub = params.n_sub;
    let window = params.window.max(1);
    let period = every.max(1) * window;
    let snaps = Arc::clone(exec.snapshots());
    let tol = params.tol;
    let validator: TaskValidator<Chunk> = Arc::new(move |c: &Chunk| c.verify(tol));
    let body = task_body(params, injector, corruptor, body_runs);
    let is_snap_layer = move |iter: isize| -> bool {
        iter == -1 || ((iter as usize) + 1) % period == 0
    };

    // Durable restore base for failures in the first period.
    for (j, c) in domain.subdomains.iter().enumerate() {
        snaps.save_value(&ckpt_key(-1, j), c)?;
    }

    // entry[j]: state at the layer just below the current window
    // (None = irreparably poisoned).
    let mut entry: Vec<Option<Chunk>> = domain.subdomains.iter().cloned().map(Some).collect();
    let mut futs: Vec<Future<Chunk>> =
        domain.subdomains.iter().map(|c| Future::ready(Ok(c.clone()))).collect();
    let mut grid: Vec<Vec<Future<Chunk>>> = Vec::new();
    let mut win_start: usize = 0;
    let mut force_barrier = false;
    let mut repair_latencies: Vec<f64> = Vec::new();

    for iter in 0..params.iterations {
        let mut next: Vec<Future<Chunk>> = Vec::with_capacity(n_sub);
        for j in 0..n_sub {
            if before_task(iter * n_sub + j) {
                force_barrier = true;
            }
            let deps = vec![
                futs[(j + n_sub - 1) % n_sub].clone(),
                futs[j].clone(),
                futs[(j + 1) % n_sub].clone(),
            ];
            let b = body.clone();
            let fut = if is_snap_layer(iter as isize) {
                exec.dataflow_checkpointed_validate(
                    &ckpt_key(iter as isize, j),
                    move |c: &Chunk| c.verify(tol),
                    move |v: &[Chunk]| b(v),
                    deps,
                )
            } else {
                exec.dataflow_validate(
                    move |c: &Chunk| c.verify(tol),
                    move |v: &[Chunk]| b(v),
                    deps,
                )
            };
            next.push(fut);
        }
        grid.push(next.clone());
        futs = next;

        let at_barrier =
            force_barrier || (iter + 1) % window == 0 || iter + 1 == params.iterations;
        if !at_barrier {
            continue;
        }
        force_barrier = false;
        for f in &futs {
            f.wait();
        }
        let any_failed = grid.iter().any(|layer| layer.iter().any(|f| f.get_copy().is_err()));
        if any_failed {
            let t = Timer::start();
            repair_window(
                params,
                exec,
                &snaps,
                &validator,
                &body,
                &mut grid,
                &entry,
                win_start,
                is_snap_layer,
            );
            repair_latencies.push(t.elapsed_secs());
            futs = grid.last().expect("barrier implies a launched layer").clone();
        }
        // Advance the entry wavefront and trim the window state.
        entry = futs.iter().map(|f| f.get_copy().ok()).collect();
        grid.clear();
        win_start = iter + 1;
        after_barrier();
    }

    let mut launch_errors = 0u64;
    let mut final_domain = Domain { n_sub, nx: params.nx, subdomains: Vec::new() };
    for f in futs {
        match f.get() {
            Ok(chunk) => final_domain.subdomains.push(chunk),
            Err(_) => {
                launch_errors += 1;
                final_domain.subdomains.push(Chunk::new(vec![0.0; params.nx]));
            }
        }
    }
    Ok(CkptOutcome { domain: final_domain, launch_errors, repair_latencies })
}

/// Repair one window in place: re-execute exactly the failed tasks,
/// layer by layer ascending. Dependencies for a repaired task at layer
/// `t` come from (in priority order) the repaired/surviving values of
/// layer `t-1`, and — for the window-entry layer — the snapshot store
/// first when that layer was checkpointed (this is where lost AGAS
/// snapshots bite: a lost entry snapshot falls back to the surviving
/// in-memory wavefront, and only if both are gone does the poison
/// stand). Repaired snapshot-layer results are re-persisted so the
/// snapshot set stays complete. Tasks whose dependencies are
/// irreparable keep their error — the poison is never papered over.
#[allow(clippy::too_many_arguments)]
fn repair_window<E: TaskLauncher>(
    params: &StencilParams,
    exec: &CheckpointExecutor<E>,
    snaps: &Arc<Snapshots>,
    validator: &TaskValidator<Chunk>,
    body: &(impl Fn(&[Chunk]) -> TaskResult<Chunk> + Clone + Send + Sync + 'static),
    grid: &mut [Vec<Future<Chunk>>],
    entry: &[Option<Chunk>],
    win_start: usize,
    is_snap_layer: impl Fn(isize) -> bool,
) {
    let n_sub = params.n_sub;
    let entry_iter = win_start as isize - 1;
    let entry_snapshotted = is_snap_layer(entry_iter);

    // Entry dependency state, restored lazily: only the slots a failed
    // first-layer task actually depends on are read back from the store
    // (the durable copy); everything else comes from the surviving
    // in-memory wavefront — so the `restored` count is real restore
    // traffic, not a blanket re-read.
    let mut needed = vec![false; n_sub];
    if let Some(layer) = grid.first() {
        for (j, f) in layer.iter().enumerate() {
            if f.get_copy().is_err() {
                needed[(j + n_sub - 1) % n_sub] = true;
                needed[j] = true;
                needed[(j + 1) % n_sub] = true;
            }
        }
    }
    let mut prev: Vec<Option<Chunk>> = (0..n_sub)
        .map(|j| {
            if entry_snapshotted && needed[j] {
                if let Some(c) =
                    snaps.restore_value::<Chunk>(&ckpt_key(entry_iter, j), Some(validator))
                {
                    return Some(c);
                }
                // Snapshot missing or lost: fall back to the surviving
                // in-memory wavefront below.
            }
            entry[j].clone()
        })
        .collect();

    let attempt = |deps: &[Chunk]| -> TaskResult<Chunk> {
        let b = body.clone();
        let d = deps.to_vec();
        match exec.base().submit(Arc::new(move || b(&d))).get() {
            Ok(c) if c.verify(params.tol) => Ok(c),
            Ok(_) => Err(TaskError::ValidationRejected),
            Err(e) => Err(e),
        }
    };

    for (t_rel, layer) in grid.iter_mut().enumerate() {
        let iter_t = (win_start + t_rel) as isize;
        let mut cur: Vec<Option<Chunk>> = layer.iter().map(|f| f.get_copy().ok()).collect();
        // Gather this layer's repair jobs, then launch them all before
        // collecting any: failed tasks within a layer are independent,
        // so their repairs run concurrently on the substrate instead of
        // serializing the recovery pass.
        let mut jobs: Vec<(usize, Vec<Chunk>)> = Vec::new();
        for j in 0..n_sub {
            if cur[j].is_some() {
                continue;
            }
            let deps = [
                prev[(j + n_sub - 1) % n_sub].clone(),
                prev[j].clone(),
                prev[(j + 1) % n_sub].clone(),
            ];
            if deps.iter().any(|d| d.is_none()) {
                continue; // upstream irreparable: the poison stands
            }
            jobs.push((j, deps.into_iter().flatten().collect()));
        }
        let inflight: Vec<Future<Chunk>> = jobs
            .iter()
            .map(|(_, deps)| {
                let b = body.clone();
                let d = deps.clone();
                exec.base().submit(Arc::new(move || b(&d)))
            })
            .collect();
        for ((j, deps), fut) in jobs.into_iter().zip(inflight) {
            let mut outcome = match fut.get() {
                Ok(c) if c.verify(params.tol) => Ok(c),
                Ok(_) => Err(TaskError::ValidationRejected),
                Err(e) => Err(e),
            };
            // Serial retries only for the (rare) repair that failed
            // again — e.g. an injected error striking the repair itself.
            for _ in 1..REPAIR_ATTEMPTS {
                if outcome.is_ok() {
                    break;
                }
                outcome = attempt(&deps);
            }
            match outcome {
                Ok(c) => {
                    if is_snap_layer(iter_t) {
                        let _ = snaps.save_value(&ckpt_key(iter_t, j), &c);
                    }
                    layer[j] = Future::ready(Ok(c.clone()));
                    cur[j] = Some(c);
                }
                Err(e) => {
                    layer[j] = Future::ready(Err(e));
                    // cur[j] stays None: dependents keep their poison.
                }
            }
        }
        prev = cur;
    }
}

/// Fresh per-run directory for the disk snapshot backend (unique even
/// across runs in one process, e.g. bench arms).
fn disk_snapshot_dir() -> PathBuf {
    crate::checkpoint::store::unique_temp_dir("rhpx_stencil_snap")
}

/// The pool checkpoint route: same substrate as [`run_pool`], but tasks
/// launch through a [`CheckpointExecutor`] and failed windows repair
/// from snapshots instead of retrying inline.
fn run_pool_ckpt(
    rt: &Runtime,
    params: &StencilParams,
    every: usize,
    backend: SnapshotBackend,
) -> TaskResult<(Vec<f64>, StencilReport)> {
    let (store, disk_dir): (Arc<dyn SnapshotStore>, Option<PathBuf>) = match backend {
        SnapshotBackend::Agas => {
            return Err(TaskError::Runtime(
                "--resilience checkpoint: the agas backend needs --cluster".into(),
            ))
        }
        SnapshotBackend::Disk => {
            let dir = disk_snapshot_dir();
            (Arc::new(DiskSnapshotStore::new(dir.clone())) as Arc<dyn SnapshotStore>, Some(dir))
        }
        SnapshotBackend::Auto | SnapshotBackend::Memory => {
            (Arc::new(MemorySnapshotStore::new()) as Arc<dyn SnapshotStore>, None)
        }
    };
    let injector = FaultInjector::new(params.error_rate.unwrap_or(0.0), params.seed);
    let corruptor = SilentCorruptor::new(params.silent_rate, params.seed ^ 0xDEAD);
    let body_runs = Arc::new(AtomicU64::new(0));
    let domain = Domain::sine(params.n_sub, params.nx);
    let exec = CheckpointExecutor::new(PoolExecutor::new(rt), store, "stencil");

    let timer = Timer::start();
    let outcome = run_ckpt_dag(
        params,
        every,
        &exec,
        &domain,
        &injector,
        &corruptor,
        &body_runs,
        |_| false,
        || {},
    );
    let wall = timer.elapsed_secs();
    // Temp-dir cleanup must also run when the DAG errored out (e.g. an
    // unwritable snapshot store), not just on success.
    if let Some(dir) = disk_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let out = outcome?;

    let report = StencilReport {
        mode: params.resilience.map(|p| p.label()).unwrap_or_default(),
        launcher: exec.base().base_label(),
        wall_secs: wall,
        tasks: params.total_tasks(),
        subdomains: params.n_sub,
        failures_injected: injector.counters().injected(),
        silent_corruptions: corruptor.count(),
        launch_errors: out.launch_errors,
        kills_applied: 0,
        recovery_latency_secs: mean_secs(&out.repair_latencies),
        localities: Vec::new(),
        tasks_reexecuted: body_runs
            .load(Ordering::Relaxed)
            .saturating_sub(params.total_tasks() as u64),
        snapshots: exec.snapshots().counts(),
        final_checksum: out.domain.global_checksum(),
    };
    Ok((out.domain.gather_on(rt), report))
}

/// The cluster checkpoint route: tasks place over *live* localities
/// only ([`ClusterExecutor::alive_routed`] — checkpointing consumes the
/// membership view instead of absorbing dead-locality attempts as
/// retries), the fault schedule's kills are propagated to the snapshot
/// store (loss-on-kill; the AGAS backend re-homes or drops replicas),
/// and killed subdomains restore from the last window snapshot with
/// only the delta tasks re-executed.
fn run_cluster_ckpt(
    params: &StencilParams,
    spec: &ClusterSpec,
    every: usize,
    backend: SnapshotBackend,
) -> TaskResult<(Vec<f64>, StencilReport)> {
    reject_per_call_modes_on_cluster(params)?;
    let injector = FaultInjector::new(params.error_rate.unwrap_or(0.0), params.seed);
    let corruptor = SilentCorruptor::new(params.silent_rate, params.seed ^ 0xDEAD);
    let body_runs = Arc::new(AtomicU64::new(0));
    let domain = Domain::sine(params.n_sub, params.nx);
    let cluster = spec.build();
    let (store, disk_dir): (Arc<dyn SnapshotStore>, Option<PathBuf>) = match backend {
        SnapshotBackend::Auto | SnapshotBackend::Agas => (
            Arc::new(AgasSnapshotStore::new(&cluster, AGAS_SNAPSHOT_REPLICAS))
                as Arc<dyn SnapshotStore>,
            None,
        ),
        SnapshotBackend::Memory => {
            (Arc::new(MemorySnapshotStore::new()) as Arc<dyn SnapshotStore>, None)
        }
        SnapshotBackend::Disk => {
            let dir = disk_snapshot_dir();
            (Arc::new(DiskSnapshotStore::new(dir.clone())) as Arc<dyn SnapshotStore>, Some(dir))
        }
    };
    let exec = CheckpointExecutor::new(ClusterExecutor::alive_routed(&cluster), store, "stencil");
    let snaps = Arc::clone(exec.snapshots());

    let mut schedule = spec.schedule.clone();
    let mut kills_applied: Vec<KillEvent> = Vec::new();
    let pending: std::cell::RefCell<Vec<Timer>> = std::cell::RefCell::new(Vec::new());
    let mut latencies: Vec<f64> = Vec::new();

    let timer = Timer::start();
    let outcome = run_ckpt_dag(
        params,
        every,
        &exec,
        &domain,
        &injector,
        &corruptor,
        &body_runs,
        |task_idx| {
            let fired = schedule.advance(task_idx, &cluster);
            for ev in &fired {
                kills_applied.push(*ev);
                pending.borrow_mut().push(Timer::start());
                // Loss-on-kill: replicas homed on the corpse are
                // re-homed (live sibling exists) or dropped and counted.
                snaps.on_locality_killed(ev.loc);
            }
            // A fired kill forces an eager barrier after this layer, so
            // recovery starts before the cone crosses the window.
            !fired.is_empty()
        },
        || {
            for t in pending.borrow_mut().drain(..) {
                latencies.push(t.elapsed_secs());
            }
        },
    );
    for t in pending.borrow_mut().drain(..) {
        latencies.push(t.elapsed_secs());
    }
    let wall = timer.elapsed_secs();
    // Temp-dir cleanup must also run when the DAG errored out.
    if let Some(dir) = disk_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let out = outcome?;

    let localities = locality_reports(&cluster, &kills_applied);

    let report = StencilReport {
        mode: params.resilience.map(|p| p.label()).unwrap_or_default(),
        launcher: exec.base().base_label(),
        wall_secs: wall,
        tasks: params.total_tasks(),
        subdomains: params.n_sub,
        failures_injected: injector.counters().injected(),
        silent_corruptions: corruptor.count(),
        launch_errors: out.launch_errors,
        kills_applied: kills_applied.len(),
        recovery_latency_secs: mean_secs(&latencies),
        tasks_reexecuted: cluster_reexecuted(&localities, params.total_tasks()),
        snapshots: exec.snapshots().counts(),
        localities,
        final_checksum: out.domain.global_checksum(),
    };
    Ok((out.domain.gather(), report))
}

/// Injects *silent* errors (now shared crate-wide from
/// [`crate::failure`]; re-exported here because the stencil surface has
/// always offered it as `stencil::SilentCorruptor`).
pub use crate::failure::SilentCorruptor;

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    fn clustered(spec: &str) -> StencilParams {
        StencilParams {
            cluster: Some(ClusterSpec::parse(spec).unwrap()),
            ..StencilParams::tiny()
        }
    }

    #[test]
    fn pure_run_is_exact_shift_at_unit_courant() {
        let rt = rt();
        let params = StencilParams::tiny(); // courant = 1.0
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.tasks, 80);
        assert_eq!(rep.subdomains, 8);
        assert_eq!(rep.survival_rate(), 1.0);
        assert_eq!(rep.launcher, "pool(2)");
        // total shift = iterations * steps cells
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn all_modes_agree_without_failures() {
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        for mode in [
            Mode::Replay { n: 3 },
            Mode::ReplayChecksum { n: 3 },
            Mode::Replicate { n: 2 },
            Mode::ReplicateChecksum { n: 2 },
            Mode::ReplicateVote { n: 3 },
            Mode::ReplicateReplay { n: 2, replays: 2 },
        ] {
            let params = StencilParams { mode, ..base.clone() };
            let (out, rep) = run(&rt, &params).unwrap();
            assert_eq!(rep.launch_errors, 0, "{mode:?}");
            assert_eq!(out, ref_out, "mode {mode:?} diverged");
        }
    }

    #[test]
    fn executor_routes_match_free_functions() {
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        for policy in [
            ExecPolicy::Replay { n: 3 },
            ExecPolicy::Replicate { n: 2 },
            ExecPolicy::Adaptive { ceiling: 8 },
            ExecPolicy::AdaptiveReplicate { ceiling: 4 },
        ] {
            let params = StencilParams { resilience: Some(policy), ..base.clone() };
            let (out, rep) = run(&rt, &params).unwrap();
            assert_eq!(rep.launch_errors, 0, "{policy:?}");
            assert_eq!(rep.mode, policy.label());
            assert_eq!(out, ref_out, "policy {policy:?} diverged");
        }
    }

    #[test]
    fn cluster_route_matches_pool_route_when_no_locality_dies() {
        // The distributed DAG is the same math: with no faults the
        // cluster gather must be bit-identical to the single-runtime
        // run, for the bare route and every decorator.
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, ref_rep) = run(&rt, &base).unwrap();
        for resilience in [
            None,
            Some(ExecPolicy::Replay { n: 3 }),
            Some(ExecPolicy::Replicate { n: 2 }),
            Some(ExecPolicy::AdaptiveReplicate { ceiling: 4 }),
            Some(ExecPolicy::Checkpoint { every: 1, backend: SnapshotBackend::Auto }),
        ] {
            let params = StencilParams { resilience, ..clustered("4") };
            let (out, rep) = run(&rt, &params).unwrap();
            assert_eq!(rep.launch_errors, 0, "{resilience:?}");
            assert_eq!(rep.launcher, "cluster(4)");
            assert_eq!(rep.kills_applied, 0);
            assert_eq!(rep.recovery_latency_secs, None);
            assert_eq!(rep.localities.len(), 4);
            assert!(rep.localities.iter().all(|l| l.alive_at_end));
            assert_eq!(out, ref_out, "cluster route diverged under {resilience:?}");
            assert_eq!(rep.final_checksum, ref_rep.final_checksum);
        }
    }

    #[test]
    fn cluster_task_placement_is_spread_across_localities() {
        let rt = rt();
        let (_, rep) = run(&rt, &clustered("4")).unwrap();
        // 80 tasks round-robin over 4 localities: every locality worked.
        let executed: Vec<usize> = rep.localities.iter().map(|l| l.tasks_executed).collect();
        assert_eq!(executed.iter().sum::<usize>(), 80);
        assert!(executed.iter().all(|&n| n > 0), "idle locality: {executed:?}");
    }

    #[test]
    fn cluster_kill_without_resilience_poisons_subdomains() {
        // The acceptance negative control: a locality dies at task 10
        // and nothing recovers — the failure cone must reach the final
        // wavefront as poisoned subdomains, and the run still reports
        // (total poisoning is a measured outcome, not a driver error).
        let rt = rt();
        let (_, rep) = run(&rt, &clustered("4:kill=10@2")).unwrap();
        assert_eq!(rep.kills_applied, 1);
        assert!(rep.launch_errors > 0, "dead locality must poison subdomains");
        assert!(rep.survival_rate() < 1.0);
        let dead = &rep.localities[2];
        assert!(!dead.alive_at_end);
        assert_eq!(dead.killed_at_task, Some(10));
        assert!(dead.tasks_rejected > 0, "routed tasks must have been rejected");
    }

    #[test]
    fn cluster_kill_with_replay_survives_locality_death() {
        // The acceptance scenario: same fault, replay(3) over the
        // 4-locality cluster — every retry leaves the locality that just
        // failed, so one death can never exhaust the budget and the
        // result is bit-identical to the single-runtime run.
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        let params = StencilParams {
            resilience: Some(ExecPolicy::Replay { n: 3 }),
            ..clustered("4:kill=10@2")
        };
        let (out, rep) = run(&rt, &params).unwrap();
        assert_eq!(rep.kills_applied, 1);
        assert_eq!(rep.launch_errors, 0, "replay must recover every subdomain");
        assert_eq!(rep.survival_rate(), 1.0);
        assert!(rep.recovery_latency_secs.is_some());
        assert_eq!(out, ref_out, "recovered run diverged from the fault-free run");
        assert!(!rep.localities[2].alive_at_end);
    }

    #[test]
    fn cluster_kill_with_adaptive_replicate_survives_locality_death() {
        // Adaptive replication width: the quiet-state width (2) already
        // places replicas on distinct localities, so one death never
        // takes out a whole launch; observed failures then widen later
        // launches instead of replaying them.
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        let params = StencilParams {
            resilience: Some(ExecPolicy::AdaptiveReplicate { ceiling: 4 }),
            ..clustered("4:kill=10@2")
        };
        let (out, rep) = run(&rt, &params).unwrap();
        assert_eq!(rep.launch_errors, 0, "replication must mask the dead locality");
        assert_eq!(rep.mode, "exec_adaptive_replicate(max 4)");
        assert_eq!(out, ref_out);
        // The policy observed the dead-locality failures.
        let snap = crate::perfcounters::global().snapshot();
        assert!(snap["/resilience/stencil/count/failures"] > 0);
    }

    #[test]
    fn cluster_route_rejects_per_call_modes() {
        let rt = rt();
        let params = StencilParams { mode: Mode::Replay { n: 3 }, ..clustered("2") };
        assert!(run(&rt, &params).is_err(), "per-call modes are pool-only");
    }

    #[test]
    fn adaptive_executor_recovers_from_injected_exceptions() {
        let rt = rt();
        let params = StencilParams {
            resilience: Some(ExecPolicy::Adaptive { ceiling: 10 }),
            error_rate: Some(2.0), // P ≈ 0.135 per task
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.failures_injected > 0);
        // P(floor consecutive fails) ≈ 0.135^5 per task leaves a tiny
        // exhaustion tail over 80 tasks; tolerate one poisoned cone and
        // only pin exactness on the (overwhelmingly common) clean runs.
        assert!(rep.launch_errors <= 1, "got {}", rep.launch_errors);
        if rep.launch_errors == 0 {
            let shift = (params.iterations * params.steps) as f64;
            let exact = domain.exact_sine_shifted(shift);
            for (a, b) in out.iter().zip(exact.iter()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
        // The policy observed the failures through its perfcounters.
        let snap = crate::perfcounters::global().snapshot();
        assert!(snap["/resilience/stencil/count/failures"] > 0);
        assert!(snap["/resilience/stencil/gauge/budget"] >= 5);
    }

    #[test]
    fn replicate_executor_route_catches_silent_corruption() {
        let rt = rt();
        let params = StencilParams {
            resilience: Some(ExecPolicy::Replicate { n: 8 }),
            silent_rate: Some(0.2),
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.silent_corruptions > 0, "corruptor must fire");
        assert_eq!(rep.launch_errors, 0);
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9, "corruption leaked into result");
        }
    }

    #[test]
    fn replay_recovers_from_injected_exceptions() {
        let rt = rt();
        let params = StencilParams {
            mode: Mode::Replay { n: 5 },
            error_rate: Some(2.0), // P ≈ 0.135 per task
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.failures_injected > 0);
        assert_eq!(rep.launch_errors, 0);
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn checksum_validation_catches_silent_corruption() {
        let rt = rt();
        let params = StencilParams {
            mode: Mode::ReplayChecksum { n: 8 },
            silent_rate: Some(0.2),
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.silent_corruptions > 0, "corruptor must fire");
        assert_eq!(rep.launch_errors, 0);
        // Despite corruption attempts, replay-on-validation-failure must
        // deliver the exact result.
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9, "corruption leaked into result");
        }
    }

    #[test]
    fn pure_mode_does_not_catch_silent_corruption() {
        // Negative control: without checksums the corruption lands.
        let rt = rt();
        let params = StencilParams {
            silent_rate: Some(0.5),
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.silent_corruptions > 0);
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        let max_err = out
            .iter()
            .zip(exact.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 0.1, "corruption should have survived: {max_err}");
    }

    #[test]
    fn conservation_invariant_under_replay() {
        let rt = rt();
        let params = StencilParams {
            mode: Mode::Replay { n: 5 },
            error_rate: Some(1.5),
            courant: 0.8, // non-exact path, still conservative
            ..StencilParams::tiny()
        };
        let (_, rep) = run(&rt, &params).unwrap();
        // sine over full periods sums to ~0, conserved by LW
        assert!(rep.final_checksum.abs() < 1e-8, "{}", rep.final_checksum);
    }

    // -- the checkpoint/restart route -----------------------------------

    fn ckpt(every: usize, backend: SnapshotBackend) -> Option<ExecPolicy> {
        Some(ExecPolicy::Checkpoint { every, backend })
    }

    #[test]
    fn pool_checkpoint_route_matches_pure_run_and_snapshots() {
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        for backend in [SnapshotBackend::Memory, SnapshotBackend::Disk] {
            let params = StencilParams { resilience: ckpt(2, backend), ..base.clone() };
            let (out, rep) = run(&rt, &params).unwrap();
            assert_eq!(rep.launch_errors, 0, "{backend:?}");
            assert_eq!(out, ref_out, "checkpoint route diverged under {backend:?}");
            assert_eq!(rep.tasks_reexecuted, 0, "fault-free run repairs nothing");
            // Initial wavefront (8) + the one in-range snapshot layer
            // (iter 7, period 8) for tiny geometry.
            assert_eq!(rep.snapshots.saved, 16, "{backend:?}");
            assert!(rep.snapshots.bytes > 0);
            assert_eq!(rep.snapshots.lost, 0);
            assert_eq!(rep.launcher, "pool(2)");
        }
        let labeled = StencilParams { resilience: ckpt(2, SnapshotBackend::Memory), ..base };
        assert_eq!(labeled.resilience.unwrap().label(), "exec_checkpoint(2,mem)");
    }

    #[test]
    fn pool_checkpoint_repairs_injected_exceptions_from_snapshots() {
        let rt = rt();
        let params = StencilParams {
            resilience: ckpt(1, SnapshotBackend::Memory),
            error_rate: Some(2.0), // P ≈ 0.135 per task
            // window 1: every barrier's entry layer is snapshotted, so
            // any failed task forces a restore from the store — the
            // `restored > 0` assertion below is deterministic.
            window: 1,
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.failures_injected > 0);
        // Repair retries make exhaustion a ~0.135^5 tail per repair; when
        // it does strike, the poisoned cone widens (dependents are never
        // papered over), so don't bound the count — pin exactness on the
        // (overwhelmingly common) clean runs instead.
        assert!(rep.tasks_reexecuted > 0, "failed tasks must be re-executed by repair");
        assert!(
            rep.snapshots.restored > 0,
            "repair must restore window-entry state from the store"
        );
        if rep.launch_errors == 0 {
            let shift = (params.iterations * params.steps) as f64;
            let exact = domain.exact_sine_shifted(shift);
            for (a, b) in out.iter().zip(exact.iter()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn pool_checkpoint_repairs_silent_corruption_via_validation() {
        let rt = rt();
        let params = StencilParams {
            resilience: ckpt(1, SnapshotBackend::Memory),
            silent_rate: Some(0.2),
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.silent_corruptions > 0, "corruptor must fire");
        // Exhausted repairs (a ~0.2^5 tail) widen the poisoned cone, so
        // the error count is unbounded in the rare case; exactness is
        // pinned on the common clean runs.
        if rep.launch_errors == 0 {
            let shift = (params.iterations * params.steps) as f64;
            let exact = domain.exact_sine_shifted(shift);
            for (a, b) in out.iter().zip(exact.iter()) {
                assert!((a - b).abs() < 1e-9, "corruption leaked into result");
            }
        }
    }

    #[test]
    fn cluster_kill_with_checkpoint_survives_with_less_reexecution_than_replay() {
        // The acceptance scenario: same kill, checkpoint:2 vs replay:3.
        // Checkpointing routes over live localities and repairs the
        // bounded in-flight cone from snapshots, so it must re-execute
        // strictly less work than replay (whose every post-kill launch
        // on the corpse burns an attempt).
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();

        let ck_params = StencilParams {
            resilience: ckpt(2, SnapshotBackend::Auto),
            ..clustered("4:kill=10@2")
        };
        let (ck_out, ck) = run(&rt, &ck_params).unwrap();
        assert_eq!(ck.kills_applied, 1);
        assert_eq!(ck.launch_errors, 0, "checkpoint must recover every subdomain");
        assert_eq!(ck.survival_rate(), 1.0);
        assert_eq!(ck_out, ref_out, "recovered run diverged from the fault-free run");
        assert_eq!(ck.mode, "exec_checkpoint(2)");
        assert!(!ck.localities[2].alive_at_end);
        assert!(ck.snapshots.saved > 0);
        assert_eq!(ck.snapshots.lost, 0, "replicated AGAS snapshots survive one kill");

        let rp_params = StencilParams {
            resilience: Some(ExecPolicy::Replay { n: 3 }),
            ..clustered("4:kill=10@2")
        };
        let (_, rp) = run(&rt, &rp_params).unwrap();
        assert_eq!(rp.launch_errors, 0);
        assert!(
            rp.tasks_reexecuted > 0,
            "replay must re-route post-kill attempts off the corpse"
        );
        assert!(
            ck.tasks_reexecuted < rp.tasks_reexecuted,
            "checkpoint ({}) must re-execute strictly less than replay ({})",
            ck.tasks_reexecuted,
            rp.tasks_reexecuted
        );
    }

    #[test]
    fn cluster_checkpoint_disk_backend_survives_kill() {
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        let params = StencilParams {
            resilience: ckpt(1, SnapshotBackend::Disk),
            ..clustered("4:kill=10@2")
        };
        let (out, rep) = run(&rt, &params).unwrap();
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(out, ref_out);
        assert_eq!(rep.mode, "exec_checkpoint(1,disk)");
        assert!(rep.snapshots.saved > 0);
        assert_eq!(rep.snapshots.lost, 0, "disk snapshots do not die with localities");
    }

    #[test]
    fn checkpoint_route_rejects_bad_configurations() {
        let rt = rt();
        // window = 0: no barriers to snapshot at.
        let params = StencilParams {
            resilience: ckpt(2, SnapshotBackend::Auto),
            window: 0,
            ..StencilParams::tiny()
        };
        assert!(run(&rt, &params).is_err(), "checkpoint needs window > 0");
        // agas backend without a cluster.
        let params = StencilParams {
            resilience: ckpt(2, SnapshotBackend::Agas),
            ..StencilParams::tiny()
        };
        assert!(run(&rt, &params).is_err(), "agas backend needs --cluster");
        // per-call modes stay rejected on the cluster checkpoint route.
        let params = StencilParams {
            resilience: ckpt(2, SnapshotBackend::Auto),
            mode: Mode::Replay { n: 3 },
            ..clustered("2")
        };
        assert!(run(&rt, &params).is_err());
    }
}
