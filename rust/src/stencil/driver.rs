//! The resilient 1D stencil driver (§V-B).
//!
//! Builds the dataflow DAG of the benchmark: one task per (subdomain,
//! iteration), each task depending on its own subdomain and its two
//! neighbors from the previous iteration, advancing `steps` time levels
//! per iteration through the ghost-region kernel. The launch API used
//! per task is selected by [`Mode`] — the exact configurations of
//! Table II and Fig 3 (pure dataflow / replay without and with checksums
//! / replicate), plus this repo's extensions.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::api::dataflow;
use crate::error::{TaskError, TaskResult};
use crate::failure::{FaultInjector, Rng};
use crate::future::Future;
use crate::metrics::Timer;
use crate::resilience::executor::BuiltExecutor;
use crate::resilience::{
    dataflow_replay, dataflow_replay_validate, dataflow_replicate, dataflow_replicate_replay,
    dataflow_replicate_validate, dataflow_replicate_vote, vote_majority,
};
use crate::runtime::ArtifactStore;
use crate::runtime_handle::Runtime;

use super::domain::{build_extended, Chunk, Domain};
use super::kernel;

/// Which launch API each stencil task uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Plain `dataflow` — Table II's "Pure Dataflow" baseline.
    Pure,
    /// `dataflow_replay(n)` — "Replay without checksums".
    Replay { n: usize },
    /// `dataflow_replay_validate(n, checksum)` — "Replay with checksums".
    ReplayChecksum { n: usize },
    /// `dataflow_replicate(n)` — "Replicate without checksums".
    Replicate { n: usize },
    /// `dataflow_replicate_validate(n, checksum)`.
    ReplicateChecksum { n: usize },
    /// `dataflow_replicate_vote(n, majority)` — silent-error consensus.
    ReplicateVote { n: usize },
    /// Replicate-of-replays extension (§Future-Work).
    ReplicateReplay { n: usize, replays: usize },
}

impl Mode {
    pub fn label(&self) -> String {
        match self {
            Mode::Pure => "pure_dataflow".into(),
            Mode::Replay { n } => format!("replay({n})"),
            Mode::ReplayChecksum { n } => format!("replay_checksum({n})"),
            Mode::Replicate { n } => format!("replicate({n})"),
            Mode::ReplicateChecksum { n } => format!("replicate_checksum({n})"),
            Mode::ReplicateVote { n } => format!("replicate_vote({n})"),
            Mode::ReplicateReplay { n, replays } => format!("replicate_replay({n},{replays})"),
        }
    }
}

/// Executor-routed resilience for the whole driver (CLI `--resilience`):
/// instead of selecting a resilient *call* per task ([`Mode`]), the
/// driver swaps in a resilient executor decorator and every task launch
/// goes through it unchanged — checksum validation included, so the
/// executor observes both thrown and silent errors. The adaptive
/// variant publishes perfcounters under `/resilience/stencil/...`.
pub use crate::resilience::executor::PolicySpec as ExecPolicy;

/// The adaptive route's minimum replay budget. Generous on purpose:
/// replay attempts cost nothing unless a task actually fails, and a low
/// floor would let early tasks exhaust before the policy has observed
/// anything. A user-requested ceiling below this still wins (the floor
/// is clamped to the ceiling in [`ExecPolicy::build`]).
const ADAPTIVE_FLOOR: usize = 5;

/// Which kernel executes the math.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust reference kernel.
    Native,
    /// The AOT JAX/Pallas artifact through PJRT (the production path).
    Pjrt { artifact: PathBuf },
}

impl Backend {
    /// Resolve the PJRT backend from an artifact store.
    pub fn pjrt(store: &ArtifactStore, nx: usize, steps: usize) -> TaskResult<Backend> {
        Ok(Backend::Pjrt { artifact: store.stencil_path(nx, steps)?.to_path_buf() })
    }
}

/// Stencil run parameters. Paper cases (Table II):
/// * case A: 128 subdomains × 16000 points;
/// * case B: 256 subdomains × 8000 points;
/// both: 8192 iterations, 128 time steps per iteration.
#[derive(Clone)]
pub struct StencilParams {
    pub n_sub: usize,
    pub nx: usize,
    pub iterations: usize,
    /// Time steps advanced per task (= ghost cells per side).
    pub steps: usize,
    /// Courant number (c = 1 makes Lax-Wendroff an exact shift).
    pub courant: f64,
    pub mode: Mode,
    /// When set, every task is routed through the corresponding executor
    /// decorator instead of the per-call [`Mode`] free functions.
    pub resilience: Option<ExecPolicy>,
    pub backend: Backend,
    /// Exception-style failures: error-rate factor x, P = e^{-x}.
    pub error_rate: Option<f64>,
    /// Silent-corruption probability per task (checksum-detectable).
    pub silent_rate: Option<f64>,
    pub seed: u64,
    /// Barrier every `window` iterations to bound in-flight tasks.
    pub window: usize,
    /// Checksum validation tolerance.
    pub tol: f64,
}

impl StencilParams {
    /// Paper case A geometry, scaled by `scale` (1 = full paper size).
    pub fn case_a(scale: f64) -> Self {
        StencilParams {
            n_sub: 128,
            nx: 16_000,
            iterations: ((8192.0 * scale) as usize).max(1),
            steps: 128,
            courant: 0.9,
            mode: Mode::Pure,
            resilience: None,
            backend: Backend::Native,
            error_rate: None,
            silent_rate: None,
            seed: 0xA,
            window: 16,
            tol: 1e-6,
        }
    }

    /// Paper case B geometry, scaled by `scale`.
    pub fn case_b(scale: f64) -> Self {
        StencilParams {
            n_sub: 256,
            nx: 8_000,
            iterations: ((8192.0 * scale) as usize).max(1),
            steps: 128,
            seed: 0xB,
            ..Self::case_a(scale)
        }
    }

    /// A small configuration for tests and quick examples.
    pub fn tiny() -> Self {
        StencilParams {
            n_sub: 8,
            nx: 64,
            iterations: 10,
            steps: 4,
            courant: 1.0,
            mode: Mode::Pure,
            resilience: None,
            backend: Backend::Native,
            error_rate: None,
            silent_rate: None,
            seed: 0x7,
            window: 4,
            tol: 1e-6,
        }
    }

    /// Total number of top-level tasks the run launches.
    pub fn total_tasks(&self) -> usize {
        self.n_sub * self.iterations
    }
}

/// Outcome of a stencil run.
#[derive(Debug, Clone)]
pub struct StencilReport {
    pub mode: String,
    pub wall_secs: f64,
    pub tasks: usize,
    pub failures_injected: u64,
    pub silent_corruptions: u64,
    /// Tasks whose resilient launch ultimately failed (DAG poisoned).
    pub launch_errors: u64,
    pub final_checksum: f64,
}

/// Run the stencil; returns the final global state and the report.
pub fn run(rt: &Runtime, params: &StencilParams) -> TaskResult<(Vec<f64>, StencilReport)> {
    assert!(params.steps <= params.nx, "ghost region larger than subdomain");
    let injector = FaultInjector::new(params.error_rate.unwrap_or(0.0), params.seed);
    let corruptor = SilentCorruptor::new(params.silent_rate, params.seed ^ 0xDEAD);
    let domain = Domain::sine(params.n_sub, params.nx);
    let route: Option<BuiltExecutor> =
        params.resilience.map(|p| p.build(rt, "stencil", ADAPTIVE_FLOOR));

    let timer = Timer::start();
    let mut futs: Vec<Future<Chunk>> = domain
        .subdomains
        .iter()
        .map(|c| Future::ready(Ok(c.clone())))
        .collect();

    let n_sub = params.n_sub;
    for iter in 0..params.iterations {
        let mut next: Vec<Future<Chunk>> = Vec::with_capacity(n_sub);
        for j in 0..n_sub {
            let deps = vec![
                futs[(j + n_sub - 1) % n_sub].clone(),
                futs[j].clone(),
                futs[(j + 1) % n_sub].clone(),
            ];
            next.push(launch_task(rt, params, &route, &injector, &corruptor, deps));
        }
        futs = next;
        if params.window > 0 && (iter + 1) % params.window == 0 {
            // Bound in-flight work: block until this wavefront is done.
            for f in &futs {
                f.wait();
            }
        }
    }

    let mut launch_errors = 0u64;
    let mut final_domain = Domain { n_sub: params.n_sub, nx: params.nx, subdomains: Vec::new() };
    let mut first_error: Option<TaskError> = None;
    for f in futs {
        match f.get() {
            Ok(chunk) => final_domain.subdomains.push(chunk),
            Err(e) => {
                // A poisoned subdomain (resilience exhausted): keep the
                // gather shape with a zero placeholder and count it.
                launch_errors += 1;
                if first_error.is_none() {
                    first_error = Some(e);
                }
                final_domain.subdomains.push(Chunk::new(vec![0.0; params.nx]));
            }
        }
    }
    let wall = timer.elapsed_secs();

    let report = StencilReport {
        mode: params
            .resilience
            .map(|p| p.label())
            .unwrap_or_else(|| params.mode.label()),
        wall_secs: wall,
        tasks: params.total_tasks(),
        failures_injected: injector.counters().injected(),
        silent_corruptions: corruptor.count(),
        launch_errors,
        final_checksum: final_domain.global_checksum(),
    };
    match first_error {
        Some(e) if launch_errors as usize == params.n_sub => Err(e),
        _ => Ok((final_domain.gather(), report)),
    }
}

/// Launch one stencil task through the configured API variant (or the
/// executor route, when one is active).
fn launch_task(
    rt: &Runtime,
    params: &StencilParams,
    route: &Option<BuiltExecutor>,
    injector: &FaultInjector,
    corruptor: &SilentCorruptor,
    deps: Vec<Future<Chunk>>,
) -> Future<Chunk> {
    let steps = params.steps;
    let courant = params.courant;
    let backend = params.backend.clone();
    let injector = injector.clone();
    let corruptor = corruptor.clone();
    let tol = params.tol;

    let body = move |vals: &[Chunk]| -> TaskResult<Chunk> {
        injector.draw("stencil-task")?;
        let ext = build_extended(&vals[0], &vals[1], &vals[2], steps);
        let (mut out, cksum) = match &backend {
            Backend::Native => {
                let out = kernel::lax_wendroff_multistep(&ext, steps, courant);
                let ck = kernel::checksum(&out);
                (out, ck)
            }
            Backend::Pjrt { artifact } => {
                let c_arr = [courant];
                let mut vecs = crate::runtime::execute_f64(artifact, &[&ext, &c_arr])?;
                if vecs.len() != 2 || vecs[1].len() != 1 {
                    return Err(TaskError::Runtime(format!(
                        "stencil artifact returned unexpected shape: {:?}",
                        vecs.iter().map(|v| v.len()).collect::<Vec<_>>()
                    )));
                }
                let ck = vecs[1][0];
                (std::mem::take(&mut vecs[0]), ck)
            }
        };
        corruptor.maybe_corrupt(&mut out);
        Ok(Chunk::with_checksum(out, cksum))
    };

    let validate = move |c: &Chunk| c.verify(tol);

    // Executor-routed launches: the call is always the same dataflow;
    // the policy lives entirely in the executor.
    if let Some(ex) = route {
        return ex.dataflow_validate(validate, move |v: &[Chunk]| body(v), deps);
    }

    match params.mode {
        Mode::Pure => dataflow(rt, move |v: Vec<Chunk>| body(&v), deps),
        Mode::Replay { n } => dataflow_replay(rt, n, move |v: &[Chunk]| body(v), deps),
        Mode::ReplayChecksum { n } => {
            dataflow_replay_validate(rt, n, validate, move |v: &[Chunk]| body(v), deps)
        }
        Mode::Replicate { n } => dataflow_replicate(rt, n, move |v: &[Chunk]| body(v), deps),
        Mode::ReplicateChecksum { n } => {
            dataflow_replicate_validate(rt, n, validate, move |v: &[Chunk]| body(v), deps)
        }
        Mode::ReplicateVote { n } => {
            dataflow_replicate_vote(rt, n, vote_majority, move |v: &[Chunk]| body(v), deps)
        }
        Mode::ReplicateReplay { n, replays } => {
            dataflow_replicate_replay(rt, n, replays, move |v: &[Chunk]| body(v), deps)
        }
    }
}

/// Injects *silent* errors: corrupts one element of a task's output
/// without updating the checksum, so only checksum validation (or
/// replica voting) can catch it.
#[derive(Clone)]
pub struct SilentCorruptor {
    injector: Option<FaultInjector>,
    count: Arc<AtomicU64>,
    seed: u64,
}

impl SilentCorruptor {
    pub fn new(probability: Option<f64>, seed: u64) -> Self {
        SilentCorruptor {
            injector: probability
                .filter(|p| *p > 0.0)
                .map(|p| FaultInjector::with_probability(p, seed)),
            count: Arc::new(AtomicU64::new(0)),
            seed,
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// With the configured probability, perturb one element.
    pub fn maybe_corrupt(&self, data: &mut [f64]) {
        let Some(inj) = &self.injector else { return };
        if data.is_empty() || !inj.should_fail() {
            return;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        let idx = Rng::seeded(self.seed ^ n).next_below(data.len() as u64) as usize;
        data[idx] += 1.0; // large, checksum-visible corruption
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    #[test]
    fn pure_run_is_exact_shift_at_unit_courant() {
        let rt = rt();
        let params = StencilParams::tiny(); // courant = 1.0
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert_eq!(rep.launch_errors, 0);
        assert_eq!(rep.tasks, 80);
        // total shift = iterations * steps cells
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn all_modes_agree_without_failures() {
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        for mode in [
            Mode::Replay { n: 3 },
            Mode::ReplayChecksum { n: 3 },
            Mode::Replicate { n: 2 },
            Mode::ReplicateChecksum { n: 2 },
            Mode::ReplicateVote { n: 3 },
            Mode::ReplicateReplay { n: 2, replays: 2 },
        ] {
            let params = StencilParams { mode, ..base.clone() };
            let (out, rep) = run(&rt, &params).unwrap();
            assert_eq!(rep.launch_errors, 0, "{mode:?}");
            assert_eq!(out, ref_out, "mode {mode:?} diverged");
        }
    }

    #[test]
    fn executor_routes_match_free_functions() {
        let rt = rt();
        let base = StencilParams::tiny();
        let (ref_out, _) = run(&rt, &base).unwrap();
        for policy in [
            ExecPolicy::Replay { n: 3 },
            ExecPolicy::Replicate { n: 2 },
            ExecPolicy::Adaptive { ceiling: 8 },
        ] {
            let params = StencilParams { resilience: Some(policy), ..base.clone() };
            let (out, rep) = run(&rt, &params).unwrap();
            assert_eq!(rep.launch_errors, 0, "{policy:?}");
            assert_eq!(rep.mode, policy.label());
            assert_eq!(out, ref_out, "policy {policy:?} diverged");
        }
    }

    #[test]
    fn adaptive_executor_recovers_from_injected_exceptions() {
        let rt = rt();
        let params = StencilParams {
            resilience: Some(ExecPolicy::Adaptive { ceiling: 10 }),
            error_rate: Some(2.0), // P ≈ 0.135 per task
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.failures_injected > 0);
        // P(floor consecutive fails) ≈ 0.135^5 per task leaves a tiny
        // exhaustion tail over 80 tasks; tolerate one poisoned cone and
        // only pin exactness on the (overwhelmingly common) clean runs.
        assert!(rep.launch_errors <= 1, "got {}", rep.launch_errors);
        if rep.launch_errors == 0 {
            let shift = (params.iterations * params.steps) as f64;
            let exact = domain.exact_sine_shifted(shift);
            for (a, b) in out.iter().zip(exact.iter()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
        // The policy observed the failures through its perfcounters.
        let snap = crate::perfcounters::global().snapshot();
        assert!(snap["/resilience/stencil/count/failures"] > 0);
        assert!(snap["/resilience/stencil/gauge/budget"] >= 5);
    }

    #[test]
    fn replicate_executor_route_catches_silent_corruption() {
        let rt = rt();
        let params = StencilParams {
            resilience: Some(ExecPolicy::Replicate { n: 8 }),
            silent_rate: Some(0.2),
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.silent_corruptions > 0, "corruptor must fire");
        assert_eq!(rep.launch_errors, 0);
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9, "corruption leaked into result");
        }
    }

    #[test]
    fn replay_recovers_from_injected_exceptions() {
        let rt = rt();
        let params = StencilParams {
            mode: Mode::Replay { n: 5 },
            error_rate: Some(2.0), // P ≈ 0.135 per task
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.failures_injected > 0);
        assert_eq!(rep.launch_errors, 0);
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn checksum_validation_catches_silent_corruption() {
        let rt = rt();
        let params = StencilParams {
            mode: Mode::ReplayChecksum { n: 8 },
            silent_rate: Some(0.2),
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.silent_corruptions > 0, "corruptor must fire");
        assert_eq!(rep.launch_errors, 0);
        // Despite corruption attempts, replay-on-validation-failure must
        // deliver the exact result.
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        for (a, b) in out.iter().zip(exact.iter()) {
            assert!((a - b).abs() < 1e-9, "corruption leaked into result");
        }
    }

    #[test]
    fn pure_mode_does_not_catch_silent_corruption() {
        // Negative control: without checksums the corruption lands.
        let rt = rt();
        let params = StencilParams {
            silent_rate: Some(0.5),
            ..StencilParams::tiny()
        };
        let domain = Domain::sine(params.n_sub, params.nx);
        let (out, rep) = run(&rt, &params).unwrap();
        assert!(rep.silent_corruptions > 0);
        let shift = (params.iterations * params.steps) as f64;
        let exact = domain.exact_sine_shifted(shift);
        let max_err = out
            .iter()
            .zip(exact.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 0.1, "corruption should have survived: {max_err}");
    }

    #[test]
    fn conservation_invariant_under_replay() {
        let rt = rt();
        let params = StencilParams {
            mode: Mode::Replay { n: 5 },
            error_rate: Some(1.5),
            courant: 0.8, // non-exact path, still conservative
            ..StencilParams::tiny()
        };
        let (_, rep) = run(&rt, &params).unwrap();
        // sine over full periods sums to ~0, conserved by LW
        assert!(rep.final_checksum.abs() < 1e-8, "{}", rep.final_checksum);
    }
}
