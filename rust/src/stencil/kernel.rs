//! Native (pure-Rust) Lax-Wendroff kernel — the reference implementation
//! the PJRT artifact is validated against, and the fast path for
//! overhead-focused benchmarks (the paper measures *runtime* overheads;
//! the kernel itself only sets the task grain).
//!
//! Linear advection `u_t + a u_x = 0` on a uniform grid; Lax-Wendroff:
//!
//! ```text
//! u_i' = u_i - (c/2)(u_{i+1} - u_{i-1}) + (c²/2)(u_{i+1} - 2 u_i + u_{i-1})
//! ```
//!
//! with Courant number `c = a·dt/dx`. A task advances `steps` time levels
//! over a subdomain extended with `steps` ghost cells per side ("reading
//! an extended ghost region of data values from each neighbor, which
//! helps reducing overheads and latency effects", §V-B): each level
//! consumes one ghost cell per side, so the output is exactly the
//! interior subdomain.

/// One Lax-Wendroff time level over the interior of `u` (drops one cell
/// per side). Writes into `out`, which must have length `u.len() - 2`.
#[inline]
pub fn lax_wendroff_step(u: &[f64], c: f64, out: &mut [f64]) {
    debug_assert_eq!(out.len() + 2, u.len());
    let half_c = 0.5 * c;
    let half_c2 = 0.5 * c * c;
    for i in 0..out.len() {
        let (um, u0, up) = (u[i], u[i + 1], u[i + 2]);
        out[i] = u0 - half_c * (up - um) + half_c2 * (up - 2.0 * u0 + um);
    }
}

/// Advance `steps` time levels over an extended subdomain of length
/// `nx + 2*steps`; returns the `nx` interior points.
pub fn lax_wendroff_multistep(extended: &[f64], steps: usize, c: f64) -> Vec<f64> {
    lax_wendroff_multistep_owned(extended.to_vec(), steps, c)
}

/// As [`lax_wendroff_multistep`], consuming the extended array and
/// reusing it as one of the ping-pong buffers — the stencil task body
/// already owns its ghost-extended wavefront buffer, so taking it by
/// value saves one full-array allocation + copy per task.
pub fn lax_wendroff_multistep_owned(extended: Vec<f64>, steps: usize, c: f64) -> Vec<f64> {
    assert!(extended.len() > 2 * steps, "extended region too small");
    let mut cur = extended;
    let mut next = vec![0.0; cur.len().saturating_sub(2)];
    for _ in 0..steps {
        next.resize(cur.len() - 2, 0.0);
        lax_wendroff_step(&cur, c, &mut next);
        std::mem::swap(&mut cur, &mut next);
    }
    cur
}

/// Checksum of a data block (plain sum, as in the Teranishi et al.
/// milestone the paper's stencil follows): recomputed by consumers to
/// detect silent corruption of task outputs.
#[inline]
pub fn checksum(data: &[f64]) -> f64 {
    data.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sine(n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| (2.0 * std::f64::consts::PI * i as f64 / n as f64).sin())
            .collect()
    }

    /// Extended array for a periodic domain: `steps` ghosts per side.
    fn extend_periodic(u: &[f64], ghost: usize) -> Vec<f64> {
        let n = u.len();
        let mut ext = Vec::with_capacity(n + 2 * ghost);
        for i in 0..ghost {
            ext.push(u[(n - ghost + i) % n]);
        }
        ext.extend_from_slice(u);
        for i in 0..ghost {
            ext.push(u[i % n]);
        }
        ext
    }

    #[test]
    fn unit_courant_is_exact_shift() {
        // With c = 1 Lax-Wendroff reduces to u_i' = u_{i-1}: an exact
        // one-cell shift per step.
        let n = 64;
        let u = sine(n);
        let steps = 5;
        let ext = extend_periodic(&u, steps);
        let out = lax_wendroff_multistep(&ext, steps, 1.0);
        assert_eq!(out.len(), n);
        for i in 0..n {
            let expect = u[(i + n - steps) % n];
            assert!(
                (out[i] - expect).abs() < 1e-12,
                "i={i}: {} vs {}",
                out[i],
                expect
            );
        }
    }

    #[test]
    fn single_step_matches_formula() {
        let u = [1.0, 2.0, 4.0];
        let c = 0.5;
        let mut out = [0.0];
        lax_wendroff_step(&u, c, &mut out);
        let expect = 2.0 - 0.25 * (4.0 - 1.0) + 0.125 * (4.0 - 4.0 + 1.0);
        assert!((out[0] - expect).abs() < 1e-15);
    }

    #[test]
    fn multistep_equals_repeated_single_steps() {
        let ext = sine(32);
        let a = lax_wendroff_multistep(&ext, 3, 0.8);
        // manual: three applications
        let mut cur = ext.to_vec();
        for _ in 0..3 {
            let mut next = vec![0.0; cur.len() - 2];
            lax_wendroff_step(&cur, 0.8, &mut next);
            cur = next;
        }
        assert_eq!(a, cur);
    }

    #[test]
    fn second_order_convergence() {
        // Halving dx (with fixed c, so dt halves too) should shrink the
        // error by ~4x for this smooth profile over a fixed time window.
        let c = 0.5;
        let err = |n: usize| -> f64 {
            // advance T = n_steps*dt where n_steps scales with n to fix
            // physical time: steps = n/4 cells of travel at c=0.5 means
            // shift = steps*c cells.
            let steps = n / 8;
            let u = sine(n);
            let ext = extend_periodic(&u, steps);
            let out = lax_wendroff_multistep(&ext, steps, c);
            // exact: shift by c*steps cells (fractional): u0(x - a t)
            let shift = c * steps as f64;
            (0..n)
                .map(|i| {
                    let x = i as f64 - shift;
                    let exact = (2.0 * std::f64::consts::PI * x / n as f64).sin();
                    (out[i] - exact).powi(2)
                })
                .sum::<f64>()
                .sqrt()
                / (n as f64).sqrt()
        };
        let e1 = err(64);
        let e2 = err(128);
        // N doubles, steps double: fixed physical window in grid units
        // relative to wavelength. Expect ratio ≈ 4 (2nd order); accept ≥ 3.
        assert!(e1 / e2 > 3.0, "e1={e1:.3e} e2={e2:.3e} ratio={}", e1 / e2);
    }

    #[test]
    fn checksum_sums() {
        assert_eq!(checksum(&[1.0, 2.0, 3.5]), 6.5);
        assert_eq!(checksum(&[]), 0.0);
    }

    #[test]
    #[should_panic(expected = "extended region too small")]
    fn rejects_undersized_extension() {
        lax_wendroff_multistep(&[1.0, 2.0], 1, 0.5);
    }
}
