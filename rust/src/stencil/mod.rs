//! The 1D stencil benchmark application (§V-B).
//!
//! A linear-advection solver decomposed into subdomains, advanced by a
//! multi-timestep Lax-Wendroff ghost-region kernel, with one dataflow
//! task per (subdomain, iteration) — each task depending on its own and
//! both neighboring subdomains from the previous iteration. This is the
//! application whose resilient variants produce Table II and Fig 3.
//!
//! * [`kernel`] — the native Rust reference kernel (validated against the
//!   JAX/Pallas oracle and the PJRT artifact);
//! * [`domain`] — decomposition, chunks-with-checksums, exact solutions;
//! * [`driver`] — the dataflow driver with per-task resiliency modes,
//!   executor-routed resilience ([`ExecPolicy`]), and the distributed
//!   route ([`StencilParams::cluster`]): the same DAG over a simulated
//!   cluster with a deterministic locality-kill schedule — the paper's
//!   "task survives locality death" scenario (Fig 4–5).

pub mod domain;
pub mod driver;
pub mod kernel;

pub use crate::distributed::{ClusterSpec, FaultSchedule, KillEvent};
pub use crate::resilience::checkpoint::SnapshotCounts;
pub use crate::resilience::executor::SnapshotBackend;
pub use domain::{build_extended, Chunk, Domain};
pub use driver::{
    run, Backend, ExecPolicy, LocalityReport, Mode, SilentCorruptor, StencilParams,
    StencilReport,
};
