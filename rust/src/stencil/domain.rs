//! Domain decomposition for the 1D periodic advection problem.

use std::sync::Arc;

/// A subdomain's worth of state plus its checksum — the unit of data
/// flowing through the stencil DAG. `data` is shared (`Arc`) so dataflow
/// dependencies clone cheaply; `checksum` travels with the data so
/// consumers (and the `_validate` API variants) can detect silent
/// corruption without rescanning the producer's memory.
#[derive(Debug, Clone)]
pub struct Chunk {
    pub data: Arc<Vec<f64>>,
    pub checksum: f64,
}

impl PartialEq for Chunk {
    /// Equality on the *data* (used by majority voting over replicas).
    fn eq(&self, other: &Self) -> bool {
        self.data == other.data
    }
}

impl Chunk {
    pub fn new(data: Vec<f64>) -> Self {
        let checksum = super::kernel::checksum(&data);
        Chunk { data: Arc::new(data), checksum }
    }

    /// A chunk with an explicit (possibly stale) checksum — used by the
    /// silent-corruption injector, which alters data *without* fixing
    /// the checksum.
    pub fn with_checksum(data: Vec<f64>, checksum: f64) -> Self {
        Chunk { data: Arc::new(data), checksum }
    }

    /// True if the checksum matches the data (the `_validate` predicate).
    pub fn verify(&self, tol: f64) -> bool {
        (super::kernel::checksum(&self.data) - self.checksum).abs() <= tol
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Snapshot encoding: `[checksum: 8 bytes LE][data: len × 8 bytes LE]`.
/// The *stored* checksum travels verbatim — a chunk persisted with a
/// stale checksum deserializes with that same stale checksum, so
/// [`Chunk::verify`] stays meaningful across a snapshot round trip (the
/// checkpoint layer validates before persisting *and* after restoring).
impl crate::checkpoint::SnapshotData for Chunk {
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.data.len() * 8);
        out.extend_from_slice(&self.checksum.to_le_bytes());
        for v in self.data.iter() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    fn from_bytes(bytes: &[u8]) -> Option<Self> {
        let checksum = f64::from_le_bytes(bytes.get(..8)?.try_into().expect("8 bytes"));
        let data = <Vec<f64> as crate::checkpoint::SnapshotData>::from_bytes(&bytes[8..])?;
        Some(Chunk::with_checksum(data, checksum))
    }
}

/// The decomposed global domain.
#[derive(Debug, Clone)]
pub struct Domain {
    /// Number of subdomains.
    pub n_sub: usize,
    /// Points per subdomain.
    pub nx: usize,
    /// Per-subdomain state.
    pub subdomains: Vec<Chunk>,
}

impl Domain {
    /// Initialize with a sine profile over the global periodic domain
    /// (smooth, so Lax-Wendroff's 2nd-order accuracy is observable and
    /// the exact solution is a pure shift).
    pub fn sine(n_sub: usize, nx: usize) -> Self {
        let total = n_sub * nx;
        let mut subdomains = Vec::with_capacity(n_sub);
        for j in 0..n_sub {
            let data: Vec<f64> = (0..nx)
                .map(|i| {
                    let g = (j * nx + i) as f64;
                    (2.0 * std::f64::consts::PI * g / total as f64).sin()
                })
                .collect();
            subdomains.push(Chunk::new(data));
        }
        Domain { n_sub, nx, subdomains }
    }

    /// Total points.
    pub fn total_points(&self) -> usize {
        self.n_sub * self.nx
    }

    /// Gather all subdomains into one global vector.
    pub fn gather(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.total_points());
        for c in &self.subdomains {
            out.extend_from_slice(&c.data);
        }
        out
    }

    /// Sharded gather: one copy task per subdomain, each writing its
    /// disjoint destination range of the preallocated output in parallel
    /// on `rt`. Bit-identical to [`Domain::gather`] — the same bytes land
    /// at the same offsets, only on more threads — and falls back to the
    /// serial gather when the domain is too small (or the pool too
    /// narrow) to amortize the task launches.
    pub fn gather_on(&self, rt: &crate::runtime_handle::Runtime) -> Vec<f64> {
        /// Below this many total points the memcpy is cheaper than the
        /// launches (a shard is ~one task per ~256 KiB at paper sizes).
        const SHARD_MIN_POINTS: usize = 1 << 15;
        let total: usize = self.subdomains.iter().map(|c| c.len()).sum();
        if rt.workers() < 2 || total < SHARD_MIN_POINTS || self.subdomains.len() < 2 {
            return self.gather();
        }
        let mut out = vec![0.0f64; total];
        struct SendPtr(*mut f64);
        // SAFETY: raw pointer to a range only its one task writes.
        unsafe impl Send for SendPtr {}
        let base = out.as_mut_ptr();
        let mut offset = 0usize;
        let mut copies = Vec::with_capacity(self.subdomains.len());
        for c in &self.subdomains {
            let len = c.len();
            let dst = SendPtr(unsafe { base.add(offset) });
            let chunk = c.clone(); // Arc clone: no data copy
            copies.push(crate::api::async_(rt, move || {
                let dst = dst;
                // SAFETY: this task is the sole writer of
                // [offset, offset + len), and `out` outlives the join
                // below.
                unsafe { std::ptr::copy_nonoverlapping(chunk.data.as_ptr(), dst.0, len) };
            }));
            offset += len;
        }
        let mut ok = true;
        for f in copies {
            ok &= f.get().is_ok();
        }
        if !ok {
            // A copy task failed (cannot happen short of a panic in the
            // runtime itself): recompute serially rather than return a
            // partially-written buffer.
            return self.gather();
        }
        out
    }

    /// Global checksum (sum over all points). For periodic linear
    /// advection, Lax-Wendroff conserves this exactly up to rounding —
    /// the whole-run conservation invariant the integration tests check.
    pub fn global_checksum(&self) -> f64 {
        self.subdomains.iter().map(|c| super::kernel::checksum(&c.data)).sum()
    }

    /// The exact solution after the profile has advected by `shift_cells`
    /// grid cells (may be fractional).
    pub fn exact_sine_shifted(&self, shift_cells: f64) -> Vec<f64> {
        let total = self.total_points();
        (0..total)
            .map(|i| {
                let x = i as f64 - shift_cells;
                (2.0 * std::f64::consts::PI * x / total as f64).sin()
            })
            .collect()
    }
}

/// Build the extended array for subdomain `j` from its three dependency
/// chunks `[left, center, right]`: the last `ghost` cells of `left`, all
/// of `center`, the first `ghost` cells of `right`.
pub fn build_extended(left: &Chunk, center: &Chunk, right: &Chunk, ghost: usize) -> Vec<f64> {
    assert!(ghost <= left.len() && ghost <= right.len(), "ghost exceeds neighbor size");
    let mut ext = Vec::with_capacity(center.len() + 2 * ghost);
    ext.extend_from_slice(&left.data[left.len() - ghost..]);
    ext.extend_from_slice(&center.data);
    ext.extend_from_slice(&right.data[..ghost]);
    ext
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_checksum_and_verify() {
        let c = Chunk::new(vec![1.0, 2.0, 3.0]);
        assert_eq!(c.checksum, 6.0);
        assert!(c.verify(1e-12));
        let bad = Chunk::with_checksum(vec![1.0, 2.0, 3.0], 99.0);
        assert!(!bad.verify(1e-6));
    }

    #[test]
    fn sine_domain_is_periodic_and_zero_sum() {
        let d = Domain::sine(4, 32);
        assert_eq!(d.total_points(), 128);
        assert_eq!(d.gather().len(), 128);
        // sine over a full period sums to ~0
        assert!(d.global_checksum().abs() < 1e-10);
    }

    #[test]
    fn build_extended_wraps_neighbors() {
        let l = Chunk::new(vec![1.0, 2.0, 3.0]);
        let c = Chunk::new(vec![4.0, 5.0, 6.0]);
        let r = Chunk::new(vec![7.0, 8.0, 9.0]);
        let ext = build_extended(&l, &c, &r, 2);
        assert_eq!(ext, vec![2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn exact_shift_zero_is_initial() {
        let d = Domain::sine(2, 16);
        let exact = d.exact_sine_shifted(0.0);
        let init = d.gather();
        for (a, b) in exact.iter().zip(init.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn gather_on_matches_serial_gather_bit_identically() {
        let rt = crate::runtime_handle::Runtime::builder().workers(2).build();
        // Large enough to take the sharded path (≥ 2^15 points).
        let d = Domain::sine(16, 4096);
        assert_eq!(d.gather_on(&rt), d.gather());
        // Small domains take the serial path; still identical.
        let tiny = Domain::sine(4, 16);
        assert_eq!(tiny.gather_on(&rt), tiny.gather());
    }

    #[test]
    fn chunk_snapshot_roundtrip_preserves_data_and_checksum() {
        use crate::checkpoint::SnapshotData;
        let c = Chunk::new(vec![1.5, -2.0, 3.25]);
        let back = Chunk::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(back.data, c.data);
        assert_eq!(back.checksum, c.checksum);
        assert!(back.verify(1e-12));
        // A stale checksum survives the round trip and stays detectable.
        let stale = Chunk::with_checksum(vec![1.0, 2.0], 99.0);
        let back = Chunk::from_bytes(&stale.to_bytes()).unwrap();
        assert_eq!(back.checksum, 99.0);
        assert!(!back.verify(1e-6));
        assert_eq!(Chunk::from_bytes(&[0u8; 4]), None, "truncated header rejected");
    }

    #[test]
    fn chunk_equality_is_data_equality() {
        let a = Chunk::new(vec![1.0, 2.0]);
        let b = Chunk::with_checksum(vec![1.0, 2.0], 999.0);
        assert_eq!(a, b); // checksum not part of identity
    }
}
