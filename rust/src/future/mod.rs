//! Lightweight futures and promises — the HPX synchronization substrate.
//!
//! HPX component (1): "futures, channels and other asynchronization
//! primitives". These are *eager, runtime-scheduled* futures in the HPX /
//! C++ `std::future` tradition, not Rust `async` futures: a [`Promise`]
//! owns the write side of a shared state, a [`Future`] the read side;
//! continuations attached with [`Future::then`] run on the scheduler as
//! soon as the value is set, and [`Future::get`] blocks — cooperatively
//! helping the pool run other tasks when called from a worker thread, so
//! waiting inside a task can never deadlock the pool.
//!
//! The shared state is a lock-free atomic state machine — no
//! `Mutex`/`Condvar` pair, one allocation per future:
//!
//! ```text
//!             attach: CAS node onto list            set(): swap
//!   EMPTY ──────────────────────────► (cont list) ─────────────┐
//!     │ set(): swap                                            ▼
//!     └───────────────────────────────────────────────────► NOTIFY
//!        value written; continuations fire (no lock held)      │
//!                                              store(READY) ◄──┘
//!   READY ──CAS──► BUSY ──► TAKEN          (value readable; new
//!     (take/`into_result` in flight)        continuations run inline)
//! ```
//!
//! The single `state` word is either a small tag (`EMPTY`/`READY`/
//! `TAKEN`/`BUSY`/`NOTIFY`) or a pointer to the head of the pending
//! continuation list (nodes are 8-byte aligned, so tags and pointers
//! never collide). Continuations *always* fire outside any critical
//! section — a continuation may freely attach further continuations to
//! the same future (the old mutex implementation self-deadlocked here;
//! see the `on_ready_inline_can_attach_more_continuations` regression
//! test). Blocking waiters materialize lazily: a blocked `get` attaches a
//! park/unpark continuation for its own thread — futures that are never
//! blocked on never pay for a condvar.
//!
//! Paper mapping: HPX runtime substrate; `when_all` is the
//! synchronization under every §V-B stencil dataflow task.

mod channel;
mod when_all;

pub use channel::{channel, Receiver, Sender};
pub use when_all::{collapse_results, when_all, when_all_results};

use std::cell::UnsafeCell;
use std::mem::ManuallyDrop;
use std::ptr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{TaskError, TaskResult};
use crate::scheduler::{current_worker, Pool};

/// Pending, no value, no continuations.
const EMPTY: usize = 0;
/// Value present and consumable.
const READY: usize = 1;
/// Value consumed by `into_result`/`try_take`.
const TAKEN: usize = 2;
/// Transient: a taker holds exclusive access to the value.
const BUSY: usize = 3;
/// Transient: value written, the setter is still firing the pending
/// continuation list. Readable (readers protocol) but not yet takeable.
const NOTIFY: usize = 4;
/// Values >= this are continuation-list head pointers (nodes are
/// 8-byte aligned).
const PTR_MIN: usize = 8;

/// Type-erased continuation node: a single allocation holding the
/// closure inline, dispatched through one fn pointer (no nested
/// `Box<dyn FnOnce>`).
#[repr(C, align(8))]
struct Node<T> {
    next: *mut Node<T>,
    /// `Some(v)`: consume the node and run the closure with the value.
    /// `None`: consume the node and drop the closure unrun.
    run: unsafe fn(*mut Node<T>, Option<&TaskResult<T>>),
}

#[repr(C)]
struct FullNode<T, F> {
    base: Node<T>,
    f: ManuallyDrop<F>,
}

unsafe fn run_node<T, F: FnOnce(&TaskResult<T>)>(n: *mut Node<T>, v: Option<&TaskResult<T>>) {
    let mut boxed = Box::from_raw(n as *mut FullNode<T, F>);
    let f = ManuallyDrop::take(&mut boxed.f);
    drop(boxed);
    if let Some(v) = v {
        f(v);
    }
}

fn new_node<T, F: FnOnce(&TaskResult<T>)>(f: F) -> *mut Node<T> {
    Box::into_raw(Box::new(FullNode {
        base: Node { next: ptr::null_mut(), run: run_node::<T, F> },
        f: ManuallyDrop::new(f),
    })) as *mut Node<T>
}

/// Reclaim a node whose CAS never published it, recovering the closure
/// (the caller still knows the concrete `F`).
unsafe fn unpublish_node<T, F: FnOnce(&TaskResult<T>)>(n: *mut Node<T>) -> F {
    let mut boxed = Box::from_raw(n as *mut FullNode<T, F>);
    ManuallyDrop::take(&mut boxed.f)
}

/// Bounded spin, then yield: the transient states waited on (`NOTIFY`
/// while a setter fires arbitrary continuations, `BUSY` while a taker
/// moves the value) can run user code, so pure `spin_loop` would burn a
/// whole scheduling quantum on a single-vCPU host while starving the
/// only thread able to make progress.
#[inline]
fn spin_or_yield(spins: &mut u32) {
    if *spins < 64 {
        *spins += 1;
        std::hint::spin_loop();
    } else {
        std::thread::yield_now();
    }
}

struct Shared<T> {
    /// Tag or continuation-list head (see module docs).
    state: AtomicUsize,
    /// Threads currently borrowing `value` (inline continuations,
    /// `get_copy`). Takers wait for this to drain after claiming `BUSY`.
    readers: AtomicUsize,
    value: UnsafeCell<Option<TaskResult<T>>>,
}

// SAFETY: `value` is only written by the single setter (before
// publication) and moved out by the single CAS-winning taker after
// `readers` drains; shared reads hold a `readers` registration that
// takers wait on. Continuation closures are `Send`.
unsafe impl<T: Send> Send for Shared<T> {}
unsafe impl<T: Send> Sync for Shared<T> {}

impl<T> Shared<T> {
    fn new() -> Arc<Self> {
        Arc::new(Shared {
            state: AtomicUsize::new(EMPTY),
            readers: AtomicUsize::new(0),
            value: UnsafeCell::new(None),
        })
    }

    fn new_ready(value: TaskResult<T>) -> Arc<Self> {
        Arc::new(Shared {
            state: AtomicUsize::new(READY),
            readers: AtomicUsize::new(0),
            value: UnsafeCell::new(Some(value)),
        })
    }

    /// True once a value (or error) has been published for consumption.
    /// `NOTIFY` counts: the value exists and blocked waiters woken by a
    /// firing continuation must be able to proceed into `take`/`clone`
    /// (which serialize against the `NOTIFY`→`READY` hand-off).
    fn produced(&self) -> bool {
        // Acquire: whoever sees a produced tag also sees the value write
        // (published by the setter's AcqRel swap / release store).
        matches!(self.state.load(Ordering::Acquire), READY | TAKEN | BUSY | NOTIFY)
    }

    /// Publish the value: write it, swap the pending continuation list
    /// out, fire every continuation *outside any critical section*, then
    /// open the state for consumption. Continuations that attach while we
    /// fire observe `NOTIFY` and run inline (the value is already
    /// readable), so no continuation is ever lost or deferred.
    fn set(&self, value: TaskResult<T>) {
        // Double-set guard. Not atomic w.r.t. a racing second setter, but
        // the Promise API makes a second setter unreachable (set_* consume
        // the promise); this catches internal misuse deterministically.
        if matches!(self.state.load(Ordering::Relaxed), READY | TAKEN | BUSY | NOTIFY) {
            panic!("promise value set twice");
        }
        // SAFETY: single setter, and no reader can observe the value
        // until the swap below publishes a produced tag.
        unsafe { *self.value.get() = Some(value) };
        // AcqRel: releases the value write to anyone who loads the tag;
        // acquires the attachers' node publications so we can walk them.
        let prev = self.state.swap(NOTIFY, Ordering::AcqRel);
        if prev >= PTR_MIN {
            unsafe { self.fire_list(prev as *mut Node<T>) };
        } else {
            debug_assert_eq!(prev, EMPTY, "produced tags are guarded above");
        }
        // Release: opens take/clone; the value write is already visible
        // through the swap, this orders the end of the firing phase.
        self.state.store(READY, Ordering::Release);
    }

    /// Fire a detached continuation list in attach (FIFO) order. Runs
    /// with state == `NOTIFY`: the value cannot be taken while we hold
    /// this borrow (takers spin until `READY`), and concurrent inline
    /// readers are fine (shared borrows).
    unsafe fn fire_list(&self, head: *mut Node<T>) {
        // The list is LIFO (CAS pushes); reverse to fire in attach order.
        let mut prev: *mut Node<T> = ptr::null_mut();
        let mut cur = head;
        while !cur.is_null() {
            let next = (*cur).next;
            (*cur).next = prev;
            prev = cur;
            cur = next;
        }
        let v = (*self.value.get()).as_ref().expect("value written before NOTIFY");
        let mut cur = prev;
        while !cur.is_null() {
            let next = (*cur).next;
            ((*cur).run)(cur, Some(v));
            cur = next;
        }
    }

    /// Attach `f`: push onto the pending list, or — if the value is
    /// already produced — run inline under the readers protocol, outside
    /// any critical section.
    fn attach<F: FnOnce(&TaskResult<T>) + Send + 'static>(&self, f: F) {
        let mut cur = self.state.load(Ordering::Acquire);
        // Fast inline path before paying for a node allocation.
        if matches!(cur, READY | NOTIFY | BUSY | TAKEN) {
            return self.run_inline(f);
        }
        let node = new_node(f);
        loop {
            match cur {
                EMPTY => unsafe { (*node).next = ptr::null_mut() },
                p if p >= PTR_MIN => unsafe { (*node).next = p as *mut Node<T> },
                _ => {
                    // Value landed while we were allocating: recover the
                    // closure and run it inline.
                    let f = unsafe { unpublish_node::<T, F>(node) };
                    return self.run_inline(f);
                }
            }
            match self.state.compare_exchange_weak(
                cur,
                node as usize,
                // Release: publish the node (and closure) to the setter.
                Ordering::Release,
                // Acquire: on failure we may go inline and read the value.
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Run a continuation inline with a shared borrow of the value.
    fn run_inline<F: FnOnce(&TaskResult<T>)>(&self, f: F) {
        let mut spins = 0u32;
        loop {
            // SeqCst RMW + SeqCst state load: Dekker with the taker (it
            // claims BUSY, then reads `readers`; we register, then read
            // the tag) — at least one side observes the other, so we
            // never borrow a value that is being moved out.
            self.readers.fetch_add(1, Ordering::SeqCst);
            match self.state.load(Ordering::SeqCst) {
                READY | NOTIFY => break,
                other => {
                    // Deregister *before* spinning: a taker that claimed
                    // BUSY waits for `readers` to drain, so holding the
                    // registration here would livelock against it.
                    self.readers.fetch_sub(1, Ordering::SeqCst);
                    match other {
                        TAKEN => panic!("future value already consumed"),
                        BUSY => spin_or_yield(&mut spins),
                        _ => unreachable!("run_inline called before value production"),
                    }
                }
            }
        }
        // SAFETY: registration + tag check above exclude concurrent moves.
        let v = unsafe { (*self.value.get()).as_ref().expect("produced tag implies value") };
        f(v);
        // Release the borrow: a waiting taker may proceed.
        self.readers.fetch_sub(1, Ordering::SeqCst);
    }

    /// Move the value out. Caller must have observed `produced()`.
    fn take_value(&self) -> TaskResult<T> {
        let mut spins = 0u32;
        loop {
            // SeqCst: Dekker with `run_inline` registration (see there).
            match self.state.compare_exchange(READY, BUSY, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => {
                    // Wait for in-flight shared borrows to drain.
                    let mut drain_spins = 0u32;
                    while self.readers.load(Ordering::SeqCst) != 0 {
                        spin_or_yield(&mut drain_spins);
                    }
                    // SAFETY: BUSY + drained readers = exclusive access.
                    let v = unsafe { (*self.value.get()).take().expect("READY implies value") };
                    // Release: publishes the move before the terminal tag.
                    self.state.store(TAKEN, Ordering::Release);
                    return v;
                }
                Err(TAKEN) => panic!("future value already consumed"),
                Err(NOTIFY) | Err(BUSY) => {
                    // Setter still firing continuations, or a racing
                    // taker about to reach TAKEN: both transient, but
                    // NOTIFY runs user code — yield once spun out.
                    spin_or_yield(&mut spins);
                }
                Err(_) => unreachable!("take_value called before value production"),
            }
        }
    }
}

impl<T> Drop for Shared<T> {
    fn drop(&mut self) {
        // Defensive: a leaked, never-set promise leaves unfired nodes.
        let s = *self.state.get_mut();
        if s >= PTR_MIN {
            let mut cur = s as *mut Node<T>;
            while !cur.is_null() {
                unsafe {
                    let next = (*cur).next;
                    ((*cur).run)(cur, None);
                    cur = next;
                }
            }
        }
    }
}

/// Write side of a future's shared state.
///
/// Dropping a `Promise` without setting a value resolves the future with
/// a "broken promise" [`TaskError`], matching `std::future_errc`.
pub struct Promise<T> {
    shared: Arc<Shared<T>>,
    set: bool,
}

impl<T> Promise<T> {
    pub fn new() -> (Promise<T>, Future<T>) {
        let shared = Shared::new();
        (
            Promise { shared: Arc::clone(&shared), set: false },
            Future { shared },
        )
    }

    /// Fulfil the promise with a successful value.
    pub fn set_value(mut self, value: T) {
        self.set = true;
        self.shared.set(Ok(value));
    }

    /// Fulfil the promise with an error.
    pub fn set_error(mut self, err: TaskError) {
        self.set = true;
        self.shared.set(Err(err));
    }

    /// Fulfil the promise with a `TaskResult`.
    pub fn set_result(mut self, r: TaskResult<T>) {
        self.set = true;
        self.shared.set(r);
    }
}

impl<T> Drop for Promise<T> {
    fn drop(&mut self) {
        if !self.set {
            self.shared
                .set(Err(TaskError::App("broken promise".to_string())));
        }
    }
}

/// Read side of an asynchronously produced value.
pub struct Future<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Future<T> {
    fn clone(&self) -> Self {
        Future { shared: Arc::clone(&self.shared) }
    }
}

impl<T: Send + 'static> Future<T> {
    /// A future that is already resolved. One allocation, no promise
    /// round-trip, no wakeup machinery.
    pub fn ready(value: TaskResult<T>) -> Self {
        Future { shared: Shared::new_ready(value) }
    }

    /// True once a value (or error) is available.
    pub fn is_ready(&self) -> bool {
        self.shared.produced()
    }

    /// Block until the value is available.
    ///
    /// On a worker thread this *helps*: it runs queued tasks while
    /// waiting, so nested `get` calls keep the pool making progress (the
    /// HPX "suspend the hpx-thread" analogue). Off-worker threads park
    /// and are unparked by a lazily-attached wakeup continuation — no
    /// condvar lives in the future itself.
    pub fn wait(&self) {
        if self.is_ready() {
            return;
        }
        match current_worker() {
            Some((pool, idx)) => self.wait_helping(&pool, idx),
            None => self.wait_parked(),
        }
    }

    fn wait_parked(&self) {
        let me = std::thread::current();
        self.shared.attach(move |_| me.unpark());
        while !self.is_ready() {
            // The continuation's unpark token guarantees wakeup even if
            // it fired between our check and the park; spurious wakeups
            // re-check.
            std::thread::park();
        }
    }

    fn wait_helping(&self, pool: &Arc<Pool>, idx: usize) {
        let me = std::thread::current();
        self.shared.attach(move |_| me.unpark());
        loop {
            if self.is_ready() {
                return;
            }
            if !pool.try_run_one(idx) {
                // No runnable work: park briefly. The continuation
                // unparks us the instant the value lands; the timeout
                // only bounds waiting for work that arrives on *other*
                // workers' queues, which has no wakeup edge to us.
                std::thread::park_timeout(Duration::from_micros(50));
            }
        }
    }

    /// Block and consume the future, returning the task's result.
    ///
    /// Panics if the value was already consumed by a previous
    /// `into_result`/`get` through a clone of this future.
    pub fn into_result(self) -> TaskResult<T> {
        self.wait();
        self.shared.take_value()
    }

    /// Alias for [`Future::into_result`], matching `future::get()`.
    pub fn get(self) -> TaskResult<T> {
        self.into_result()
    }

    /// Non-blocking: consume the value if it is ready.
    pub fn try_take(&self) -> Option<TaskResult<T>> {
        let mut spins = 0u32;
        loop {
            match self.shared.state.load(Ordering::Acquire) {
                EMPTY | NOTIFY => return None, // NOTIFY: not yet published for takers
                TAKEN => panic!("future value already consumed"),
                READY => return Some(self.shared.take_value()),
                BUSY => spin_or_yield(&mut spins), // racing taker: about to be TAKEN
                p => {
                    debug_assert!(p >= PTR_MIN);
                    return None;
                }
            }
        }
    }

    /// Attach a continuation that runs (on the caller's scheduler if the
    /// value is not yet ready; inline otherwise) with a reference to the
    /// result. Returns a future for the continuation's value.
    pub fn then<U, F>(&self, f: F) -> Future<U>
    where
        U: Send + 'static,
        F: FnOnce(&TaskResult<T>) -> TaskResult<U> + Send + 'static,
    {
        let (p, fut) = Promise::new();
        self.on_ready(move |r| p.set_result(f(r)));
        fut
    }

    /// Lower-level hook: run `f` with the result as soon as it is set.
    /// If the value is already available, `f` runs inline — *without*
    /// holding any lock, so `f` may itself attach further continuations
    /// to this future (or inspect it) freely.
    pub fn on_ready<F>(&self, f: F)
    where
        F: FnOnce(&TaskResult<T>) + Send + 'static,
    {
        self.shared.attach(f);
    }
}

impl<T: Clone + Send + 'static> Future<T> {
    /// Block and return a clone of the value, leaving it in place so
    /// other holders of this (cloned) future can also read it.
    pub fn get_copy(&self) -> TaskResult<T> {
        self.wait();
        let mut out = None;
        self.shared.run_inline(|v| out = Some(v.clone()));
        out.expect("run_inline always invokes the closure")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn promise_future_roundtrip() {
        let (p, f) = Promise::new();
        p.set_value(42);
        assert!(f.is_ready());
        assert_eq!(f.get(), Ok(42));
    }

    #[test]
    fn broken_promise() {
        let (p, f) = Promise::<i32>::new();
        drop(p);
        assert_eq!(f.get(), Err(TaskError::App("broken promise".to_string())));
    }

    #[test]
    fn then_chains_inline_when_ready() {
        let f = Future::ready(Ok(2));
        let g = f.then(|r| r.clone().map(|v| v * 10));
        assert_eq!(g.get(), Ok(20));
    }

    #[test]
    fn then_fires_on_later_set() {
        let (p, f) = Promise::new();
        let g = f.then(|r| r.clone().map(|v: i32| v + 1));
        assert!(!g.is_ready());
        p.set_value(9);
        assert_eq!(g.get(), Ok(10));
    }

    #[test]
    fn error_propagates_through_then() {
        let f: Future<i32> = Future::ready(Err(TaskError::App("x".into())));
        let g = f.then(|r| r.clone().map(|v| v + 1));
        assert_eq!(g.get(), Err(TaskError::App("x".to_string())));
    }

    #[test]
    fn cross_thread_wait() {
        let (p, f) = Promise::new();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            p.set_value(7u64);
        });
        assert_eq!(f.get(), Ok(7));
        h.join().unwrap();
    }

    #[test]
    fn get_copy_leaves_value() {
        let f = Future::ready(Ok(5i32));
        assert_eq!(f.get_copy(), Ok(5));
        assert_eq!(f.get_copy(), Ok(5));
        assert_eq!(f.get(), Ok(5));
    }

    #[test]
    #[should_panic(expected = "promise value set twice")]
    fn double_set_panics() {
        let shared = Shared::new();
        shared.set(Ok(1));
        shared.set(Ok(2));
    }

    /// Regression (the old mutex implementation deadlocked here): a
    /// continuation attached to an already-ready future runs inline; if
    /// it attaches *another* continuation to the same future, that must
    /// run too instead of deadlocking on a held state lock.
    #[test]
    fn on_ready_inline_can_attach_more_continuations() {
        let hits = Arc::new(AtomicUsize::new(0));
        let f = Future::ready(Ok(1i32));
        let f2 = f.clone();
        let h = Arc::clone(&hits);
        f.on_ready(move |_| {
            let h2 = Arc::clone(&h);
            f2.on_ready(move |r| {
                assert_eq!(*r, Ok(1));
                h2.fetch_add(1, Ordering::SeqCst);
            });
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    /// A continuation firing from `set` (the NOTIFY phase) can also
    /// attach further continuations to the same future.
    #[test]
    fn continuation_during_set_can_attach_more_continuations() {
        let hits = Arc::new(AtomicUsize::new(0));
        let (p, f) = Promise::new();
        let f2 = f.clone();
        let h = Arc::clone(&hits);
        f.on_ready(move |_| {
            let h2 = Arc::clone(&h);
            f2.on_ready(move |r| {
                assert_eq!(*r, Ok(3));
                h2.fetch_add(1, Ordering::SeqCst);
            });
            h.fetch_add(1, Ordering::SeqCst);
        });
        p.set_value(3i32);
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        assert_eq!(f.get(), Ok(3));
    }

    #[test]
    fn continuations_fire_in_attach_order() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let (p, f) = Promise::new();
        for i in 0..4 {
            let order = Arc::clone(&order);
            f.on_ready(move |_| order.lock().unwrap().push(i));
        }
        p.set_value(0i32);
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn try_take_consumes_once() {
        let f = Future::ready(Ok(9i32));
        assert_eq!(f.try_take(), Some(Ok(9)));
        let (p, g) = Promise::<i32>::new();
        assert_eq!(g.try_take(), None);
        p.set_value(1);
        assert_eq!(g.try_take(), Some(Ok(1)));
    }
}
