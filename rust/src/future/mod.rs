//! Lightweight futures and promises — the HPX synchronization substrate.
//!
//! HPX component (1): "futures, channels and other asynchronization
//! primitives". These are *eager, runtime-scheduled* futures in the HPX /
//! C++ `std::future` tradition, not Rust `async` futures: a [`Promise`]
//! owns the write side of a shared state, a [`Future`] the read side;
//! continuations attached with [`Future::then`] run on the scheduler as
//! soon as the value is set, and [`Future::get`] blocks — cooperatively
//! helping the pool run other tasks when called from a worker thread, so
//! waiting inside a task can never deadlock the pool.
//!
//! Paper mapping: HPX runtime substrate; `when_all` is the
//! synchronization under every §V-B stencil dataflow task.

mod channel;
mod when_all;

pub use channel::{channel, Receiver, Sender};
pub use when_all::{collapse_results, when_all, when_all_results};

use std::sync::{Arc, Condvar, Mutex};

use crate::error::{TaskError, TaskResult};
use crate::scheduler::{current_worker, Pool};

type Continuation<T> = Box<dyn FnOnce(&TaskResult<T>) + Send + 'static>;

/// Continuation storage tuned for the common case: almost every future
/// gets zero or one continuation, so avoid a `Vec` allocation for those.
enum Conts<T> {
    None,
    One(Continuation<T>),
    Many(Vec<Continuation<T>>),
}

impl<T> Conts<T> {
    fn push(&mut self, c: Continuation<T>) {
        match std::mem::replace(self, Conts::None) {
            Conts::None => *self = Conts::One(c),
            Conts::One(first) => *self = Conts::Many(vec![first, c]),
            Conts::Many(mut v) => {
                v.push(c);
                *self = Conts::Many(v);
            }
        }
    }

    fn is_empty(&self) -> bool {
        matches!(self, Conts::None)
    }

    fn fire(self, v: &TaskResult<T>) {
        match self {
            Conts::None => {}
            Conts::One(c) => c(v),
            Conts::Many(cs) => {
                for c in cs {
                    c(v);
                }
            }
        }
    }
}

enum State<T> {
    /// Value not yet produced; holds continuations to fire on set.
    Pending(Conts<T>),
    /// Value produced (taken by at most one `get`/`try_take`).
    Ready(TaskResult<T>),
    /// Value produced and consumed by `into_result`.
    Taken,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> Shared<T> {
    fn new() -> Arc<Self> {
        Arc::new(Shared { state: Mutex::new(State::Pending(Conts::None)), cv: Condvar::new() })
    }

    /// Publish the value: drain and fire continuations (without holding
    /// the state lock, so continuations may freely attach further
    /// continuations), then store the value and wake blocked waiters.
    /// Loops because a firing continuation may attach new continuations.
    fn set(&self, value: TaskResult<T>) {
        let mut value = Some(value);
        loop {
            let mut g = self.state.lock().unwrap();
            match &mut *g {
                State::Pending(conts) if !conts.is_empty() => {
                    let cs = std::mem::replace(conts, Conts::None);
                    drop(g);
                    let v = value.as_ref().expect("value present until stored");
                    cs.fire(v);
                }
                State::Pending(_) => {
                    *g = State::Ready(value.take().expect("single store"));
                    drop(g);
                    self.cv.notify_all();
                    return;
                }
                // Double-set is a programming error in this crate.
                _ => panic!("promise value set twice"),
            }
        }
    }
}

/// Write side of a future's shared state.
///
/// Dropping a `Promise` without setting a value resolves the future with
/// a "broken promise" [`TaskError`], matching `std::future_errc`.
pub struct Promise<T> {
    shared: Arc<Shared<T>>,
    set: bool,
}

impl<T> Promise<T> {
    pub fn new() -> (Promise<T>, Future<T>) {
        let shared = Shared::new();
        (
            Promise { shared: Arc::clone(&shared), set: false },
            Future { shared },
        )
    }

    /// Fulfil the promise with a successful value.
    pub fn set_value(mut self, value: T) {
        self.set = true;
        self.shared.set(Ok(value));
    }

    /// Fulfil the promise with an error.
    pub fn set_error(mut self, err: TaskError) {
        self.set = true;
        self.shared.set(Err(err));
    }

    /// Fulfil the promise with a `TaskResult`.
    pub fn set_result(mut self, r: TaskResult<T>) {
        self.set = true;
        self.shared.set(r);
    }
}

impl<T> Drop for Promise<T> {
    fn drop(&mut self) {
        if !self.set {
            self.shared
                .set(Err(TaskError::App("broken promise".to_string())));
        }
    }
}

/// Read side of an asynchronously produced value.
pub struct Future<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Future<T> {
    fn clone(&self) -> Self {
        Future { shared: Arc::clone(&self.shared) }
    }
}

impl<T: Send + 'static> Future<T> {
    /// A future that is already resolved.
    pub fn ready(value: TaskResult<T>) -> Self {
        let (p, f) = Promise::new();
        p.set_result(value);
        f
    }

    /// True once a value (or error) is available.
    pub fn is_ready(&self) -> bool {
        !matches!(*self.shared.state.lock().unwrap(), State::Pending(_))
    }

    /// Block until the value is available.
    ///
    /// On a worker thread this *helps*: it runs queued tasks while
    /// waiting, so nested `get` calls keep the pool making progress (the
    /// HPX "suspend the hpx-thread" analogue).
    pub fn wait(&self) {
        if self.is_ready() {
            return;
        }
        if let Some((pool, idx)) = current_worker() {
            self.wait_helping(&pool, idx);
        } else {
            let mut g = self.shared.state.lock().unwrap();
            while matches!(*g, State::Pending(_)) {
                g = self.shared.cv.wait(g).unwrap();
            }
        }
    }

    fn wait_helping(&self, pool: &Arc<Pool>, idx: usize) {
        loop {
            if self.is_ready() {
                return;
            }
            if !pool.try_run_one(idx) {
                // No runnable work; sleep briefly on the future's condvar.
                let g = self.shared.state.lock().unwrap();
                if !matches!(*g, State::Pending(_)) {
                    return;
                }
                let _ = self
                    .shared
                    .cv
                    .wait_timeout(g, std::time::Duration::from_micros(50))
                    .unwrap();
            }
        }
    }

    /// Block and consume the future, returning the task's result.
    ///
    /// Panics if the value was already consumed by a previous
    /// `into_result`/`get` through a clone of this future.
    pub fn into_result(self) -> TaskResult<T> {
        self.wait();
        let mut g = self.shared.state.lock().unwrap();
        match std::mem::replace(&mut *g, State::Taken) {
            State::Ready(v) => v,
            State::Taken => panic!("future value already consumed"),
            State::Pending(_) => unreachable!("wait() returned while pending"),
        }
    }

    /// Alias for [`Future::into_result`], matching `future::get()`.
    pub fn get(self) -> TaskResult<T> {
        self.into_result()
    }

    /// Non-blocking: consume the value if it is ready.
    pub fn try_take(&self) -> Option<TaskResult<T>> {
        let mut g = self.shared.state.lock().unwrap();
        match &*g {
            State::Pending(_) => None,
            State::Taken => panic!("future value already consumed"),
            State::Ready(_) => match std::mem::replace(&mut *g, State::Taken) {
                State::Ready(v) => Some(v),
                _ => unreachable!(),
            },
        }
    }

    /// Attach a continuation that runs (on the caller's scheduler if the
    /// value is not yet ready; inline otherwise) with a reference to the
    /// result. Returns a future for the continuation's value.
    pub fn then<U, F>(&self, f: F) -> Future<U>
    where
        U: Send + 'static,
        F: FnOnce(&TaskResult<T>) -> TaskResult<U> + Send + 'static,
    {
        let (p, fut) = Promise::new();
        self.on_ready(move |r| p.set_result(f(r)));
        fut
    }

    /// Lower-level hook: run `f` with the result as soon as it is set.
    /// If the value is already available, `f` runs inline.
    pub fn on_ready<F>(&self, f: F)
    where
        F: FnOnce(&TaskResult<T>) + Send + 'static,
    {
        let mut g = self.shared.state.lock().unwrap();
        match &mut *g {
            State::Pending(conts) => conts.push(Box::new(f)),
            State::Ready(v) => {
                // Fire inline while holding the lock: cheap (no job is
                // scheduled) and consistent with the set() path.
                f(v);
            }
            State::Taken => panic!("future value already consumed"),
        }
    }
}

impl<T: Clone + Send + 'static> Future<T> {
    /// Block and return a clone of the value, leaving it in place so
    /// other holders of this (cloned) future can also read it.
    pub fn get_copy(&self) -> TaskResult<T> {
        self.wait();
        let g = self.shared.state.lock().unwrap();
        match &*g {
            State::Ready(v) => v.clone(),
            State::Taken => panic!("future value already consumed"),
            State::Pending(_) => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn promise_future_roundtrip() {
        let (p, f) = Promise::new();
        p.set_value(42);
        assert!(f.is_ready());
        assert_eq!(f.get(), Ok(42));
    }

    #[test]
    fn broken_promise() {
        let (p, f) = Promise::<i32>::new();
        drop(p);
        assert_eq!(f.get(), Err(TaskError::App("broken promise".to_string())));
    }

    #[test]
    fn then_chains_inline_when_ready() {
        let f = Future::ready(Ok(2));
        let g = f.then(|r| r.clone().map(|v| v * 10));
        assert_eq!(g.get(), Ok(20));
    }

    #[test]
    fn then_fires_on_later_set() {
        let (p, f) = Promise::new();
        let g = f.then(|r| r.clone().map(|v: i32| v + 1));
        assert!(!g.is_ready());
        p.set_value(9);
        assert_eq!(g.get(), Ok(10));
    }

    #[test]
    fn error_propagates_through_then() {
        let f: Future<i32> = Future::ready(Err(TaskError::App("x".into())));
        let g = f.then(|r| r.clone().map(|v| v + 1));
        assert_eq!(g.get(), Err(TaskError::App("x".to_string())));
    }

    #[test]
    fn cross_thread_wait() {
        let (p, f) = Promise::new();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            p.set_value(7u64);
        });
        assert_eq!(f.get(), Ok(7));
        h.join().unwrap();
    }

    #[test]
    fn get_copy_leaves_value() {
        let f = Future::ready(Ok(5i32));
        assert_eq!(f.get_copy(), Ok(5));
        assert_eq!(f.get_copy(), Ok(5));
        assert_eq!(f.get(), Ok(5));
    }

    #[test]
    #[should_panic(expected = "promise value set twice")]
    fn double_set_panics() {
        let shared = Shared::new();
        shared.set(Ok(1));
        shared.set(Ok(2));
    }
}
