//! Asynchronous channels in the HPX style.
//!
//! An HPX channel is a pipe of futures: `recv` returns a [`Future`] that
//! resolves when a matching `send` arrives (possibly before the send).
//! Sends never block; pending receives are matched FIFO.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::error::{TaskError, TaskResult};

use super::{Future, Promise};

struct ChannelState<T> {
    /// Values sent with no receiver waiting.
    queued: VecDeque<TaskResult<T>>,
    /// Receivers waiting for a value.
    waiting: VecDeque<Promise<T>>,
    /// Set once every `Sender` has been dropped.
    closed: bool,
}

/// Create an unbounded multi-producer multi-consumer future channel.
pub fn channel<T: Send + 'static>() -> (Sender<T>, Receiver<T>) {
    let state = Arc::new(Mutex::new(ChannelState {
        queued: VecDeque::new(),
        waiting: VecDeque::new(),
        closed: false,
    }));
    (
        Sender { state: Arc::clone(&state) },
        Receiver { state },
    )
}

/// Sending half; cloneable. Use [`Receiver::close`] to close the channel
/// and fail all pending and future receives.
pub struct Sender<T: Send + 'static> {
    state: Arc<Mutex<ChannelState<T>>>,
}

impl<T: Send + 'static> Sender<T> {
    /// Deliver a value: wakes the oldest waiting receiver, or queues.
    pub fn send(&self, value: T) {
        let waiter = {
            let mut g = self.state.lock().unwrap();
            match g.waiting.pop_front() {
                Some(p) => Some(p),
                None => {
                    g.queued.push_back(Ok(value));
                    return;
                }
            }
        };
        waiter.expect("checked above").set_value(value);
    }
}

impl<T: Send + 'static> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Sender { state: Arc::clone(&self.state) }
    }
}

/// Receiving half; cloneable (competing consumers).
pub struct Receiver<T: Send + 'static> {
    state: Arc<Mutex<ChannelState<T>>>,
}

impl<T: Send + 'static> Receiver<T> {
    /// A future for the next value.
    pub fn recv(&self) -> Future<T> {
        let mut g = self.state.lock().unwrap();
        if let Some(v) = g.queued.pop_front() {
            return Future::ready(v);
        }
        if g.closed {
            return Future::ready(Err(TaskError::App("channel closed".to_string())));
        }
        let (p, f) = Promise::new();
        g.waiting.push_back(p);
        f
    }

    /// Close the channel explicitly: pending receivers fail, queued
    /// values remain readable.
    pub fn close(&self) {
        let waiters: Vec<Promise<T>> = {
            let mut g = self.state.lock().unwrap();
            g.closed = true;
            g.waiting.drain(..).collect()
        };
        for w in waiters {
            w.set_error(TaskError::App("channel closed".to_string()));
        }
    }

    /// Number of queued, unconsumed values.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queued.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T: Send + 'static> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver { state: Arc::clone(&self.state) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_then_recv() {
        let (tx, rx) = channel();
        tx.send(1);
        tx.send(2);
        assert_eq!(rx.recv().get(), Ok(1));
        assert_eq!(rx.recv().get(), Ok(2));
    }

    #[test]
    fn recv_then_send() {
        let (tx, rx) = channel();
        let f = rx.recv();
        assert!(!f.is_ready());
        tx.send(9);
        assert_eq!(f.get(), Ok(9));
    }

    #[test]
    fn fifo_matching_of_waiters() {
        let (tx, rx) = channel();
        let f1 = rx.recv();
        let f2 = rx.recv();
        tx.send("a");
        tx.send("b");
        assert_eq!(f1.get(), Ok("a"));
        assert_eq!(f2.get(), Ok("b"));
    }

    #[test]
    fn close_fails_waiters_but_keeps_queue() {
        let (tx, rx) = channel();
        tx.send(5);
        let pending = {
            let rx2 = rx.clone();
            let f = rx2.recv(); // consumes the queued 5
            assert_eq!(f.get(), Ok(5));
            rx.recv()
        };
        rx.close();
        assert!(pending.get().is_err());
        assert!(rx.recv().get().is_err());
        tx.send(6); // send after close: queued but unreachable; must not panic
    }

    #[test]
    fn cross_thread_channel() {
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i);
            }
        });
        let mut sum = 0i64;
        for _ in 0..100 {
            sum += rx.recv().get().unwrap();
        }
        h.join().unwrap();
        assert_eq!(sum, 4950);
    }
}
