//! `when_all` — the synchronization primitive under `dataflow`.
//!
//! A dataflow task "waits for all provided futures to become ready, and
//! then executes the specified function" (paper §V-B). `when_all` is the
//! waiting half: it completes when every input future holds a value,
//! without blocking any thread.
//!
//! The join is lock-free: one shared allocation holds an atomic
//! countdown plus one value slot per dependency. Each slot is written by
//! exactly one dependency's continuation (per-slot once-only writes need
//! no synchronization of their own), and the continuation that brings
//! the countdown to zero — having *acquired* every other slot write via
//! the `AcqRel` decrement — collects the slots and resolves the output
//! promise. An N-dependency join therefore costs N atomic decrements,
//! zero mutex acquisitions, on the dependency-completion path. Inputs
//! that are all already resolved short-circuit into a ready future with
//! no join state at all.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::error::{TaskError, TaskResult};

use super::{Future, Promise};

/// Resolve with the values of all `futs`; if any input fails, resolve
/// with that input's error (first one observed wins deterministically by
/// index priority: the lowest-index error is reported).
pub fn when_all<T: Clone + Send + 'static>(futs: Vec<Future<T>>) -> Future<Vec<T>> {
    when_all_results(futs).then(|r| match r {
        Ok(results) => collapse_results(results),
        Err(e) => Err(e.clone()),
    })
}

/// Collapse per-dependency results into all-values-or-first-error (by
/// index order, deterministically). Shared by `when_all` and the
/// dataflow launch paths, which call it inline on `when_all_results`
/// output to avoid an extra future hop per task.
pub fn collapse_results<T: Clone>(results: &[TaskResult<T>]) -> Result<Vec<T>, TaskError> {
    if let Some(e) = results.iter().find_map(|r| r.as_ref().err()) {
        return Err(TaskError::DependencyFailed(e.to_string()));
    }
    Ok(results
        .iter()
        .map(|r| r.as_ref().ok().expect("checked above").clone())
        .collect())
}

/// Lock-free join state: slot `i` is owned by dependency `i`'s
/// continuation until the final decrement hands all slots to the
/// finishing thread.
struct Join<T> {
    slots: Box<[UnsafeCell<Option<TaskResult<T>>>]>,
    remaining: AtomicUsize,
    promise: UnsafeCell<Option<Promise<Vec<TaskResult<T>>>>>,
}

// SAFETY: each `slots[i]` has exactly one writer (dependency i's sole
// continuation); the promise cell is touched only by the thread whose
// decrement observed `remaining == 1`, after acquiring every slot write.
unsafe impl<T: Send> Send for Join<T> {}
unsafe impl<T: Send> Sync for Join<T> {}

/// Resolve with every input's `TaskResult` (never fails itself): the
/// error-tolerant variant used by the resiliency layer, which must see
/// *which* dependencies failed rather than a collapsed error.
///
/// Hot path of every dataflow task: a *single* shared allocation and one
/// atomic decrement per dependency completion — no locks anywhere.
pub fn when_all_results<T: Clone + Send + 'static>(
    futs: Vec<Future<T>>,
) -> Future<Vec<TaskResult<T>>> {
    if futs.is_empty() {
        return Future::ready(Ok(Vec::new()));
    }
    // Fast path: every input already resolved (common behind the stencil
    // window barrier) — clone the values straight out, no join state, no
    // countdown, no continuation nodes.
    if futs.iter().all(|f| f.is_ready()) {
        let results: Vec<TaskResult<T>> = futs.iter().map(|f| f.get_copy()).collect();
        return Future::ready(Ok(results));
    }
    let n = futs.len();
    let (promise, out) = Promise::new();
    let join: Arc<Join<T>> = Arc::new(Join {
        slots: (0..n).map(|_| UnsafeCell::new(None)).collect(),
        remaining: AtomicUsize::new(n),
        promise: UnsafeCell::new(Some(promise)),
    });

    for (i, f) in futs.iter().enumerate() {
        let join = Arc::clone(&join);
        f.on_ready(move |r| {
            // SAFETY: sole writer of slot i (once-only by construction).
            unsafe { *join.slots[i].get() = Some(r.clone()) };
            // AcqRel: releases our slot write to the finishing thread and
            // (on the final decrement) acquires every other slot write.
            if join.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                // SAFETY: all dependencies have written their slots and
                // the countdown hands us exclusive access to all of them.
                let results: Vec<TaskResult<T>> = join
                    .slots
                    .iter()
                    .map(|s| unsafe { (*s.get()).take().expect("all slots filled") })
                    .collect();
                let p = unsafe {
                    (*join.promise.get()).take().expect("final decrement happens once")
                };
                p.set_value(results);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn when_all_ready_inputs() {
        let futs = vec![Future::ready(Ok(1)), Future::ready(Ok(2)), Future::ready(Ok(3))];
        assert_eq!(when_all(futs).get(), Ok(vec![1, 2, 3]));
    }

    #[test]
    fn when_all_empty() {
        let futs: Vec<Future<i32>> = vec![];
        assert_eq!(when_all(futs).get(), Ok(vec![]));
    }

    #[test]
    fn when_all_orders_by_index_not_completion() {
        let (p1, f1) = Promise::new();
        let (p2, f2) = Promise::new();
        let all = when_all(vec![f1, f2]);
        p2.set_value(20); // second input completes first
        p1.set_value(10);
        assert_eq!(all.get(), Ok(vec![10, 20]));
    }

    #[test]
    fn when_all_propagates_lowest_index_error() {
        let (p1, f1) = Promise::<i32>::new();
        let (p2, f2) = Promise::<i32>::new();
        let all = when_all(vec![f1, f2]);
        p2.set_error(TaskError::App("late".into()));
        p1.set_error(TaskError::App("early".into()));
        match all.get() {
            Err(TaskError::DependencyFailed(m)) => assert!(m.contains("early"), "{m}"),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn when_all_results_preserves_individual_errors() {
        let futs = vec![
            Future::ready(Ok(1)),
            Future::ready(Err(TaskError::App("x".into()))),
        ];
        let r = when_all_results(futs).get().unwrap();
        assert_eq!(r[0], Ok(1));
        assert!(r[1].is_err());
    }

    #[test]
    fn when_all_results_mixed_ready_and_pending() {
        // Exercises the slow path with some slots filled inline at
        // attach time and some by a later set.
        let (p, pending) = Promise::new();
        let futs = vec![Future::ready(Ok(1)), pending, Future::ready(Ok(3))];
        let all = when_all_results(futs);
        assert!(!all.is_ready());
        p.set_value(2);
        assert_eq!(all.get().unwrap(), vec![Ok(1), Ok(2), Ok(3)]);
    }

    #[test]
    fn when_all_duplicate_input_future() {
        // The same shared state appearing under several indices must fill
        // every one of its slots.
        let (p, f) = Promise::new();
        let all = when_all(vec![f.clone(), f.clone(), f]);
        p.set_value(7);
        assert_eq!(all.get(), Ok(vec![7, 7, 7]));
    }
}
