//! `when_all` — the synchronization primitive under `dataflow`.
//!
//! A dataflow task "waits for all provided futures to become ready, and
//! then executes the specified function" (paper §V-B). `when_all` is the
//! waiting half: it completes when every input future holds a value,
//! without blocking any thread (a shared atomic countdown fired from each
//! input's continuation).

use std::sync::{Arc, Mutex};

use crate::error::{TaskError, TaskResult};

use super::{Future, Promise};

/// Resolve with the values of all `futs`; if any input fails, resolve
/// with that input's error (first one observed wins deterministically by
/// index priority: the lowest-index error is reported).
pub fn when_all<T: Clone + Send + 'static>(futs: Vec<Future<T>>) -> Future<Vec<T>> {
    when_all_results(futs).then(|r| match r {
        Ok(results) => collapse_results(results),
        Err(e) => Err(e.clone()),
    })
}

/// Collapse per-dependency results into all-values-or-first-error (by
/// index order, deterministically). Shared by `when_all` and the
/// dataflow launch paths, which call it inline on `when_all_results`
/// output to avoid an extra future hop per task.
pub fn collapse_results<T: Clone>(results: &[TaskResult<T>]) -> Result<Vec<T>, TaskError> {
    if let Some(e) = results.iter().find_map(|r| r.as_ref().err()) {
        return Err(TaskError::DependencyFailed(e.to_string()));
    }
    Ok(results
        .iter()
        .map(|r| r.as_ref().ok().expect("checked above").clone())
        .collect())
}

/// Resolve with every input's `TaskResult` (never fails itself): the
/// error-tolerant variant used by the resiliency layer, which must see
/// *which* dependencies failed rather than a collapsed error.
///
/// Hot path of every dataflow task: a *single* shared allocation (one
/// `Arc<Mutex<…>>` holding slots + countdown + promise) and one lock per
/// dependency completion.
pub fn when_all_results<T: Clone + Send + 'static>(
    futs: Vec<Future<T>>,
) -> Future<Vec<TaskResult<T>>> {
    if futs.is_empty() {
        return Future::ready(Ok(Vec::new()));
    }
    let n = futs.len();
    let (promise, out) = Promise::new();

    struct JoinState<T> {
        slots: Vec<Option<TaskResult<T>>>,
        remaining: usize,
        promise: Option<Promise<Vec<TaskResult<T>>>>,
    }
    let state = Arc::new(Mutex::new(JoinState {
        slots: (0..n).map(|_| None).collect(),
        remaining: n,
        promise: Some(promise),
    }));

    for (i, f) in futs.iter().enumerate() {
        let state = Arc::clone(&state);
        f.on_ready(move |r| {
            let finish = {
                let mut g = state.lock().unwrap();
                g.slots[i] = Some(r.clone());
                g.remaining -= 1;
                if g.remaining == 0 {
                    let results: Vec<TaskResult<T>> = g
                        .slots
                        .drain(..)
                        .map(|s| s.expect("all slots filled"))
                        .collect();
                    g.promise.take().map(|p| (p, results))
                } else {
                    None
                }
            };
            if let Some((p, results)) = finish {
                p.set_value(results);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn when_all_ready_inputs() {
        let futs = vec![Future::ready(Ok(1)), Future::ready(Ok(2)), Future::ready(Ok(3))];
        assert_eq!(when_all(futs).get(), Ok(vec![1, 2, 3]));
    }

    #[test]
    fn when_all_empty() {
        let futs: Vec<Future<i32>> = vec![];
        assert_eq!(when_all(futs).get(), Ok(vec![]));
    }

    #[test]
    fn when_all_orders_by_index_not_completion() {
        let (p1, f1) = Promise::new();
        let (p2, f2) = Promise::new();
        let all = when_all(vec![f1, f2]);
        p2.set_value(20); // second input completes first
        p1.set_value(10);
        assert_eq!(all.get(), Ok(vec![10, 20]));
    }

    #[test]
    fn when_all_propagates_lowest_index_error() {
        let (p1, f1) = Promise::<i32>::new();
        let (p2, f2) = Promise::<i32>::new();
        let all = when_all(vec![f1, f2]);
        p2.set_error(TaskError::App("late".into()));
        p1.set_error(TaskError::App("early".into()));
        match all.get() {
            Err(TaskError::DependencyFailed(m)) => assert!(m.contains("early"), "{m}"),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn when_all_results_preserves_individual_errors() {
        let futs = vec![
            Future::ready(Ok(1)),
            Future::ready(Err(TaskError::App("x".into()))),
        ];
        let r = when_all_results(futs).get().unwrap();
        assert_eq!(r[0], Ok(1));
        assert!(r[1].is_err());
    }
}
