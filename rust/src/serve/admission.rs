//! `serve::admission` — queue-depth admission control with backpressure.
//!
//! The containment half of the service-level resilience story: instead
//! of buffering unboundedly (and letting an overload turn into memory
//! exhaustion and unbounded latency), the gate holds a fixed number of
//! in-flight-or-queued jobs and answers everything beyond it with an
//! explicit [`Decision::Rejected`] carrying a retry hint. Clients that
//! honor `retry_after_ms` turn an overload spike into a paced retry
//! storm the server can absorb; clients that don't still cannot push
//! the queue past its bound.
//!
//! The gate is deliberately tiny — one mutex, three counters — so the
//! deterministic-schedule test for "two clients race the last slot" can
//! replay both interleavings and see exactly one admission.

use std::sync::Mutex;

/// Outcome of [`AdmissionGate::try_admit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// A slot was taken; the caller owns it until
    /// [`AdmissionGate::release`].
    Admitted,
    /// Queue full — retry no sooner than `retry_after_ms`.
    Rejected { retry_after_ms: u64 },
}

#[derive(Default)]
struct GateState {
    depth: usize,
    admitted: u64,
    rejected: u64,
    high_water: usize,
}

/// Bounded admission gate: at most `capacity` jobs admitted-and-unreleased
/// at any instant.
pub struct AdmissionGate {
    capacity: usize,
    retry_after_ms: u64,
    state: Mutex<GateState>,
}

impl AdmissionGate {
    /// Gate with `capacity` slots; rejections advise retrying after
    /// `retry_after_ms`.
    pub fn new(capacity: usize, retry_after_ms: u64) -> Self {
        AdmissionGate {
            capacity: capacity.max(1),
            retry_after_ms,
            state: Mutex::new(GateState::default()),
        }
    }

    /// Take a slot if one is free. Check-and-increment under one lock:
    /// two racing clients can never both see the last free slot.
    pub fn try_admit(&self) -> Decision {
        let mut st = self.state.lock().unwrap();
        if st.depth < self.capacity {
            st.depth += 1;
            st.admitted += 1;
            st.high_water = st.high_water.max(st.depth);
            Decision::Admitted
        } else {
            st.rejected += 1;
            Decision::Rejected { retry_after_ms: self.retry_after_ms }
        }
    }

    /// Take a slot unconditionally — the restart-recovery path, where
    /// jobs journaled by a previous process re-enter the queue even if
    /// that briefly exceeds `capacity` (they were already admitted once;
    /// dropping them would violate the no-lost-accepted-work promise).
    pub fn admit_unchecked(&self) {
        let mut st = self.state.lock().unwrap();
        st.depth += 1;
        st.admitted += 1;
        st.high_water = st.high_water.max(st.depth);
    }

    /// Return a slot (job completed or failed terminally).
    pub fn release(&self) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.depth > 0, "release without a matching admit");
        st.depth = st.depth.saturating_sub(1);
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().depth
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// (admitted, rejected, high-water depth) so far.
    pub fn counters(&self) -> (u64, u64, usize) {
        let st = self.state.lock().unwrap();
        (st.admitted, st.rejected, st.high_water)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_to_capacity_then_rejects_with_retry_hint() {
        let gate = AdmissionGate::new(2, 40);
        assert_eq!(gate.try_admit(), Decision::Admitted);
        assert_eq!(gate.try_admit(), Decision::Admitted);
        assert_eq!(gate.try_admit(), Decision::Rejected { retry_after_ms: 40 });
        assert_eq!(gate.depth(), 2);
        let (admitted, rejected, high) = gate.counters();
        assert_eq!((admitted, rejected, high), (2, 1, 2));
    }

    #[test]
    fn release_frees_a_slot() {
        let gate = AdmissionGate::new(1, 10);
        assert_eq!(gate.try_admit(), Decision::Admitted);
        assert!(matches!(gate.try_admit(), Decision::Rejected { .. }));
        gate.release();
        assert_eq!(gate.try_admit(), Decision::Admitted);
        assert_eq!(gate.depth(), 1);
    }

    #[test]
    fn unchecked_admission_can_exceed_capacity_for_recovery() {
        let gate = AdmissionGate::new(1, 10);
        gate.admit_unchecked();
        gate.admit_unchecked();
        assert_eq!(gate.depth(), 2, "recovered jobs re-enter past the cap");
        assert!(matches!(gate.try_admit(), Decision::Rejected { .. }));
        gate.release();
        gate.release();
        assert_eq!(gate.try_admit(), Decision::Admitted);
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let gate = AdmissionGate::new(0, 10);
        assert_eq!(gate.capacity(), 1);
        assert_eq!(gate.try_admit(), Decision::Admitted);
    }

    #[test]
    fn concurrent_admits_never_exceed_capacity() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let gate = Arc::new(AdmissionGate::new(4, 5));
        let wins = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let gate = Arc::clone(&gate);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    if gate.try_admit() == Decision::Admitted {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(wins.load(Ordering::Relaxed), 4);
        assert_eq!(gate.depth(), 4);
    }
}
