//! `serve::breaker` — per-task-class circuit breaker with exponential
//! backoff and deterministic seeded jitter.
//!
//! The detection-plus-containment half the admission gate cannot cover:
//! the gate bounds *how much* work is in flight, the breaker bounds *how
//! much of it is allowed to keep failing*. Each task class (workload
//! name) carries a tiny state machine:
//!
//! ```text
//!            failures ≥ threshold
//!   Closed ────────────────────────▶ Open
//!     ▲                               │ cooldown elapses
//!     │ probe succeeds                ▼
//!     └───────────────────────── HalfOpen ──▶ Open (probe fails,
//!                                              cooldown doubles)
//! ```
//!
//! While Open, every request is rejected with the remaining cooldown as
//! its retry hint. The cooldown is `base << opens` (capped) plus seeded
//! jitter from [`crate::failure::Rng`] — exponential backoff that
//! de-synchronizes retry storms, yet is bit-for-bit reproducible under a
//! fixed seed, which is what lets the deterministic-schedule tests in
//! `rust/tests/deterministic_schedules.rs` replay both probe
//! interleavings and assert exact retry budgets.
//!
//! Time is the caller's problem: every entry point takes `now` in ticks
//! (the server passes milliseconds since start; the tests pass a
//! [`crate::testing::det::VirtualClock`] reading). The breaker never
//! reads a wall clock, so no test ever sleeps.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::failure::Rng;

/// Breaker tuning. Defaults: trip after 3 consecutive failures, 100-tick
/// base cooldown doubling up to 6 times, up to 25 ticks of jitter.
#[derive(Debug, Clone)]
pub struct BreakerConfig {
    /// Consecutive failures (per class) that trip Closed → Open.
    pub failure_threshold: u32,
    /// Base cooldown in ticks for the first trip.
    pub cooldown_ticks: u64,
    /// Cap on cooldown doublings (backoff = base · 2^min(opens−1, cap)).
    pub max_doublings: u32,
    /// Jitter added per trip, uniform in `0..=jitter_ticks`.
    pub jitter_ticks: u64,
    /// Seed for the jitter stream (deterministic across runs).
    pub seed: u64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            failure_threshold: 3,
            cooldown_ticks: 100,
            max_doublings: 6,
            jitter_ticks: 25,
            seed: 0x1CE,
        }
    }
}

/// Per-class breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Closed,
    Open { until: u64 },
    HalfOpen { probe_in_flight: bool },
}

#[derive(Debug)]
struct ClassState {
    state: State,
    consecutive_failures: u32,
    /// Trips so far — drives the backoff exponent.
    opens: u32,
}

impl ClassState {
    fn new() -> Self {
        ClassState { state: State::Closed, consecutive_failures: 0, opens: 0 }
    }
}

/// Outcome of [`CircuitBreaker::allow`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Class is healthy — run the job.
    Admit,
    /// Class is half-open and this caller holds the single probe slot:
    /// run the job and report the outcome; it decides Closed vs Open.
    Probe,
    /// Class is open (or another probe is in flight) — retry after.
    Reject { retry_after_ticks: u64 },
}

/// Per-task-class circuit breaker. Thread-safe; one instance serves all
/// classes.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    inner: Mutex<Inner>,
}

struct Inner {
    classes: HashMap<String, ClassState>,
    rng: Rng,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        let rng = Rng::seeded(cfg.seed);
        CircuitBreaker { cfg, inner: Mutex::new(Inner { classes: HashMap::new(), rng }) }
    }

    /// May a job of `class` run at tick `now`?
    pub fn allow(&self, class: &str, now: u64) -> Admission {
        let mut inner = self.inner.lock().unwrap();
        let st = inner.classes.entry(class.to_string()).or_insert_with(ClassState::new);
        match st.state {
            State::Closed => Admission::Admit,
            State::Open { until } => {
                if now >= until {
                    // Cooldown elapsed: this caller becomes the probe.
                    st.state = State::HalfOpen { probe_in_flight: true };
                    Admission::Probe
                } else {
                    Admission::Reject { retry_after_ticks: until - now }
                }
            }
            State::HalfOpen { probe_in_flight } => {
                if probe_in_flight {
                    // One probe at a time; others back off a base
                    // cooldown rather than pile onto a maybe-sick class.
                    Admission::Reject { retry_after_ticks: self.cfg.cooldown_ticks }
                } else {
                    st.state = State::HalfOpen { probe_in_flight: true };
                    Admission::Probe
                }
            }
        }
    }

    /// Report a successful completion for `class`.
    pub fn on_success(&self, class: &str, now: u64) {
        let _ = now;
        let mut inner = self.inner.lock().unwrap();
        let st = inner.classes.entry(class.to_string()).or_insert_with(ClassState::new);
        st.consecutive_failures = 0;
        if matches!(st.state, State::HalfOpen { .. }) {
            // Probe succeeded: full recovery, backoff resets.
            st.state = State::Closed;
            st.opens = 0;
        }
    }

    /// Report a failed completion for `class` at tick `now`.
    pub fn on_failure(&self, class: &str, now: u64) {
        let mut inner = self.inner.lock().unwrap();
        let Inner { classes, rng } = &mut *inner;
        let st = classes.entry(class.to_string()).or_insert_with(ClassState::new);
        match st.state {
            State::HalfOpen { .. } => {
                // Probe failed: reopen with a doubled (jittered) cooldown.
                Self::trip(&self.cfg, st, rng, now);
            }
            State::Closed => {
                st.consecutive_failures += 1;
                if st.consecutive_failures >= self.cfg.failure_threshold {
                    Self::trip(&self.cfg, st, rng, now);
                }
            }
            State::Open { .. } => {
                // Stragglers admitted before the trip; already contained.
            }
        }
    }

    /// A probe was admitted but never ran (e.g. its journal write
    /// failed): free the probe slot without judging the class.
    pub fn abandon_probe(&self, class: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(st) = inner.classes.get_mut(class) {
            if st.state == (State::HalfOpen { probe_in_flight: true }) {
                st.state = State::HalfOpen { probe_in_flight: false };
            }
        }
    }

    fn trip(cfg: &BreakerConfig, st: &mut ClassState, rng: &mut Rng, now: u64) {
        st.opens += 1;
        st.consecutive_failures = 0;
        let exp = (st.opens - 1).min(cfg.max_doublings);
        let cooldown = cfg.cooldown_ticks.saturating_mul(1u64 << exp);
        let jitter = if cfg.jitter_ticks > 0 { rng.next_below(cfg.jitter_ticks + 1) } else { 0 };
        st.state = State::Open { until: now.saturating_add(cooldown).saturating_add(jitter) };
    }

    /// Number of trips so far for `class` (0 if never seen).
    pub fn opens(&self, class: &str) -> u32 {
        self.inner.lock().unwrap().classes.get(class).map_or(0, |st| st.opens)
    }

    /// True while `class` is in the Open state at tick `now` (a probe
    /// would not yet be admitted).
    pub fn is_open(&self, class: &str, now: u64) -> bool {
        matches!(
            self.inner.lock().unwrap().classes.get(class).map(|st| st.state),
            Some(State::Open { until }) if now < until
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_no_jitter() -> BreakerConfig {
        BreakerConfig {
            failure_threshold: 2,
            cooldown_ticks: 10,
            max_doublings: 3,
            jitter_ticks: 0,
            seed: 1,
        }
    }

    #[test]
    fn trips_after_threshold_and_rejects_with_remaining_cooldown() {
        let br = CircuitBreaker::new(cfg_no_jitter());
        assert_eq!(br.allow("w", 0), Admission::Admit);
        br.on_failure("w", 0);
        assert_eq!(br.allow("w", 0), Admission::Admit, "below threshold stays closed");
        br.on_failure("w", 0); // second failure trips: open until 10
        assert!(br.is_open("w", 0));
        assert_eq!(br.allow("w", 4), Admission::Reject { retry_after_ticks: 6 });
        assert_eq!(br.allow("w", 9), Admission::Reject { retry_after_ticks: 1 });
        assert_eq!(br.allow("w", 10), Admission::Probe, "cooldown tick admits the probe");
    }

    #[test]
    fn probe_success_closes_and_resets_backoff() {
        let br = CircuitBreaker::new(cfg_no_jitter());
        br.on_failure("w", 0);
        br.on_failure("w", 0);
        assert_eq!(br.allow("w", 10), Admission::Probe);
        br.on_success("w", 11);
        assert_eq!(br.allow("w", 11), Admission::Admit);
        assert_eq!(br.opens("w"), 0, "success resets the backoff exponent");
    }

    #[test]
    fn probe_failure_reopens_with_doubled_cooldown() {
        let br = CircuitBreaker::new(cfg_no_jitter());
        br.on_failure("w", 0);
        br.on_failure("w", 0); // open #1: until 10
        assert_eq!(br.allow("w", 10), Admission::Probe);
        br.on_failure("w", 10); // open #2: cooldown 20, until 30
        assert_eq!(br.allow("w", 12), Admission::Reject { retry_after_ticks: 18 });
        assert_eq!(br.allow("w", 30), Admission::Probe);
        br.on_failure("w", 30); // open #3: cooldown 40, until 70
        assert_eq!(br.allow("w", 30), Admission::Reject { retry_after_ticks: 40 });
        assert_eq!(br.opens("w"), 3);
    }

    #[test]
    fn backoff_doubling_is_capped() {
        let cfg = BreakerConfig { max_doublings: 2, ..cfg_no_jitter() };
        let br = CircuitBreaker::new(cfg);
        let mut now = 0;
        for _ in 0..5 {
            br.on_failure("w", now);
            br.on_failure("w", now);
            // Walk time to the probe, fail it too.
            while br.is_open("w", now) {
                now += 1;
            }
            assert_eq!(br.allow("w", now), Admission::Probe);
            br.on_failure("w", now);
            while br.is_open("w", now) {
                now += 1;
            }
            assert_eq!(br.allow("w", now), Admission::Probe);
            br.on_success("w", now);
        }
        // Never exceeded base << 2 per wait; reaching here without the
        // loop running away is the assertion.
        assert!(now < 1000, "cap kept cooldowns bounded, now={now}");
    }

    #[test]
    fn only_one_probe_at_a_time() {
        let br = CircuitBreaker::new(cfg_no_jitter());
        br.on_failure("w", 0);
        br.on_failure("w", 0);
        assert_eq!(br.allow("w", 10), Admission::Probe);
        assert!(matches!(br.allow("w", 10), Admission::Reject { .. }));
        br.abandon_probe("w");
        assert_eq!(br.allow("w", 10), Admission::Probe, "abandoned probe frees the slot");
    }

    #[test]
    fn classes_are_independent() {
        let br = CircuitBreaker::new(cfg_no_jitter());
        br.on_failure("sick", 0);
        br.on_failure("sick", 0);
        assert!(matches!(br.allow("sick", 1), Admission::Reject { .. }));
        assert_eq!(br.allow("healthy", 1), Admission::Admit);
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let cfg = BreakerConfig { jitter_ticks: 25, ..cfg_no_jitter() };
        let trip = |seed: u64| {
            let br = CircuitBreaker::new(BreakerConfig { seed, ..cfg.clone() });
            br.on_failure("w", 0);
            br.on_failure("w", 0);
            match br.allow("w", 0) {
                Admission::Reject { retry_after_ticks } => retry_after_ticks,
                other => panic!("expected reject, got {other:?}"),
            }
        };
        let a = trip(7);
        assert_eq!(a, trip(7), "same seed, same jitter");
        assert!((10..=35).contains(&a), "cooldown 10 + jitter 0..=25, got {a}");
        // Different seeds de-synchronize (xoshiro makes collisions on
        // a 26-value range across these two seeds vanishingly unlikely,
        // and the assertion is deterministic either way).
        let differs = (0..8).any(|s| trip(s) != a);
        assert!(differs, "jitter must vary across seeds");
    }
}
