//! `serve::protocol` — the dependency-free framed wire protocol for
//! [`rhpx serve`](crate::serve).
//!
//! One frame = an 8-byte versioned header (`magic "rh"`, version, tag,
//! payload length), a length-prefixed payload, and a trailing FNV-1a
//! checksum over header + payload. Payload bytes for job submissions are
//! the [`SnapshotData`] encoding of [`JobSpec`] — the same bytes the
//! server journals through a [`crate::checkpoint::SnapshotStore`], so
//! what travels on the wire is exactly what survives a daemon restart.
//!
//! Decoding is total: any byte stream yields either a complete
//! `(Frame, consumed)` pair or a typed [`FrameError`] — never a panic,
//! never a partial frame, never an unbounded allocation
//! ([`FrameError::Oversize`] caps the length field before any buffer is
//! sized from it). [`FrameError::Truncated`] doubles as the streaming
//! "need more bytes" signal for TCP readers accumulating a buffer.
//!
//! Paper mapping: the wire layer of the service-level resilience story —
//! checksummed framing is the same detection-by-redundancy pattern the
//! task layer uses for silent data corruption, applied to bytes in
//! flight instead of task outputs.

use crate::checkpoint::SnapshotData;

/// Protocol magic: first two bytes of every frame.
pub const MAGIC: [u8; 2] = *b"rh";

/// Current protocol version; [`Frame::decode`] rejects anything else.
pub const PROTOCOL_VERSION: u8 = 1;

/// Upper bound on the payload-length field. Bounds the allocation a
/// hostile or corrupted length prefix can demand.
pub const MAX_PAYLOAD: usize = 1 << 20;

const HEADER_LEN: usize = 8;
const CHECKSUM_LEN: usize = 8;

const TAG_SUBMIT: u8 = 1;
const TAG_ACK: u8 = 2;
const TAG_RESULT: u8 = 3;
const TAG_STATUS: u8 = 4;
const TAG_REJECT: u8 = 5;
// Process-locality substrate (`distributed::proc`): parent ⇄ worker
// task traffic rides the same framing as the service protocol.
const TAG_LAUNCH: u8 = 6;
const TAG_TASK_RESULT: u8 = 7;
const TAG_HEARTBEAT: u8 = 8;
const TAG_SNAPSHOT: u8 = 9;
// Observability: flight-recorder chunks streamed worker → parent (the
// same bytes the worker fsyncs to its local spool), and periodic
// perfcounter snapshots folded into the parent registry.
const TAG_TRACE: u8 = 10;
const TAG_COUNTERS: u8 = 11;

/// FNV-1a over `bytes`. Every step is a bijection of the running state,
/// so any single-byte difference in the covered region is guaranteed to
/// change the digest (multi-byte garbling is caught probabilistically,
/// like any 64-bit checksum).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A job submission: which zoo workload to run, under which resilience
/// policy, at what scale and injected fault probability.
///
/// Implements [`SnapshotData`]; the Submit frame payload and the
/// server's journal entry share this encoding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// Client-chosen identifier; the exactly-once boundary. Resubmitting
    /// a completed `job_id` returns the cached result.
    pub job_id: u64,
    /// Workload name from the zoo registry (`workloads::WORKLOADS`).
    pub workload: String,
    /// `PolicySpec` token (e.g. `replay:3`), or empty for no resilience.
    pub policy: String,
    /// Workload scale ×1000 (250 ⇒ scale 0.25).
    pub scale_milli: u32,
    /// Per-task injected-failure probability ×100 (0..=99).
    pub error_prob_pct: u32,
}

impl JobSpec {
    /// Scale as the zoo's `f64` factor.
    pub fn scale(&self) -> f64 {
        self.scale_milli as f64 / 1000.0
    }

    /// Injected-failure probability in `[0, 1)`.
    pub fn error_prob(&self) -> f64 {
        (self.error_prob_pct.min(99)) as f64 / 100.0
    }
}

impl SnapshotData for JobSpec {
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + self.workload.len() + self.policy.len());
        out.extend_from_slice(&self.job_id.to_le_bytes());
        out.extend_from_slice(&self.scale_milli.to_le_bytes());
        out.extend_from_slice(&self.error_prob_pct.to_le_bytes());
        put_str(&mut out, &self.workload);
        put_str(&mut out, &self.policy);
        out
    }

    fn from_bytes(bytes: &[u8]) -> Option<Self> {
        let mut c = Cursor::new(bytes);
        let spec = JobSpec {
            job_id: c.u64()?,
            scale_milli: c.u32()?,
            error_prob_pct: c.u32()?,
            workload: c.str()?,
            policy: c.str()?,
        };
        c.done()?;
        Some(spec)
    }
}

/// Lifecycle state of a journaled job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobState {
    /// Acked to the client but not yet completed; a restart re-runs it.
    Accepted,
    /// Completed (`ok` = ran to completion without launch errors);
    /// `checksum_bits` is the workload's final checksum as `f64` bits.
    Done { ok: bool, checksum_bits: u64 },
}

/// What the server journals per accepted job: the spec (so a restart can
/// re-run it) plus its lifecycle state (so a restart never re-runs a
/// completed job — the exactly-once half of the ledger).
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub spec: JobSpec,
    pub state: JobState,
}

impl SnapshotData for JobRecord {
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self.state {
            JobState::Accepted => out.push(0),
            JobState::Done { ok, checksum_bits } => {
                out.push(1);
                out.push(ok as u8);
                out.extend_from_slice(&checksum_bits.to_le_bytes());
            }
        }
        out.extend_from_slice(&self.spec.to_bytes());
        out
    }

    fn from_bytes(bytes: &[u8]) -> Option<Self> {
        let (state, rest) = match bytes.split_first()? {
            (0, rest) => (JobState::Accepted, rest),
            (1, rest) => {
                let mut c = Cursor::new(rest);
                let ok = match c.u8()? {
                    0 => false,
                    1 => true,
                    _ => return None,
                };
                let checksum_bits = c.u64()?;
                (JobState::Done { ok, checksum_bits }, &rest[9..])
            }
            _ => return None,
        };
        Some(JobRecord { state, spec: JobSpec::from_bytes(rest)? })
    }
}

/// One task launch shipped to a worker process
/// ([`crate::distributed::proc`]): which zoo workload body to run
/// (named, not serialized — bodies are pure per the [`Workload`
/// contract](crate::workloads::Workload), so `(workload, layer, index)`
/// identifies the exact function on both sides) plus the resolved
/// dependency values as [`SnapshotData`] chunk bytes.
///
/// Implements [`SnapshotData`] so the Launch payload shares the same
/// untrusted-bytes hardening as every other wire structure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskDesc {
    /// Parent-chosen launch identifier; `TaskResult` frames echo it.
    pub task_id: u64,
    /// Workload name from the zoo registry (`workloads::WORKLOADS`).
    pub workload: String,
    /// Workload scale ×1000 (the worker rebuilds the workload with it).
    pub scale_milli: u32,
    /// DAG layer of the task body (`Workload::layer_tasks(layer)`).
    pub layer: u32,
    /// Slot index within the layer.
    pub index: u32,
    /// Resolved dependency values, one `Chunk::to_bytes()` each.
    pub inputs: Vec<Vec<u8>>,
}

impl SnapshotData for TaskDesc {
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&self.task_id.to_le_bytes());
        out.extend_from_slice(&self.scale_milli.to_le_bytes());
        out.extend_from_slice(&self.layer.to_le_bytes());
        out.extend_from_slice(&self.index.to_le_bytes());
        put_str(&mut out, &self.workload);
        out.extend_from_slice(&(self.inputs.len() as u32).to_le_bytes());
        for input in &self.inputs {
            put_bytes(&mut out, input);
        }
        out
    }

    fn from_bytes(bytes: &[u8]) -> Option<Self> {
        let mut c = Cursor::new(bytes);
        let task_id = c.u64()?;
        let scale_milli = c.u32()?;
        let layer = c.u32()?;
        let index = c.u32()?;
        let workload = c.str()?;
        let n = usize::try_from(c.u32()?).ok()?;
        // The count field is untrusted: capacity is bounded by the bytes
        // actually present (each input costs ≥ 4 length bytes).
        let mut inputs = Vec::with_capacity(n.min(bytes.len() / 4 + 1));
        for _ in 0..n {
            inputs.push(c.bytes()?.to_vec());
        }
        c.done()?;
        Some(TaskDesc { task_id, workload, scale_milli, layer, index, inputs })
    }
}

/// Server-side counters a Status frame carries, plus end-to-end job
/// latency quantiles (µs, from the server's `LatencyHistogram`; 0 until
/// a job has completed) and a named perfcounter snapshot — a live
/// daemon is observable without restarting it.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatusReport {
    pub submitted: u64,
    pub accepted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected_queue: u64,
    pub rejected_breaker: u64,
    pub queue_depth: u64,
    pub queue_capacity: u64,
    /// Median job latency, microseconds.
    pub p50_us: u64,
    /// 99th-percentile job latency, microseconds.
    pub p99_us: u64,
    /// 99.9th-percentile job latency, microseconds.
    pub p999_us: u64,
    /// Perfcounter snapshot (`/serve/...`, `/scheduler/...`,
    /// `/resilience/...`) — empty in client-side query frames.
    pub counters: Vec<(String, u64)>,
}

/// One protocol message. Clients send `Submit` and (empty) `Status`
/// queries; the server answers with `Ack`/`Result`/`Reject` and filled
/// `Status` frames.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server: run this job.
    Submit(JobSpec),
    /// Server → client: the job was accepted and journaled; a `Result`
    /// frame will follow.
    Ack { job_id: u64 },
    /// Server → client: terminal outcome of an accepted job.
    Result { job_id: u64, ok: bool, checksum_bits: u64, detail: String },
    /// Health/state snapshot. A client sends the default (all-zero)
    /// report as a query; the server replies with counters filled in.
    Status(StatusReport),
    /// Server → client: not accepted — back off and retry (or fix the
    /// request; `reason` says which).
    Reject { job_id: u64, retry_after_ms: u64, reason: String },
    /// Parent → worker: run this task ([`crate::distributed::proc`]).
    Launch(TaskDesc),
    /// Worker → parent: outcome of a [`Frame::Launch`]. On success
    /// `payload` is the task output (`Vec<f64>` snapshot bytes); on
    /// failure it is the UTF-8 error text.
    TaskResult { task_id: u64, ok: bool, payload: Vec<u8> },
    /// Worker → parent: liveness beacon. The first beat (`seq` 0) also
    /// serves as the connection hello that maps a socket to a locality;
    /// the parent's `HeartbeatMonitor` declares a locality dead after K
    /// missed periods.
    Heartbeat { locality: u32, seq: u64 },
    /// Parent → worker: mirror this snapshot (checkpoint re-homing for
    /// the `checkpoint:K` policy on the process substrate).
    Snapshot { key: String, bytes: Vec<u8> },
    /// Worker → parent: a flight-recorder chunk
    /// ([`crate::trace::spool::TraceChunk`]) — streamed opportunistically
    /// while the identical bytes are fsynced to the worker's local spool.
    Trace(crate::trace::spool::TraceChunk),
    /// Worker → parent: periodic perfcounter snapshot, folded into the
    /// parent registry as `/locality/<id>/...`.
    Counters { locality: u32, counters: Vec<(String, u64)> },
}

/// Typed decode failure. `Truncated` is retryable with more bytes;
/// everything else means the stream is corrupt at this frame boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Not enough bytes yet for a complete frame.
    Truncated { needed: usize, have: usize },
    /// First two bytes are not [`MAGIC`].
    BadMagic { got: [u8; 2] },
    /// Version byte is not [`PROTOCOL_VERSION`].
    BadVersion { got: u8 },
    /// Header is valid and checksummed but the tag is unknown.
    UnknownTag { got: u8 },
    /// Length field exceeds [`MAX_PAYLOAD`].
    Oversize { len: usize },
    /// FNV-1a over header + payload does not match the trailer.
    ChecksumMismatch { expected: u64, got: u64 },
    /// Payload bytes do not decode as the tagged variant.
    BadPayload { tag: &'static str },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated { needed, have } => {
                write!(f, "truncated frame: need {needed} bytes, have {have}")
            }
            FrameError::BadMagic { got } => write!(f, "bad magic {got:?}"),
            FrameError::BadVersion { got } => {
                write!(f, "unsupported protocol version {got} (want {PROTOCOL_VERSION})")
            }
            FrameError::UnknownTag { got } => write!(f, "unknown frame tag {got}"),
            FrameError::Oversize { len } => {
                write!(f, "payload length {len} exceeds cap {MAX_PAYLOAD}")
            }
            FrameError::ChecksumMismatch { expected, got } => {
                write!(f, "frame checksum mismatch: computed {expected:#x}, stored {got:#x}")
            }
            FrameError::BadPayload { tag } => write!(f, "malformed {tag} payload"),
        }
    }
}

impl std::error::Error for FrameError {}

impl Frame {
    fn tag(&self) -> u8 {
        match self {
            Frame::Submit(_) => TAG_SUBMIT,
            Frame::Ack { .. } => TAG_ACK,
            Frame::Result { .. } => TAG_RESULT,
            Frame::Status(_) => TAG_STATUS,
            Frame::Reject { .. } => TAG_REJECT,
            Frame::Launch(_) => TAG_LAUNCH,
            Frame::TaskResult { .. } => TAG_TASK_RESULT,
            Frame::Heartbeat { .. } => TAG_HEARTBEAT,
            Frame::Snapshot { .. } => TAG_SNAPSHOT,
            Frame::Trace(_) => TAG_TRACE,
            Frame::Counters { .. } => TAG_COUNTERS,
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Frame::Submit(spec) => p = spec.to_bytes(),
            Frame::Ack { job_id } => p.extend_from_slice(&job_id.to_le_bytes()),
            Frame::Result { job_id, ok, checksum_bits, detail } => {
                p.extend_from_slice(&job_id.to_le_bytes());
                p.push(*ok as u8);
                p.extend_from_slice(&checksum_bits.to_le_bytes());
                put_str(&mut p, detail);
            }
            Frame::Status(s) => {
                for v in [
                    s.submitted,
                    s.accepted,
                    s.completed,
                    s.failed,
                    s.rejected_queue,
                    s.rejected_breaker,
                    s.queue_depth,
                    s.queue_capacity,
                    s.p50_us,
                    s.p99_us,
                    s.p999_us,
                ] {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                put_counters(&mut p, &s.counters);
            }
            Frame::Reject { job_id, retry_after_ms, reason } => {
                p.extend_from_slice(&job_id.to_le_bytes());
                p.extend_from_slice(&retry_after_ms.to_le_bytes());
                put_str(&mut p, reason);
            }
            Frame::Launch(desc) => p = desc.to_bytes(),
            Frame::TaskResult { task_id, ok, payload } => {
                p.extend_from_slice(&task_id.to_le_bytes());
                p.push(*ok as u8);
                put_bytes(&mut p, payload);
            }
            Frame::Heartbeat { locality, seq } => {
                p.extend_from_slice(&locality.to_le_bytes());
                p.extend_from_slice(&seq.to_le_bytes());
            }
            Frame::Snapshot { key, bytes } => {
                put_str(&mut p, key);
                put_bytes(&mut p, bytes);
            }
            Frame::Trace(chunk) => p = chunk.to_bytes(),
            Frame::Counters { locality, counters } => {
                p.extend_from_slice(&locality.to_le_bytes());
                put_counters(&mut p, counters);
            }
        }
        p
    }

    /// Encode as header ∥ payload ∥ checksum.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.payload();
        assert!(payload.len() <= MAX_PAYLOAD, "frame payload exceeds protocol cap");
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
        out.extend_from_slice(&MAGIC);
        out.push(PROTOCOL_VERSION);
        out.push(self.tag());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Decode one frame from the front of `buf`; returns the frame and
    /// the number of bytes consumed (trailing bytes are the next
    /// frame's, untouched). [`FrameError::Truncated`] means "feed me
    /// more bytes"; any other error means the stream is corrupt.
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
        if buf.len() < HEADER_LEN {
            return Err(FrameError::Truncated { needed: HEADER_LEN, have: buf.len() });
        }
        if buf[0..2] != MAGIC {
            return Err(FrameError::BadMagic { got: [buf[0], buf[1]] });
        }
        if buf[2] != PROTOCOL_VERSION {
            return Err(FrameError::BadVersion { got: buf[2] });
        }
        let len = u32::from_le_bytes(buf[4..8].try_into().expect("4 bytes")) as usize;
        if len > MAX_PAYLOAD {
            return Err(FrameError::Oversize { len });
        }
        let total = HEADER_LEN + len + CHECKSUM_LEN;
        if buf.len() < total {
            return Err(FrameError::Truncated { needed: total, have: buf.len() });
        }
        let expected = fnv1a(&buf[..HEADER_LEN + len]);
        let got = u64::from_le_bytes(
            buf[HEADER_LEN + len..total].try_into().expect("8 bytes"),
        );
        if expected != got {
            return Err(FrameError::ChecksumMismatch { expected, got });
        }
        let payload = &buf[HEADER_LEN..HEADER_LEN + len];
        let frame = match buf[3] {
            TAG_SUBMIT => Frame::Submit(
                JobSpec::from_bytes(payload).ok_or(FrameError::BadPayload { tag: "Submit" })?,
            ),
            TAG_ACK => {
                let mut c = Cursor::new(payload);
                let job_id = c.u64().ok_or(FrameError::BadPayload { tag: "Ack" })?;
                c.done().ok_or(FrameError::BadPayload { tag: "Ack" })?;
                Frame::Ack { job_id }
            }
            TAG_RESULT => {
                let mut c = Cursor::new(payload);
                let parse = || -> Option<Frame> {
                    let job_id = c.u64()?;
                    let ok = match c.u8()? {
                        0 => false,
                        1 => true,
                        _ => return None,
                    };
                    let checksum_bits = c.u64()?;
                    let detail = c.str()?;
                    c.done()?;
                    Some(Frame::Result { job_id, ok, checksum_bits, detail })
                };
                parse().ok_or(FrameError::BadPayload { tag: "Result" })?
            }
            TAG_STATUS => {
                let mut c = Cursor::new(payload);
                let parse = || -> Option<Frame> {
                    let s = StatusReport {
                        submitted: c.u64()?,
                        accepted: c.u64()?,
                        completed: c.u64()?,
                        failed: c.u64()?,
                        rejected_queue: c.u64()?,
                        rejected_breaker: c.u64()?,
                        queue_depth: c.u64()?,
                        queue_capacity: c.u64()?,
                        p50_us: c.u64()?,
                        p99_us: c.u64()?,
                        p999_us: c.u64()?,
                        counters: c.counters()?,
                    };
                    c.done()?;
                    Some(Frame::Status(s))
                };
                parse().ok_or(FrameError::BadPayload { tag: "Status" })?
            }
            TAG_REJECT => {
                let mut c = Cursor::new(payload);
                let parse = || -> Option<Frame> {
                    let job_id = c.u64()?;
                    let retry_after_ms = c.u64()?;
                    let reason = c.str()?;
                    c.done()?;
                    Some(Frame::Reject { job_id, retry_after_ms, reason })
                };
                parse().ok_or(FrameError::BadPayload { tag: "Reject" })?
            }
            TAG_LAUNCH => Frame::Launch(
                TaskDesc::from_bytes(payload).ok_or(FrameError::BadPayload { tag: "Launch" })?,
            ),
            TAG_TASK_RESULT => {
                let mut c = Cursor::new(payload);
                let parse = || -> Option<Frame> {
                    let task_id = c.u64()?;
                    let ok = match c.u8()? {
                        0 => false,
                        1 => true,
                        _ => return None,
                    };
                    let payload = c.bytes()?.to_vec();
                    c.done()?;
                    Some(Frame::TaskResult { task_id, ok, payload })
                };
                parse().ok_or(FrameError::BadPayload { tag: "TaskResult" })?
            }
            TAG_HEARTBEAT => {
                let mut c = Cursor::new(payload);
                let parse = || -> Option<Frame> {
                    let locality = c.u32()?;
                    let seq = c.u64()?;
                    c.done()?;
                    Some(Frame::Heartbeat { locality, seq })
                };
                parse().ok_or(FrameError::BadPayload { tag: "Heartbeat" })?
            }
            TAG_SNAPSHOT => {
                let mut c = Cursor::new(payload);
                let parse = || -> Option<Frame> {
                    let key = c.str()?;
                    let bytes = c.bytes()?.to_vec();
                    c.done()?;
                    Some(Frame::Snapshot { key, bytes })
                };
                parse().ok_or(FrameError::BadPayload { tag: "Snapshot" })?
            }
            TAG_TRACE => Frame::Trace(
                crate::trace::spool::TraceChunk::from_bytes(payload)
                    .ok_or(FrameError::BadPayload { tag: "Trace" })?,
            ),
            TAG_COUNTERS => {
                let mut c = Cursor::new(payload);
                let parse = || -> Option<Frame> {
                    let locality = c.u32()?;
                    let counters = c.counters()?;
                    c.done()?;
                    Some(Frame::Counters { locality, counters })
                };
                parse().ok_or(FrameError::BadPayload { tag: "Counters" })?
            }
            other => return Err(FrameError::UnknownTag { got: other }),
        };
        Ok((frame, total))
    }
}

/// Length-prefixed UTF-8 string (u32 LE length + bytes).
fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Length-prefixed raw bytes (u32 LE length + bytes).
fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

/// Named counter list: u32 LE count, then per entry a length-prefixed
/// name followed by a u64 LE value.
fn put_counters(out: &mut Vec<u8>, counters: &[(String, u64)]) {
    out.extend_from_slice(&(counters.len() as u32).to_le_bytes());
    for (name, v) in counters {
        put_str(out, name);
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over untrusted bytes: every
/// accessor returns `None` past the end, string lengths are checked
/// against the bytes actually present before any allocation.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let s = self.buf.get(self.pos..end)?;
        self.pos = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().expect("8 bytes")))
    }

    fn str(&mut self) -> Option<String> {
        let len = usize::try_from(self.u32()?).ok()?;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).ok()
    }

    /// Length-prefixed raw bytes (the [`put_bytes`] inverse); the length
    /// field is checked against the bytes present before any slice.
    fn bytes(&mut self) -> Option<&'a [u8]> {
        let len = usize::try_from(self.u32()?).ok()?;
        self.take(len)
    }

    /// Named counter list (the [`put_counters`] inverse). The count
    /// field is untrusted: capacity is bounded by the bytes actually
    /// present (each entry costs ≥ 12 length + value bytes).
    fn counters(&mut self) -> Option<Vec<(String, u64)>> {
        let n = usize::try_from(self.u32()?).ok()?;
        let mut out = Vec::with_capacity(n.min(self.buf.len() / 12 + 1));
        for _ in 0..n {
            let name = self.str()?;
            let v = self.u64()?;
            out.push((name, v));
        }
        Some(out)
    }

    /// All bytes consumed — trailing garbage is a decode failure.
    fn done(&self) -> Option<()> {
        (self.pos == self.buf.len()).then_some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Submit(JobSpec {
                job_id: 42,
                workload: "stencil1d".into(),
                policy: "replay:3".into(),
                scale_milli: 250,
                error_prob_pct: 10,
            }),
            Frame::Ack { job_id: 7 },
            Frame::Result {
                job_id: 7,
                ok: true,
                checksum_bits: 1.5f64.to_bits(),
                detail: "stencil1d ✓".into(),
            },
            Frame::Status(StatusReport {
                submitted: 10,
                accepted: 8,
                completed: 6,
                failed: 1,
                rejected_queue: 1,
                rejected_breaker: 1,
                queue_depth: 1,
                queue_capacity: 16,
                p50_us: 120,
                p99_us: 950,
                p999_us: 2400,
                counters: vec![
                    ("/serve/count/accepted".into(), 8),
                    ("/scheduler/count/spawned".into(), 41),
                ],
            }),
            // A client-side query frame: the all-zero default report.
            Frame::Status(StatusReport::default()),
            Frame::Reject { job_id: 9, retry_after_ms: 250, reason: "queue full".into() },
            Frame::Launch(TaskDesc {
                task_id: 1001,
                workload: "stencil1d".into(),
                scale_milli: 10,
                layer: 3,
                index: 2,
                inputs: vec![vec![1, 2, 3], vec![], vec![0xFF; 9]],
            }),
            Frame::TaskResult { task_id: 1001, ok: true, payload: vec![9, 8, 7] },
            Frame::TaskResult { task_id: 1002, ok: false, payload: b"kernel diverged".to_vec() },
            Frame::Heartbeat { locality: 2, seq: 0 },
            Frame::Snapshot { key: "ckpt_4_1".into(), bytes: vec![0; 24] },
            Frame::Trace(crate::trace::spool::TraceChunk {
                locality: 1,
                seq: 3,
                dropped: 2,
                events: vec![
                    crate::trace::Event {
                        ts_ns: 1_000,
                        kind: crate::trace::EventKind::ExecBegin,
                        track: 0,
                        a: 7,
                        b: 0,
                    },
                    crate::trace::Event {
                        ts_ns: 2_500,
                        kind: crate::trace::EventKind::ExecEnd,
                        track: 0,
                        a: 7,
                        b: 1,
                    },
                ],
            }),
            Frame::Counters {
                locality: 2,
                counters: vec![("/resilience/count/executed".into(), 17)],
            },
        ]
    }

    #[test]
    fn every_variant_roundtrips() {
        for f in sample_frames() {
            let bytes = f.encode();
            let (back, consumed) = Frame::decode(&bytes).unwrap();
            assert_eq!(back, f);
            assert_eq!(consumed, bytes.len());
        }
    }

    #[test]
    fn decode_consumes_one_frame_from_a_stream() {
        let a = Frame::Ack { job_id: 1 }.encode();
        let b = Frame::Ack { job_id: 2 }.encode();
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let (f1, n1) = Frame::decode(&stream).unwrap();
        assert_eq!(f1, Frame::Ack { job_id: 1 });
        assert_eq!(n1, a.len());
        let (f2, n2) = Frame::decode(&stream[n1..]).unwrap();
        assert_eq!(f2, Frame::Ack { job_id: 2 });
        assert_eq!(n1 + n2, stream.len());
    }

    #[test]
    fn truncation_asks_for_more_bytes_at_every_cut() {
        let bytes = sample_frames()[0].encode();
        for cut in 0..bytes.len() {
            match Frame::decode(&bytes[..cut]) {
                Err(FrameError::Truncated { needed, have }) => {
                    assert_eq!(have, cut);
                    assert!(needed > cut);
                }
                other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn header_corruption_is_typed() {
        let mut bytes = Frame::Ack { job_id: 3 }.encode();
        bytes[0] = b'x';
        assert!(matches!(Frame::decode(&bytes), Err(FrameError::BadMagic { .. })));

        let mut bytes = Frame::Ack { job_id: 3 }.encode();
        bytes[2] = 99;
        assert_eq!(Frame::decode(&bytes), Err(FrameError::BadVersion { got: 99 }));

        // An oversize length field is rejected before any allocation or
        // wait-for-more-bytes, even though the buffer is short.
        let mut bytes = Frame::Ack { job_id: 3 }.encode();
        bytes[4..8].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert_eq!(Frame::decode(&bytes), Err(FrameError::Oversize { len: MAX_PAYLOAD + 1 }));
    }

    #[test]
    fn payload_corruption_fails_the_checksum() {
        let mut bytes = sample_frames()[0].encode();
        let mid = HEADER_LEN + 2;
        bytes[mid] ^= 0x40;
        assert!(matches!(Frame::decode(&bytes), Err(FrameError::ChecksumMismatch { .. })));
    }

    #[test]
    fn unknown_tag_with_valid_checksum_is_typed() {
        // Build a frame with tag 42 by hand, checksummed correctly (tags
        // 1..=11 are all assigned now).
        let mut bytes = vec![MAGIC[0], MAGIC[1], PROTOCOL_VERSION, 42, 0, 0, 0, 0];
        let sum = fnv1a(&bytes);
        bytes.extend_from_slice(&sum.to_le_bytes());
        assert_eq!(Frame::decode(&bytes), Err(FrameError::UnknownTag { got: 42 }));
    }

    #[test]
    fn job_spec_and_record_snapshot_roundtrip() {
        let spec = JobSpec {
            job_id: u64::MAX,
            workload: "jacobi".into(),
            policy: String::new(),
            scale_milli: 1000,
            error_prob_pct: 0,
        };
        assert_eq!(JobSpec::from_bytes(&spec.to_bytes()), Some(spec.clone()));
        for state in [JobState::Accepted, JobState::Done { ok: false, checksum_bits: 77 }] {
            let rec = JobRecord { spec: spec.clone(), state };
            assert_eq!(JobRecord::from_bytes(&rec.to_bytes()), Some(rec));
        }
        // Corrupt journal bytes decode to None, never panic.
        assert_eq!(JobRecord::from_bytes(&[]), None);
        assert_eq!(JobRecord::from_bytes(&[7, 1, 2, 3]), None);
        let mut truncated = JobRecord { spec, state: JobState::Accepted }.to_bytes();
        truncated.pop();
        assert_eq!(JobRecord::from_bytes(&truncated), None);
    }

    #[test]
    fn task_desc_snapshot_roundtrip_and_hostile_bytes() {
        let desc = TaskDesc {
            task_id: u64::MAX,
            workload: "jacobi".into(),
            scale_milli: 1000,
            layer: 0,
            index: 0,
            inputs: vec![vec![0u8; 64], vec![1]],
        };
        assert_eq!(TaskDesc::from_bytes(&desc.to_bytes()), Some(desc.clone()));
        // Truncated bytes decode to None, never panic.
        let bytes = desc.to_bytes();
        for cut in 0..bytes.len() {
            assert_eq!(TaskDesc::from_bytes(&bytes[..cut]), None, "cut {cut}");
        }
        // A hostile input count (claims 4 billion chunks, carries none)
        // must fail bounds checks instead of allocating.
        let mut hostile = TaskDesc { inputs: vec![], ..desc.clone() }.to_bytes();
        let count_at = hostile.len() - 4;
        hostile[count_at..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(TaskDesc::from_bytes(&hostile), None);
        // Trailing garbage is a decode failure, not silently ignored.
        let mut trailing = desc.to_bytes();
        trailing.push(0);
        assert_eq!(TaskDesc::from_bytes(&trailing), None);
    }

    #[test]
    fn spec_unit_conversions() {
        let spec = JobSpec {
            job_id: 1,
            workload: "stream".into(),
            policy: String::new(),
            scale_milli: 250,
            error_prob_pct: 40,
        };
        assert!((spec.scale() - 0.25).abs() < 1e-12);
        assert!((spec.error_prob() - 0.40).abs() < 1e-12);
    }
}
