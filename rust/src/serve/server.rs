//! `serve::server` — the long-running resilient task service.
//!
//! Composition of the three service-level resilience layers over the
//! task-level machinery the rest of the crate already provides:
//!
//! ```text
//!   client ──Frame──▶ AdmissionGate ──▶ CircuitBreaker ──▶ journal
//!                       (containment)     (detection)       (recovery)
//!                                                             │
//!                        executor threads ◀── pending queue ◀─┘
//!                             │ workloads::run + PolicySpec decorators
//!                             ▼
//!                        journal Done ──▶ Result frame / future
//! ```
//!
//! Every accepted job is journaled as [`JobState::Accepted`] through a
//! [`SnapshotStore`] *before* the Ack leaves the server, and re-journaled
//! as [`JobState::Done`] after execution. A restarted server scans the
//! journal: `Done` records refill the duplicate-answer cache, `Accepted`
//! records re-enter the queue — so killing the daemon loses no accepted
//! work, and completed work is never re-run (the lineage-ledger pattern
//! at job granularity). The exactly-once boundary is the journal write:
//! a crash *between* execution and the `Done` write re-runs that job on
//! restart, which is safe because workload bodies are pure.
//!
//! Time for the breaker is milliseconds since server start — monotonic,
//! and trivially replaced by a virtual clock in the scheduled tests.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::checkpoint::{SnapshotData, SnapshotStore};
use crate::future::Future;
use crate::metrics::LatencyHistogram;
use crate::runtime_handle::Runtime;
use crate::stencil::ExecPolicy;
use crate::trace::{self, EventKind};
use crate::workloads::{self, RunParams};
use crate::Promise;

use super::admission::{AdmissionGate, Decision};
use super::breaker::{Admission, BreakerConfig, CircuitBreaker};
use super::protocol::{Frame, FrameError, JobRecord, JobSpec, JobState, StatusReport};

/// Server tuning.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Admission bound: jobs queued or executing at once.
    pub queue_capacity: usize,
    /// Executor threads draining the queue (0 = manual stepping via
    /// [`Server::run_one`], which the tests and the recovery bench use).
    pub executors: usize,
    /// Worker threads in the shared task runtime.
    pub workers: usize,
    /// Retry hint handed out on queue-full rejections.
    pub retry_after_ms: u64,
    /// Circuit-breaker tuning (per task class = workload name).
    pub breaker: BreakerConfig,
    /// Base seed; each job runs with `seed ^ job_id`.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_capacity: 64,
            executors: 2,
            workers: 4,
            retry_after_ms: 50,
            breaker: BreakerConfig::default(),
            seed: 0x1CE,
        }
    }
}

/// Terminal outcome of an accepted job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    pub job_id: u64,
    /// Ran to completion with zero unrecovered launch errors.
    pub ok: bool,
    /// Workload final checksum as `f64` bits (for client-side
    /// cross-validation against a known-good run).
    pub checksum_bits: u64,
    pub detail: String,
}

/// Why a submission was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    QueueFull,
    BreakerOpen,
    UnknownWorkload,
    BadPolicy,
    DuplicateInFlight,
    JournalFailed,
}

impl RejectReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue full",
            RejectReason::BreakerOpen => "circuit open",
            RejectReason::UnknownWorkload => "unknown workload",
            RejectReason::BadPolicy => "bad policy",
            RejectReason::DuplicateInFlight => "duplicate job id in flight",
            RejectReason::JournalFailed => "journal write failed",
        }
    }
}

/// Outcome of [`Server::submit`].
#[derive(Debug)]
pub enum SubmitResponse {
    /// Journaled and queued; the future resolves with the outcome. If
    /// the server is stopped before the job runs, the future resolves
    /// with the broken-promise error — the job itself stays journaled
    /// and completes after restart.
    Accepted { future: Future<JobOutcome> },
    /// This `job_id` already completed — cached outcome, no re-run.
    AlreadyDone { outcome: JobOutcome },
    /// Not accepted; nothing was journaled.
    Rejected { reason: RejectReason, retry_after_ms: u64 },
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    accepted: AtomicU64,
    completed_ok: AtomicU64,
    failed: AtomicU64,
    rejected_queue: AtomicU64,
    rejected_breaker: AtomicU64,
    rejected_other: AtomicU64,
    executions: AtomicU64,
    deduped: AtomicU64,
    recovered_pending: AtomicU64,
    recovered_done: AtomicU64,
    journal_errors: AtomicU64,
}

/// Counter snapshot for benches and tests (the "counter algebra":
/// `executions + deduped` accounts for every queue pop, and
/// `completed_ok + failed == executions`).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub submitted: u64,
    pub accepted: u64,
    pub completed_ok: u64,
    pub failed: u64,
    pub rejected_queue: u64,
    pub rejected_breaker: u64,
    pub rejected_other: u64,
    pub executions: u64,
    pub deduped: u64,
    pub recovered_pending: u64,
    pub recovered_done: u64,
    pub journal_errors: u64,
    /// Deepest the admission gate ever was (bounded-queue evidence; can
    /// exceed capacity only via restart recovery).
    pub queue_high_water: u64,
}

impl ServerStats {
    /// Every rejection, whatever the layer.
    pub fn rejected(&self) -> u64 {
        self.rejected_queue + self.rejected_breaker + self.rejected_other
    }
}

struct Inner {
    cfg: ServeConfig,
    rt: Runtime,
    gate: AdmissionGate,
    breaker: CircuitBreaker,
    journal: Arc<dyn SnapshotStore>,
    queue: Mutex<VecDeque<JobSpec>>,
    queue_cv: Condvar,
    /// Queued-or-executing job ids — the duplicate guard for jobs that
    /// have no cached outcome yet (including recovered ones).
    pending_ids: Mutex<HashSet<u64>>,
    waiters: Mutex<HashMap<u64, Promise<JobOutcome>>>,
    results: Mutex<HashMap<u64, JobOutcome>>,
    inflight: AtomicUsize,
    counters: Counters,
    /// End-to-end job latency (µs), recorded around each execution;
    /// feeds the Status frame's p50/p99/p999.
    latency: Mutex<LatencyHistogram>,
    shutdown: AtomicBool,
    started: Instant,
}

fn journal_key(job_id: u64) -> String {
    format!("job_{job_id}")
}

impl Inner {
    fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    fn submit(&self, spec: JobSpec) -> SubmitResponse {
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);

        // Validate before consuming any slot: a malformed request must
        // not cost admission capacity.
        if workloads::by_name(&spec.workload, spec.scale()).is_none() {
            self.counters.rejected_other.fetch_add(1, Ordering::Relaxed);
            return SubmitResponse::Rejected { reason: RejectReason::UnknownWorkload, retry_after_ms: 0 };
        }
        if !spec.policy.is_empty() && ExecPolicy::parse(&spec.policy).is_err() {
            self.counters.rejected_other.fetch_add(1, Ordering::Relaxed);
            return SubmitResponse::Rejected { reason: RejectReason::BadPolicy, retry_after_ms: 0 };
        }

        // Exactly-once: a completed job id answers from the cache…
        if let Some(outcome) = self.results.lock().unwrap().get(&spec.job_id).cloned() {
            return SubmitResponse::AlreadyDone { outcome };
        }
        // …and an in-flight one is never double-queued.
        if self.pending_ids.lock().unwrap().contains(&spec.job_id) {
            self.counters.rejected_other.fetch_add(1, Ordering::Relaxed);
            return SubmitResponse::Rejected {
                reason: RejectReason::DuplicateInFlight,
                retry_after_ms: self.cfg.retry_after_ms,
            };
        }

        // Containment layer 1: bounded queue depth.
        match self.gate.try_admit() {
            Decision::Rejected { retry_after_ms } => {
                self.counters.rejected_queue.fetch_add(1, Ordering::Relaxed);
                trace::emit(EventKind::AdmissionReject, spec.job_id, 0);
                return SubmitResponse::Rejected { reason: RejectReason::QueueFull, retry_after_ms };
            }
            Decision::Admitted => {}
        }

        // Containment layer 2: per-class circuit breaker.
        match self.breaker.allow(&spec.workload, self.now_ms()) {
            Admission::Reject { retry_after_ticks } => {
                self.gate.release();
                self.counters.rejected_breaker.fetch_add(1, Ordering::Relaxed);
                trace::emit(EventKind::AdmissionReject, spec.job_id, 1);
                return SubmitResponse::Rejected {
                    reason: RejectReason::BreakerOpen,
                    retry_after_ms: retry_after_ticks,
                };
            }
            Admission::Admit | Admission::Probe => {}
        }

        // Recovery layer: journal *before* acking. If the journal write
        // fails the job was never accepted — undo both admissions.
        let record = JobRecord { spec: spec.clone(), state: JobState::Accepted };
        if self.journal.save(&journal_key(spec.job_id), &record.to_bytes()).is_err() {
            self.gate.release();
            self.breaker.abandon_probe(&spec.workload);
            self.counters.journal_errors.fetch_add(1, Ordering::Relaxed);
            self.counters.rejected_other.fetch_add(1, Ordering::Relaxed);
            return SubmitResponse::Rejected {
                reason: RejectReason::JournalFailed,
                retry_after_ms: self.cfg.retry_after_ms,
            };
        }

        let (promise, future) = Promise::new();
        self.pending_ids.lock().unwrap().insert(spec.job_id);
        self.waiters.lock().unwrap().insert(spec.job_id, promise);
        self.counters.accepted.fetch_add(1, Ordering::Relaxed);
        self.queue.lock().unwrap().push_back(spec);
        self.queue_cv.notify_one();
        SubmitResponse::Accepted { future }
    }

    /// Re-admit what a previous process journaled.
    fn recover(&self) {
        for key in self.journal.keys() {
            if !key.starts_with("job_") {
                continue;
            }
            let Some(bytes) = self.journal.load(&key) else { continue };
            let Some(record) = JobRecord::from_bytes(&bytes) else {
                // A corrupt journal entry is counted, not trusted.
                self.counters.journal_errors.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            match record.state {
                JobState::Done { ok, checksum_bits } => {
                    self.results.lock().unwrap().insert(
                        record.spec.job_id,
                        JobOutcome {
                            job_id: record.spec.job_id,
                            ok,
                            checksum_bits,
                            detail: "recovered".into(),
                        },
                    );
                    self.counters.recovered_done.fetch_add(1, Ordering::Relaxed);
                }
                JobState::Accepted => {
                    // Already accepted once — re-enter even past the cap
                    // rather than drop acked work.
                    self.gate.admit_unchecked();
                    self.pending_ids.lock().unwrap().insert(record.spec.job_id);
                    self.counters.recovered_pending.fetch_add(1, Ordering::Relaxed);
                    self.queue.lock().unwrap().push_back(record.spec);
                }
            }
        }
        self.queue_cv.notify_all();
    }

    /// Pop one job if available (never blocks).
    fn pop(&self) -> Option<JobSpec> {
        let spec = self.queue.lock().unwrap().pop_front()?;
        self.inflight.fetch_add(1, Ordering::SeqCst);
        Some(spec)
    }

    /// Run one popped job to completion and settle every layer.
    fn execute(&self, spec: JobSpec) {
        let outcome = if let Some(record) = self
            .journal
            .load(&journal_key(spec.job_id))
            .and_then(|b| JobRecord::from_bytes(&b))
            .filter(|r| matches!(r.state, JobState::Done { .. }))
        {
            // Journal says Done (a restart raced a duplicate): dedup.
            self.counters.deduped.fetch_add(1, Ordering::Relaxed);
            let JobState::Done { ok, checksum_bits } = record.state else { unreachable!() };
            JobOutcome { job_id: spec.job_id, ok, checksum_bits, detail: "deduplicated".into() }
        } else {
            self.counters.executions.fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            let outcome = self.run_workload(&spec);
            self.latency.lock().unwrap().record(t0.elapsed().as_micros() as u64);
            let record = JobRecord {
                spec: spec.clone(),
                state: JobState::Done { ok: outcome.ok, checksum_bits: outcome.checksum_bits },
            };
            if self.journal.save(&journal_key(spec.job_id), &record.to_bytes()).is_err() {
                // The run stands; a restart may re-run this job (at-least
                // -once at this boundary, by design).
                self.counters.journal_errors.fetch_add(1, Ordering::Relaxed);
            }
            let now = self.now_ms();
            if outcome.ok {
                self.breaker.on_success(&spec.workload, now);
                self.counters.completed_ok.fetch_add(1, Ordering::Relaxed);
            } else {
                let opens_before = self.breaker.opens(&spec.workload);
                self.breaker.on_failure(&spec.workload, now);
                let opens = self.breaker.opens(&spec.workload);
                if opens > opens_before {
                    trace::emit(
                        EventKind::BreakerTransition,
                        trace::key_hash(&spec.workload),
                        opens as u64,
                    );
                }
                self.counters.failed.fetch_add(1, Ordering::Relaxed);
            }
            outcome
        };

        self.results.lock().unwrap().insert(spec.job_id, outcome.clone());
        self.pending_ids.lock().unwrap().remove(&spec.job_id);
        if let Some(promise) = self.waiters.lock().unwrap().remove(&spec.job_id) {
            promise.set_value(outcome);
        }
        self.gate.release();
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }

    fn run_workload(&self, spec: &JobSpec) -> JobOutcome {
        let Some(w) = workloads::by_name(&spec.workload, spec.scale()) else {
            // Validated at submit; a recovered record could still name a
            // workload this build no longer has.
            return JobOutcome {
                job_id: spec.job_id,
                ok: false,
                checksum_bits: 0,
                detail: "unknown workload".into(),
            };
        };
        let resilience = if spec.policy.is_empty() {
            None
        } else {
            match ExecPolicy::parse(&spec.policy) {
                Ok(p) => Some(p),
                Err(_) => {
                    return JobOutcome {
                        job_id: spec.job_id,
                        ok: false,
                        checksum_bits: 0,
                        detail: "bad policy".into(),
                    }
                }
            }
        };
        let p = spec.error_prob();
        let params = RunParams {
            resilience,
            error_rate: (p > 0.0).then(|| -p.ln()),
            seed: self.cfg.seed ^ spec.job_id,
            ..RunParams::default()
        };
        match workloads::run(&self.rt, w.as_ref(), &params) {
            Ok((_, report)) => {
                let ok = report.launch_errors == 0;
                JobOutcome {
                    job_id: spec.job_id,
                    ok,
                    checksum_bits: report.final_checksum.to_bits(),
                    detail: format!(
                        "{} {}",
                        report.mode,
                        if ok { "ok" } else { "degraded" }
                    ),
                }
            }
            Err(e) => JobOutcome {
                job_id: spec.job_id,
                ok: false,
                checksum_bits: 0,
                detail: e.to_string(),
            },
        }
    }

    fn status(&self) -> StatusReport {
        let s = self.stats();
        let (p50_us, p99_us, p999_us) = {
            let h = self.latency.lock().unwrap();
            (
                h.quantile(0.5).unwrap_or(0),
                h.quantile(0.99).unwrap_or(0),
                h.quantile(0.999).unwrap_or(0),
            )
        };
        // Named counters: the server's own algebra under `/serve/...`,
        // plus whatever the process-wide registry holds (`/scheduler/...`
        // and `/resilience/...` once a run has published them).
        let mut counters: Vec<(String, u64)> = vec![
            ("/serve/count/submitted".into(), s.submitted),
            ("/serve/count/accepted".into(), s.accepted),
            ("/serve/count/completed".into(), s.completed_ok + s.deduped),
            ("/serve/count/failed".into(), s.failed),
            ("/serve/count/rejected-queue".into(), s.rejected_queue),
            ("/serve/count/rejected-breaker".into(), s.rejected_breaker),
            ("/serve/count/executions".into(), s.executions),
            ("/serve/count/deduped".into(), s.deduped),
        ];
        counters.extend(crate::perfcounters::global().snapshot());
        StatusReport {
            submitted: s.submitted,
            accepted: s.accepted,
            completed: s.completed_ok + s.deduped,
            failed: s.failed,
            rejected_queue: s.rejected_queue,
            rejected_breaker: s.rejected_breaker,
            queue_depth: self.gate.depth() as u64,
            queue_capacity: self.gate.capacity() as u64,
            p50_us,
            p99_us,
            p999_us,
            counters,
        }
    }

    fn stats(&self) -> ServerStats {
        let c = &self.counters;
        ServerStats {
            submitted: c.submitted.load(Ordering::Relaxed),
            accepted: c.accepted.load(Ordering::Relaxed),
            completed_ok: c.completed_ok.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            rejected_queue: c.rejected_queue.load(Ordering::Relaxed),
            rejected_breaker: c.rejected_breaker.load(Ordering::Relaxed),
            rejected_other: c.rejected_other.load(Ordering::Relaxed),
            executions: c.executions.load(Ordering::Relaxed),
            deduped: c.deduped.load(Ordering::Relaxed),
            recovered_pending: c.recovered_pending.load(Ordering::Relaxed),
            recovered_done: c.recovered_done.load(Ordering::Relaxed),
            journal_errors: c.journal_errors.load(Ordering::Relaxed),
            queue_high_water: self.gate.counters().2 as u64,
        }
    }
}

/// The `rhpx serve` daemon, transport-free core. TCP is one adapter
/// ([`Server::listen`]); tests drive [`Server::submit`] and
/// [`Server::handle_frame`] directly as an in-memory transport.
pub struct Server {
    inner: Arc<Inner>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Server {
    /// Start a server over `journal`, recover journaled work from a
    /// previous process, and spawn the executor threads.
    pub fn start(cfg: ServeConfig, journal: Arc<dyn SnapshotStore>) -> Server {
        let rt = Runtime::builder().workers(cfg.workers.max(1)).build();
        let inner = Arc::new(Inner {
            gate: AdmissionGate::new(cfg.queue_capacity, cfg.retry_after_ms),
            breaker: CircuitBreaker::new(cfg.breaker.clone()),
            cfg,
            rt,
            journal,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            pending_ids: Mutex::new(HashSet::new()),
            waiters: Mutex::new(HashMap::new()),
            results: Mutex::new(HashMap::new()),
            inflight: AtomicUsize::new(0),
            counters: Counters::default(),
            latency: Mutex::new(LatencyHistogram::new()),
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
        });
        inner.recover();
        let mut threads = Vec::new();
        for i in 0..inner.cfg.executors {
            let inner = Arc::clone(&inner);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rhpx-serve-exec-{i}"))
                    .spawn(move || executor_loop(&inner))
                    .expect("spawn executor thread"),
            );
        }
        Server { inner, threads: Mutex::new(threads) }
    }

    /// Submit a job (the in-memory transport).
    pub fn submit(&self, spec: JobSpec) -> SubmitResponse {
        self.inner.submit(spec)
    }

    /// Protocol adapter: answer one client frame. `Submit` answers with
    /// `Ack`/`Result`/`Reject` plus (for fresh acceptances) the future
    /// the transport should watch to send the eventual `Result` frame.
    pub fn handle_frame(&self, frame: &Frame) -> (Frame, Option<Future<JobOutcome>>) {
        match frame {
            Frame::Submit(spec) => {
                let job_id = spec.job_id;
                match self.inner.submit(spec.clone()) {
                    SubmitResponse::Accepted { future } => (Frame::Ack { job_id }, Some(future)),
                    SubmitResponse::AlreadyDone { outcome } => (result_frame(&outcome), None),
                    SubmitResponse::Rejected { reason, retry_after_ms } => (
                        Frame::Reject {
                            job_id,
                            retry_after_ms,
                            reason: reason.as_str().to_string(),
                        },
                        None,
                    ),
                }
            }
            Frame::Status(_) => (Frame::Status(self.inner.status()), None),
            other => {
                // Server-to-client frames arriving at the server are a
                // client bug, answered explicitly rather than dropped.
                let job_id = match other {
                    Frame::Ack { job_id } | Frame::Result { job_id, .. } | Frame::Reject { job_id, .. } => {
                        *job_id
                    }
                    _ => 0,
                };
                (
                    Frame::Reject { job_id, retry_after_ms: 0, reason: "unexpected frame".into() },
                    None,
                )
            }
        }
    }

    /// Execute one queued job on the calling thread; false if the queue
    /// is empty. Manual stepping for tests and the recovery bench
    /// (`executors: 0`).
    pub fn run_one(&self) -> bool {
        match self.inner.pop() {
            Some(spec) => {
                self.inner.execute(spec);
                true
            }
            None => false,
        }
    }

    /// Cached outcome of a completed job.
    pub fn outcome(&self, job_id: u64) -> Option<JobOutcome> {
        self.inner.results.lock().unwrap().get(&job_id).cloned()
    }

    /// Queued + executing jobs.
    pub fn pending(&self) -> usize {
        self.inner.queue.lock().unwrap().len() + self.inner.inflight.load(Ordering::SeqCst)
    }

    /// Block until the queue drains (true) or `timeout` elapses (false).
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.pending() > 0 {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    pub fn status(&self) -> StatusReport {
        self.inner.status()
    }

    pub fn stats(&self) -> ServerStats {
        self.inner.stats()
    }

    /// Stop accepting and executing: executor threads finish their
    /// current job and exit, queued jobs stay journaled as `Accepted`
    /// (a restart picks them up) and their futures resolve with the
    /// broken-promise error. This is the test harness's "kill the
    /// daemon mid-flight".
    pub fn stop(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        let threads: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
        // Unexecuted jobs: drop their promises so waiting clients see
        // the broken-promise error instead of hanging.
        self.inner.waiters.lock().unwrap().clear();
    }

    /// Bind `addr` and serve the framed protocol; returns the bound
    /// address (so `:0` works in tests) and the acceptor handle, which
    /// exits shortly after [`Server::stop`].
    pub fn listen(&self, addr: &str) -> std::io::Result<(SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let inner = Arc::clone(&self.inner);
        let handle = std::thread::Builder::new()
            .name("rhpx-serve-accept".into())
            .spawn(move || {
                while !inner.shutdown.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            // A thread-less Server wrapper: connections
                            // share the core but own no executors.
                            let conn = Server {
                                inner: Arc::clone(&inner),
                                threads: Mutex::new(Vec::new()),
                            };
                            let _ = std::thread::Builder::new()
                                .name("rhpx-serve-conn".into())
                                .spawn(move || handle_connection(&conn, stream));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok((local, handle))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Only the executor-owning instance has threads to stop; the
        // per-connection clones carry none.
        if !self.threads.lock().unwrap().is_empty() {
            self.stop();
        }
    }
}

fn result_frame(outcome: &JobOutcome) -> Frame {
    Frame::Result {
        job_id: outcome.job_id,
        ok: outcome.ok,
        checksum_bits: outcome.checksum_bits,
        detail: outcome.detail.clone(),
    }
}

fn executor_loop(inner: &Arc<Inner>) {
    loop {
        let spec = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if inner.shutdown.load(Ordering::SeqCst) {
                    // Deliberately abandons the queue: pending jobs stay
                    // journaled for the next incarnation.
                    return;
                }
                if let Some(spec) = queue.pop_front() {
                    inner.inflight.fetch_add(1, Ordering::SeqCst);
                    break spec;
                }
                let (q, _) =
                    inner.queue_cv.wait_timeout(queue, Duration::from_millis(50)).unwrap();
                queue = q;
            }
        };
        inner.execute(spec);
    }
}

/// One client connection: accumulate bytes, decode frames, dispatch,
/// stream back `Result` frames as accepted jobs finish.
fn handle_connection(server: &Server, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut watched: Vec<Future<JobOutcome>> = Vec::new();
    loop {
        if server.inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Flush outcomes whose futures resolved since the last pass.
        let mut i = 0;
        while i < watched.len() {
            if watched[i].is_ready() {
                let f = watched.swap_remove(i);
                let reply = match f.get() {
                    Ok(outcome) => result_frame(&outcome),
                    // Broken promise: the server stopped before running
                    // the job; the client reconnects after restart.
                    Err(e) => Frame::Reject {
                        job_id: 0,
                        retry_after_ms: 0,
                        reason: format!("job interrupted: {e}"),
                    },
                };
                if writer.write_all(&reply.encode()).is_err() {
                    return;
                }
            } else {
                i += 1;
            }
        }
        match reader.read(&mut chunk) {
            Ok(0) => return, // client hung up
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return,
        }
        loop {
            match Frame::decode(&buf) {
                Ok((frame, consumed)) => {
                    buf.drain(..consumed);
                    let (reply, future) = server.handle_frame(&frame);
                    if let Some(f) = future {
                        watched.push(f);
                    }
                    if writer.write_all(&reply.encode()).is_err() {
                        return;
                    }
                }
                Err(FrameError::Truncated { .. }) => break, // need more bytes
                Err(e) => {
                    // Framing is lost: answer once, then drop the
                    // connection (resynchronizing a corrupt byte stream
                    // is not possible with length-prefixed frames).
                    let reply = Frame::Reject {
                        job_id: 0,
                        retry_after_ms: 0,
                        reason: format!("protocol error: {e}"),
                    };
                    let _ = writer.write_all(&reply.encode());
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::MemorySnapshotStore;

    fn quick_cfg(executors: usize) -> ServeConfig {
        ServeConfig {
            queue_capacity: 8,
            executors,
            workers: 2,
            retry_after_ms: 5,
            breaker: BreakerConfig { failure_threshold: 2, ..BreakerConfig::default() },
            seed: 0x1CE,
        }
    }

    fn spec(job_id: u64, workload: &str, error_prob_pct: u32) -> JobSpec {
        JobSpec {
            job_id,
            workload: workload.into(),
            policy: String::new(),
            scale_milli: 100,
            error_prob_pct,
        }
    }

    #[test]
    fn submit_executes_and_resolves_the_future() {
        let server = Server::start(quick_cfg(1), Arc::new(MemorySnapshotStore::new()));
        let SubmitResponse::Accepted { future } = server.submit(spec(1, "stencil1d", 0)) else {
            panic!("expected acceptance");
        };
        let outcome = future.get().expect("job completes");
        assert!(outcome.ok, "{outcome:?}");
        assert_eq!(outcome.job_id, 1);
        assert!(server.drain(Duration::from_secs(10)));
        let stats = server.stats();
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.executions, 1);
        assert_eq!(stats.completed_ok, 1);
        server.stop();
    }

    #[test]
    fn duplicate_completed_job_answers_from_cache() {
        let server = Server::start(quick_cfg(0), Arc::new(MemorySnapshotStore::new()));
        assert!(matches!(
            server.submit(spec(5, "forkjoin", 0)),
            SubmitResponse::Accepted { .. }
        ));
        assert!(server.run_one());
        let first = server.outcome(5).expect("completed");
        match server.submit(spec(5, "forkjoin", 0)) {
            SubmitResponse::AlreadyDone { outcome } => assert_eq!(outcome, first),
            other => panic!("expected cached outcome, got {other:?}"),
        }
        assert_eq!(server.stats().executions, 1, "no re-execution");
    }

    #[test]
    fn duplicate_in_flight_is_rejected_not_requeued() {
        let server = Server::start(quick_cfg(0), Arc::new(MemorySnapshotStore::new()));
        assert!(matches!(server.submit(spec(9, "stream", 0)), SubmitResponse::Accepted { .. }));
        match server.submit(spec(9, "stream", 0)) {
            SubmitResponse::Rejected { reason, .. } => {
                assert_eq!(reason, RejectReason::DuplicateInFlight)
            }
            other => panic!("expected duplicate rejection, got {other:?}"),
        }
        assert_eq!(server.pending(), 1);
    }

    #[test]
    fn queue_full_rejects_with_backpressure() {
        let cfg = ServeConfig { queue_capacity: 2, ..quick_cfg(0) };
        let server = Server::start(cfg, Arc::new(MemorySnapshotStore::new()));
        assert!(matches!(server.submit(spec(1, "stencil1d", 0)), SubmitResponse::Accepted { .. }));
        assert!(matches!(server.submit(spec(2, "stencil1d", 0)), SubmitResponse::Accepted { .. }));
        match server.submit(spec(3, "stencil1d", 0)) {
            SubmitResponse::Rejected { reason, retry_after_ms } => {
                assert_eq!(reason, RejectReason::QueueFull);
                assert_eq!(retry_after_ms, 5);
            }
            other => panic!("expected queue-full rejection, got {other:?}"),
        }
        // The rejected job was never journaled: nothing to recover.
        assert!(!server.inner.journal.contains(&journal_key(3)));
    }

    #[test]
    fn malformed_submissions_cost_no_capacity() {
        let cfg = ServeConfig { queue_capacity: 1, ..quick_cfg(0) };
        let server = Server::start(cfg, Arc::new(MemorySnapshotStore::new()));
        match server.submit(spec(1, "no-such-workload", 0)) {
            SubmitResponse::Rejected { reason, .. } => {
                assert_eq!(reason, RejectReason::UnknownWorkload)
            }
            other => panic!("{other:?}"),
        }
        let mut bad = spec(2, "stencil1d", 0);
        bad.policy = "replay:zero".into();
        match server.submit(bad) {
            SubmitResponse::Rejected { reason, .. } => assert_eq!(reason, RejectReason::BadPolicy),
            other => panic!("{other:?}"),
        }
        // The single slot is still free.
        assert!(matches!(server.submit(spec(3, "stencil1d", 0)), SubmitResponse::Accepted { .. }));
    }

    #[test]
    fn failing_class_trips_the_breaker_and_healthy_class_still_runs() {
        let cfg = ServeConfig {
            breaker: BreakerConfig {
                failure_threshold: 2,
                cooldown_ticks: 60_000, // stays open for the whole test
                jitter_ticks: 0,
                ..BreakerConfig::default()
            },
            ..quick_cfg(0)
        };
        let server = Server::start(cfg, Arc::new(MemorySnapshotStore::new()));
        // error_prob 99%: with no resilience policy the run fails.
        for id in 1..=2 {
            assert!(matches!(
                server.submit(spec(id, "stencil1d", 99)),
                SubmitResponse::Accepted { .. }
            ));
            assert!(server.run_one());
        }
        assert_eq!(server.stats().failed, 2);
        match server.submit(spec(3, "stencil1d", 0)) {
            SubmitResponse::Rejected { reason, retry_after_ms } => {
                assert_eq!(reason, RejectReason::BreakerOpen);
                assert!(retry_after_ms > 0, "retry hint carries the cooldown");
            }
            other => panic!("expected breaker rejection, got {other:?}"),
        }
        // Another class is unaffected, and a replay policy makes the
        // same faulty class survivable.
        assert!(matches!(server.submit(spec(4, "forkjoin", 0)), SubmitResponse::Accepted { .. }));
        assert_eq!(server.stats().rejected_breaker, 1);
    }

    #[test]
    fn restart_recovers_pending_and_done_jobs() {
        let journal: Arc<MemorySnapshotStore> = Arc::new(MemorySnapshotStore::new());
        let first = Server::start(quick_cfg(0), Arc::clone(&journal) as Arc<dyn SnapshotStore>);
        for id in 1..=3 {
            assert!(matches!(
                first.submit(spec(id, "forkjoin", 0)),
                SubmitResponse::Accepted { .. }
            ));
        }
        assert!(first.run_one()); // job 1 completes, 2 and 3 stay pending
        first.stop();
        drop(first);

        let second = Server::start(quick_cfg(0), journal as Arc<dyn SnapshotStore>);
        let stats = second.stats();
        assert_eq!(stats.recovered_done, 1);
        assert_eq!(stats.recovered_pending, 2);
        assert_eq!(second.pending(), 2);
        assert!(second.outcome(1).is_some(), "done job answers from cache");
        while second.run_one() {}
        assert_eq!(second.stats().executions, 2, "each pending job runs exactly once");
        for id in 1..=3 {
            assert!(second.outcome(id).expect("completed").ok);
        }
    }

    #[test]
    fn status_and_frame_adapter_roundtrip() {
        let server = Server::start(quick_cfg(0), Arc::new(MemorySnapshotStore::new()));
        let (reply, f) = server.handle_frame(&Frame::Submit(spec(1, "stencil1d", 0)));
        assert_eq!(reply, Frame::Ack { job_id: 1 });
        assert!(f.is_some());
        let (reply, f) = server.handle_frame(&Frame::Status(StatusReport::default()));
        assert!(f.is_none());
        let Frame::Status(s) = reply else { panic!("expected status") };
        assert_eq!(s.submitted, 1);
        assert_eq!(s.accepted, 1);
        assert_eq!(s.queue_depth, 1);
        // A server-to-client frame sent by a client is answered, typed.
        let (reply, _) = server.handle_frame(&Frame::Ack { job_id: 7 });
        assert!(matches!(reply, Frame::Reject { job_id: 7, .. }));
    }
}
