//! `rhpx serve` — a long-running resilient task service over the
//! workload zoo.
//!
//! The paper's replay/replicate/validate APIs protect a single task
//! launch; this module composes them with the *service-level* resilience
//! patterns (ORNL resilience-design-patterns catalogue: detection,
//! containment, recovery) that a daemon under sustained multi-client
//! load needs:
//!
//! * [`protocol`] — dependency-free length-prefixed framed protocol
//!   (versioned header, FNV-checksummed payload) carrying
//!   Submit/Ack/Result/Status/Reject frames over `std::net` TCP or any
//!   in-memory transport; submissions name a zoo workload plus a
//!   per-client `PolicySpec`, exposing the whole `--resilience` matrix
//!   as a service.
//! * [`admission`] — queue-depth admission control with backpressure:
//!   bounded buffering, explicit `Reject{retry_after}` beyond the bound.
//! * [`breaker`] — per-task-class Closed→Open→HalfOpen circuit breaker
//!   with exponential backoff and deterministic seeded jitter.
//! * [`server`] — the daemon: accepted jobs journal through a
//!   [`crate::checkpoint::SnapshotStore`] before they are acked, so a
//!   killed-and-restarted daemon completes every accepted job exactly
//!   once and never silently drops acked work.
//!
//! Quick start (the in-memory transport; `rhpx serve` wires the same
//! server to a `TcpListener`):
//!
//! ```
//! use std::sync::Arc;
//! use rhpx::checkpoint::MemorySnapshotStore;
//! use rhpx::serve::{JobSpec, ServeConfig, Server, SubmitResponse};
//!
//! let cfg = ServeConfig { executors: 1, workers: 2, ..ServeConfig::default() };
//! let server = Server::start(cfg, Arc::new(MemorySnapshotStore::new()));
//! let spec = JobSpec {
//!     job_id: 1,
//!     workload: "stencil1d".into(),
//!     policy: "replay:5".into(),
//!     scale_milli: 100,
//!     error_prob_pct: 10,
//! };
//! let SubmitResponse::Accepted { future } = server.submit(spec) else {
//!     panic!("accepted");
//! };
//! let outcome = future.get().unwrap();
//! assert!(outcome.ok, "replay:5 absorbs the injected faults");
//! server.stop();
//! ```

pub mod admission;
pub mod breaker;
pub mod protocol;
pub mod server;

pub use admission::{AdmissionGate, Decision};
pub use breaker::{Admission, BreakerConfig, CircuitBreaker};
pub use protocol::{Frame, FrameError, JobRecord, JobSpec, JobState, StatusReport, TaskDesc};
pub use server::{JobOutcome, RejectReason, ServeConfig, Server, ServerStats, SubmitResponse};
