//! The `rhpx` command-line launcher.
//!
//! Hand-rolled argument parsing (no clap in the offline build). See
//! `rhpx help` for the surface:
//!
//! ```text
//! rhpx info
//! rhpx run <WORKLOAD> [--resilience SPEC] [--cluster SPEC] [--json [PATH]]
//!          | rhpx run --list
//! rhpx bench <table1|table1_exec|fig2|table2|fig3|table_dist|table_ckpt|
//!             table_zoo|table_serve|all>
//!            [--scale F] [--repeats N] [--workers N] [--csv PATH]
//!            [--backend native|pjrt]
//! rhpx serve [--addr HOST:PORT] [--queue N] [--executors N] [--workers N]
//!            [--journal DIR] [--for-secs N]
//! rhpx worker --connect HOST:PORT --id N [--heartbeat-ms N] [--crash-after N]
//!             [--trace-spool DIR]
//! rhpx trace convert --spool DIR [--out PATH]
//! rhpx stencil [--case a|b|tiny] [--mode MODE] [--backend native|pjrt]
//!              [--resilience replay:N|replicate:N|adaptive[:CEIL]|
//!                            adaptive_replicate[:CEIL]]
//!              [--cluster LOCALITIES[:kill=STEP@LOC,...]] [--json PATH]
//!              [--scale F] [--error-prob PCT] [--silent-prob PCT] [--workers N]
//! rhpx workload [--tasks N] [--grain-us N] [--variant V] [--error-prob PCT]
//! rhpx distributed [--localities N] [--kill IDX] [--tasks N]
//! ```
//!
//! Paper mapping: `bench` regenerates Table I / Table II / Fig 2 / Fig 3
//! (`table1_exec` is this repo's executor-path comparison, `table_dist`
//! the distributed survival experiment, `table_zoo` the cross-workload
//! overhead-vs-survival matrix); `run` executes any registered
//! [`Workload`](crate::workloads::Workload) through the unified fault
//! model — with `--cluster` it runs distributed over simulated
//! localities with a deterministic kill schedule (the Fig 4–5 scenario;
//! see `docs/FAULT_MODEL.md`); `stencil` is the legacy §V-B entry point
//! (kept for its `--case a|b` paper geometries and `--mode` per-call
//! variants), `workload` the §V-A benchmark.
//!
//! The resilience spec grammar is owned by
//! [`PolicySpec::parse`](crate::resilience::executor::PolicySpec::parse)
//! — this module no longer hand-parses it.

use std::collections::HashMap;

use crate::config::RuntimeConfig;
use crate::distributed::proc::{self, ProcSpec, WorkerConfig};
use crate::harness::{
    emit, fig2, fig3, table1, table2, table_ckpt, table_dist, table_obs, table_proc,
    table_serve, table_zoo, HarnessOpts, KernelBackend, BENCH_MODES,
};
use crate::metrics::{BenchCli, JsonValue, Table};
use crate::runtime_handle::Runtime;
use crate::stencil::{self, Backend, ClusterSpec, ExecPolicy, Mode, StencilParams};
use crate::workload::{self, Variant, WorkloadParams};
use crate::workloads::{self, RunParams, RunReport};

/// Parsed flags: `--key value` pairs plus positional args.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

/// Flags that may appear bare, with the value implied when the next
/// token is absent or itself a flag: `--json` alone means "stdout"
/// (recorded as the conventional path `-`), `--no-validate` is a
/// boolean switch. Everything else keeps the strict `--key value`
/// contract so a forgotten value still errors loudly.
const VALUELESS_FLAGS: &[(&str, &str)] = &[("json", "-"), ("no-validate", "true")];

/// Parse `--key value` style flags (also accepts `--key=value`).
pub fn parse_args(argv: &[String]) -> Result<Args, String> {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(key) = a.strip_prefix("--") {
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if let Some((_, implied)) = VALUELESS_FLAGS
                .iter()
                .find(|(k, _)| *k == key)
                .filter(|_| argv.get(i + 1).map_or(true, |n| n.starts_with("--")))
            {
                flags.insert(key.to_string(), implied.to_string());
            } else {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| format!("flag --{key} expects a value"))?;
                flags.insert(key.to_string(), v.clone());
                i += 1;
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    Ok(Args { positional, flags })
}

impl Args {
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad number {v:?}")),
        }
    }
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v:?}")),
        }
    }
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("run `rhpx help` for usage");
            1
        }
    }
}

fn dispatch(argv: &[String]) -> Result<(), String> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    // `bench --list` is a valueless flag: handle it before the
    // `--key value` parser (which would demand a value). Only the
    // *first* bench argument selects the listing — a later literal
    // "list" (say, a --csv value) must not hijack a real run.
    if cmd == "bench" && matches!(argv.get(1).map(String::as_str), Some("--list") | Some("list"))
    {
        return cmd_bench_list();
    }
    // Same contract for the workload registry listing.
    if cmd == "run" && matches!(argv.get(1).map(String::as_str), Some("--list") | Some("list")) {
        return cmd_run_list();
    }
    let args = parse_args(&argv[1.min(argv.len())..])?;
    match cmd {
        "help" | "-h" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        "info" => cmd_info(),
        "run" => cmd_run(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "trace" => cmd_trace(&args),
        "stencil" => cmd_stencil(&args),
        "workload" => cmd_workload(&args),
        "distributed" => cmd_distributed(&args),
        other => Err(format!("unknown command {other:?}")),
    }
}

const HELP: &str = r#"rhpx — resilient AMT runtime (reproduction of SAND2020-3975)

USAGE:
  rhpx info
  rhpx run <WORKLOAD> | rhpx run --list
       [--resilience replay:N|replicate:N|team:N|drain|adaptive[:CEIL]|
                     adaptive_replicate[:CEIL]|checkpoint:K[:mem|disk|agas]]
       [--cluster LOCALITIES[:kill=STEP@LOC,...]
                 | proc:N[:kill=STEP@LOC,...][:crash=N@LOC]]
       [--latency-us N] [--loc-workers N] [--scale F] [--workers N]
       [--error-prob PCT] [--sdc-prob PCT] [--no-validate]
       [--seed N] [--json [PATH]] [--trace PATH]
  rhpx bench <MODE|all> | rhpx bench --list
       [--scale F] [--repeats N] [--workers N] [--csv PATH]
       [--backend native|pjrt] [--replicas N]
       (modes: see `rhpx bench --list`)
  rhpx serve [--addr HOST:PORT] [--queue N] [--executors N] [--workers N]
       [--journal DIR] [--for-secs N]
  rhpx trace convert --spool DIR [--out PATH]
  rhpx stencil [--case a|b|tiny] [--mode pure|replay|replay_checksum|
               replicate|replicate_checksum|replicate_vote|replicate_replay]
               [--resilience replay:N|replicate:N|team:N|drain|
                             adaptive[:CEIL]|adaptive_replicate[:CEIL]|
                             checkpoint:K[:mem|disk|agas]]
               [--cluster LOCALITIES[:kill=STEP@LOC,...]]
               [--latency-us N] [--loc-workers N]
               [--backend native|pjrt] [--scale F] [--n N] [--json PATH]
               [--error-prob PCT] [--silent-prob PCT] [--workers N]
  rhpx workload [--tasks N] [--grain-us N] [--error-prob PCT] [--workers N]
       [--variant plain|replay|replay_validate|replicate|replicate_validate|
                 replicate_vote|replicate_vote_validate] [--n N]
  rhpx distributed [--localities N] [--kill IDX] [--tasks N] [--latency-us N]

`rhpx run` executes any workload from the zoo (`rhpx run --list` prints
the registry: 1D/2D stencils, a recursive fork-join tree, Jacobi with a
per-step global reduction, a streaming pipeline) through one fault
model: `--error-prob` injects transient task failures, `--sdc-prob`
injects silent bit-flip corruption (caught only while checksum
validation is on; `--no-validate` is the control arm that lets it
leak), `--cluster` adds scheduled locality kills. Every run reports
survival rate, recovery latency, and tasks re-executed uniformly, so
workloads compare directly. `--json` without a path prints the payload
to stdout.

`--cluster proc:N` promotes localities to real OS processes: N `rhpx
worker` children are spawned, task inputs/outputs travel the framed
serve protocol over TCP, `kill=STEP@LOC` is a literal SIGKILL of the
child's PID (`crash=N@LOC` makes worker LOC abort itself on its N-th
launch — deterministic CI), and death is decided by missed heartbeats,
never assumed — the report's detection latency is the real
SIGKILL-to-verdict time. The workload scale is quantized to 1/1000 on
this route (parent and workers must agree on geometry). `rhpx worker`
is the child-process entry point; it is spawned by the parent and not
normally run by hand.

`--trace PATH` turns on the task-lifecycle flight recorder (lock-free
per-worker rings; see docs/ARCHITECTURE.md, "Observability") and writes
the run's merged timeline to PATH as Chrome trace-event JSON — open it
at https://ui.perfetto.dev. On `--cluster proc:N` every worker also
fsyncs its events to a scratch spool, so the export includes a
SIGKILLed worker's final pre-death events next to the parent's
heartbeat-miss and death-verdict instants. `rhpx trace convert` stitches
a surviving spool directory into the same JSON by hand — the post-mortem
path when the parent itself died.

`rhpx serve` runs the resilient task service: a long-lived daemon that
accepts framed job submissions over TCP (any zoo workload plus a
per-client `--resilience`-style policy spec), bounds its queue with
admission control (`--queue`), circuit-breaks failing task classes, and
journals every accepted job so a killed-and-restarted daemon (same
`--journal DIR`) completes all acked work exactly once. `--for-secs N`
serves for N seconds then drains and exits (benchmarks/smoke tests);
without it the daemon runs until killed.

`rhpx stencil` is the legacy single-workload entry point, DEPRECATED in
favor of `rhpx run stencil1d`; it remains for the paper's `--case a|b`
geometries and the per-call `--mode` variants.

`--resilience` routes every stencil task through the executor decorators
(rhpx::resilience::executor) instead of per-call resilient functions;
`adaptive` tunes the *replay budget* online from the observed error
rate, `adaptive_replicate` tunes the eager *replication width* the same
way. `team:N` runs first-result-wins replica teams: the first validated
replica resolves the future and its siblings retire early through a
shared cancellation token instead of running to completion. `drain`
adds no decorator at all — it routes placements over live localities
only and relies on lineage re-materialization (queued tasks on a killed
locality are re-scheduled onto survivors from their lineage records).
`checkpoint:K` is the third strategy (task-level
checkpoint/restart): the wavefront is snapshotted every K windows into a
snapshot store (default: in-memory on the pool, AGAS-replicated across
localities on a cluster; `:disk` models persistent storage), and a
failure restores the affected subdomains from the last snapshot and
replays only the delta tasks. It is mutually exclusive with `--mode`.

`--cluster` runs the stencil distributed: tasks are placed round-robin
across N simulated localities and each `kill=STEP@LOC` event kills
locality LOC just before global task launch STEP (0-based). The
localities' own scheduler pools do the work: `--loc-workers` sizes them
(default: --workers / LOCALITIES rounded down, min 1 — exact parity
with a pool run needs --workers divisible by the locality count).
Without `--resilience` the failure cone reaches the final
wavefront as poisoned subdomains (survival < 1); with it the decorators
recover every subdomain (see docs/FAULT_MODEL.md). Example:

  rhpx stencil --cluster 4:kill=10@2 --resilience replay:3 --json out.json
"#;

fn cmd_info() -> Result<(), String> {
    let cfg = RuntimeConfig::load(None).map_err(|e| e.to_string())?;
    println!("rhpx {}", crate::VERSION);
    println!("available parallelism : {}", cfg.workers);
    println!("artifacts dir         : {}", cfg.artifacts_dir);
    println!(
        "pjrt engine           : {}",
        if crate::runtime::pjrt_available() { "available" } else { "not compiled in" }
    );
    match crate::runtime::ArtifactStore::open(std::path::Path::new(&cfg.artifacts_dir)) {
        Ok(store) if !store.is_empty() => {
            println!("artifacts             : {}", store.names().collect::<Vec<_>>().join(", "))
        }
        _ => println!("artifacts             : (none — run `make artifacts`)"),
    }
    // Exercise the runtime briefly and publish its performance counters.
    let rt = Runtime::builder().workers(cfg.workers).build();
    let f = crate::api::async_(&rt, || 0u8);
    let _ = f.get();
    rt.wait_idle();
    let reg = crate::perfcounters::global();
    crate::perfcounters::publish_scheduler_stats(reg, &rt.stats());
    println!("\nperformance counters:\n{}", reg.render());
    Ok(())
}

fn harness_opts(args: &Args) -> Result<HarnessOpts, String> {
    Ok(HarnessOpts {
        scale: args.get_f64("scale", 0.01)?,
        repeats: args.get_usize("repeats", 3)?,
        csv: args.flags.get("csv").cloned(),
        workers: args.get_usize(
            "workers",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )?,
    })
}

/// Shared diagnostic for `--backend pjrt` without the engine.
fn pjrt_missing_msg() -> String {
    "--backend pjrt: PJRT engine not compiled in (needs a vendored `xla` dependency \
     plus --features pjrt; see rust/Cargo.toml)"
        .to_string()
}

fn backend_from(args: &Args) -> Result<Backend, String> {
    match args.get_str("backend", "native").as_str() {
        "native" => Ok(Backend::Native),
        "pjrt" => {
            // geometry resolved later per case; here we only check the dir
            Ok(Backend::Native) // replaced per-case by callers that need it
        }
        other => Err(format!("unknown backend {other:?}")),
    }
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let what = args.positional.first().map(String::as_str).unwrap_or("all");
    let opts = harness_opts(args)?;
    let replicas = args.get_usize("replicas", 3)?;
    let use_pjrt = args.get_str("backend", "native") == "pjrt";
    let _ = backend_from(args)?;

    let run_table2_fig3 = |which: &str| -> Result<(), String> {
        let backend = if use_pjrt {
            if !crate::runtime::pjrt_available() {
                return Err(pjrt_missing_msg());
            }
            let store = crate::runtime::ArtifactStore::open(std::path::Path::new("artifacts"))
                .map_err(|e| e.to_string())?;
            if store.is_empty() {
                return Err(
                    "--backend pjrt: no artifacts found — run `make artifacts` first".into()
                );
            }
            KernelBackend::Pjrt(store)
        } else {
            KernelBackend::Native
        };
        if which == "table2" {
            emit(&table2::run_table2(&opts, &backend, replicas), &opts);
        } else {
            emit(
                &fig3::run_fig3(&opts, &backend, &fig3::default_probabilities(), 5),
                &opts,
            );
        }
        Ok(())
    };

    match what {
        "table1" => emit(&table1::run_table1(&opts, &table1::default_cores(), replicas), &opts),
        "table1_exec" => emit(
            &table1::run_table1_executor(&opts, &table1::default_cores(), replicas),
            &opts,
        ),
        "fig2" => emit(&fig2::run_fig2(&opts, &fig2::default_probabilities()), &opts),
        "table2" => run_table2_fig3("table2")?,
        "fig3" => run_table2_fig3("fig3")?,
        "table_dist" => {
            emit(&table_dist::to_table(&table_dist::run_table_dist(&opts)), &opts)
        }
        "table_ckpt" => {
            emit(&table_ckpt::to_table(&table_ckpt::run_table_ckpt(&opts)), &opts)
        }
        "table_zoo" => {
            emit(&table_zoo::to_table(&table_zoo::run_table_zoo(&opts)), &opts)
        }
        "table_serve" => {
            emit(&table_serve::to_table(&table_serve::run_table_serve(&opts)), &opts)
        }
        "table_proc" => {
            emit(&table_proc::to_table(&table_proc::run_table_proc(&opts)), &opts)
        }
        "table_obs" => {
            emit(&table_obs::to_table(&table_obs::run_table_obs(&opts)), &opts)
        }
        "all" => {
            emit(&table1::run_table1(&opts, &table1::default_cores(), replicas), &opts);
            emit(
                &table1::run_table1_executor(&opts, &table1::default_cores(), replicas),
                &opts,
            );
            emit(&fig2::run_fig2(&opts, &fig2::default_probabilities()), &opts);
            run_table2_fig3("table2")?;
            run_table2_fig3("fig3")?;
            emit(&table_dist::to_table(&table_dist::run_table_dist(&opts)), &opts);
            emit(&table_ckpt::to_table(&table_ckpt::run_table_ckpt(&opts)), &opts);
            emit(&table_zoo::to_table(&table_zoo::run_table_zoo(&opts)), &opts);
            emit(&table_serve::to_table(&table_serve::run_table_serve(&opts)), &opts);
            emit(&table_proc::to_table(&table_proc::run_table_proc(&opts)), &opts);
            emit(&table_obs::to_table(&table_obs::run_table_obs(&opts)), &opts);
        }
        other => {
            return Err(format!(
                "unknown bench {other:?} (run `rhpx bench --list` for the registry)"
            ))
        }
    }
    Ok(())
}

/// `rhpx bench --list`: print the bench registry — the single source the
/// harness, CLI help, and CI loop share, so they cannot drift.
fn cmd_bench_list() -> Result<(), String> {
    let mut t = Table::new("bench modes (rhpx bench <mode>)", &["mode", "regenerates"]);
    for (name, what) in BENCH_MODES {
        t.add([name.to_string(), what.to_string()]);
    }
    t.add(["all".to_string(), "every mode above, in order".to_string()]);
    print!("{}", t.render());
    Ok(())
}

/// `rhpx run --list`: print the workload registry.
fn cmd_run_list() -> Result<(), String> {
    let mut t = Table::new("workload zoo (rhpx run <workload>)", &["workload", "description"]);
    for (name, what) in workloads::WORKLOADS {
        t.add([name.to_string(), what.to_string()]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `rhpx run <workload>`: any zoo member through the unified fault
/// model (the [`workloads::engine`] entry point).
fn cmd_run(args: &Args) -> Result<(), String> {
    let name = match args.positional.first() {
        Some(n) => n.as_str(),
        None => return cmd_run_list(),
    };
    let scale = args.get_f64("scale", 1.0)?;
    let w = workloads::by_name(name, scale)
        .ok_or_else(|| format!("unknown workload {name:?} (run `rhpx run --list`)"))?;
    let workers = args.get_usize(
        "workers",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;

    let resilience = match args.flags.get("resilience") {
        Some(spec) => Some(parse_resilience(spec)?),
        None => None,
    };
    let mut proc_spec: Option<ProcSpec> = None;
    let cluster = match args.flags.get("cluster") {
        Some(spec) if spec.starts_with("proc:") || spec == "proc" => {
            // The process-backed substrate: real spawned workers, so the
            // simulated-cluster tuning knobs don't apply.
            if args.flags.contains_key("loc-workers") || args.flags.contains_key("latency-us") {
                return Err(
                    "--loc-workers/--latency-us only apply to the simulated cluster".to_string()
                );
            }
            let rest = spec.strip_prefix("proc:").unwrap_or("");
            let mut p = ProcSpec::parse(rest).map_err(|e| format!("--cluster proc: {e}"))?;
            // Milli-quantized scale is the geometry authority shared with
            // the worker processes.
            p.scale_milli = ((scale * 1000.0).round() as u32).max(1);
            proc_spec = Some(p);
            None
        }
        Some(spec) => {
            let mut cluster =
                ClusterSpec::parse(spec).map_err(|e| format!("--cluster: {e}"))?;
            cluster.latency_us = args.get_usize("latency-us", 0)? as u64;
            // Same worker-parity rule as `rhpx stencil`: the localities'
            // own pools do the work, so spread --workers across them.
            cluster.workers_per_locality = args
                .get_usize("loc-workers", (workers / cluster.localities).max(1))?
                .max(1);
            Some(cluster)
        }
        None => {
            if args.flags.contains_key("loc-workers") || args.flags.contains_key("latency-us") {
                return Err("--loc-workers/--latency-us only apply to --cluster runs".to_string());
            }
            None
        }
    };
    // `--trace PATH`: turn on the flight recorder for the whole run and
    // export the merged timeline to PATH afterwards. On the proc route
    // the workers additionally fsync their events to a scratch spool, so
    // a SIGKILLed worker's final moments still reach the export.
    let trace_out = args.flags.get("trace").cloned();
    let mut trace_spool_dir: Option<std::path::PathBuf> = None;
    if trace_out.is_some() {
        crate::trace::enable();
        if let Some(p) = proc_spec.as_mut() {
            let dir = std::env::temp_dir().join(format!("rhpx-trace-{}", std::process::id()));
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("--trace: create spool dir {}: {e}", dir.display()))?;
            p.trace_spool = Some(dir.clone());
            trace_spool_dir = Some(dir);
        }
    }

    let p_err = args.get_f64("error-prob", 0.0)? / 100.0;
    let p_sdc = args.get_f64("sdc-prob", 0.0)? / 100.0;
    let on_cluster = cluster.is_some() || proc_spec.is_some();
    let params = RunParams {
        resilience,
        cluster,
        proc: proc_spec,
        error_rate: if p_err > 0.0 { Some(-p_err.ln()) } else { None },
        sdc_rate: if p_sdc > 0.0 { Some(p_sdc) } else { None },
        validate: !args.flags.contains_key("no-validate"),
        seed: args.get_usize("seed", 0x1CE)? as u64,
    };

    let total_tasks: usize = (0..w.layers()).map(|l| w.layer_tasks(l).len()).sum();
    println!(
        "run {}: {} — {} layers, {} tasks, mode {}{}",
        w.name(),
        w.describe(),
        w.layers(),
        total_tasks,
        params
            .resilience
            .map(|p| p.label())
            .unwrap_or_else(|| "pure_dataflow".to_string()),
        params
            .cluster
            .as_ref()
            .map(|c| {
                format!(
                    ", {} localities ({} scheduled kills)",
                    c.localities,
                    c.schedule.events().len()
                )
            })
            .or_else(|| {
                params.proc.as_ref().map(|p| {
                    format!(
                        ", {} worker processes ({} scheduled SIGKILLs{})",
                        p.localities,
                        p.schedule.events().len(),
                        if p.crash.is_some() { ", 1 self-crash" } else { "" }
                    )
                })
            })
            .unwrap_or_default()
    );

    // Cluster/proc routes idle this runtime (the localities execute).
    let rt = Runtime::builder().workers(if on_cluster { 1 } else { workers }).build();
    let (_, rep) = workloads::run(&rt, w.as_ref(), &params).map_err(|e| e.to_string())?;

    let mut t = Table::new(
        "run result",
        &[
            "workload", "mode", "launcher", "wall_s", "tasks", "injected", "silent",
            "launch_errors", "reexec", "survival_pct", "checksum",
        ],
    );
    t.add([
        rep.workload.clone(),
        rep.mode.clone(),
        rep.launcher.clone(),
        format!("{:.3}", rep.wall_secs),
        rep.tasks.to_string(),
        rep.failures_injected.to_string(),
        rep.silent_corruptions.to_string(),
        rep.launch_errors.to_string(),
        rep.tasks_reexecuted.to_string(),
        format!("{:.1}", 100.0 * rep.survival_rate()),
        format!("{:.6e}", rep.final_checksum),
    ]);
    print!("{}", t.render());

    if rep.snapshots.saved > 0 || rep.snapshots.restored > 0 || rep.snapshots.lost > 0 {
        println!(
            "snapshots: {} saved ({} bytes), {} restored, {} lost",
            rep.snapshots.saved, rep.snapshots.bytes, rep.snapshots.restored, rep.snapshots.lost
        );
    }
    if !rep.localities.is_empty() {
        let mut lt = Table::new(
            "cluster placement",
            &["locality", "executed", "rejected", "lost", "alive_at_end", "killed_at_task"],
        );
        for loc in &rep.localities {
            lt.add([
                loc.id.to_string(),
                loc.tasks_executed.to_string(),
                loc.tasks_rejected.to_string(),
                loc.tasks_lost.to_string(),
                loc.alive_at_end.to_string(),
                loc.killed_at_task.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            ]);
        }
        print!("{}", lt.render());
        if let Some(lat) = rep.recovery_latency_secs {
            println!("mean recovery latency: {lat:.4}s (queue drain, or kill -> next barrier)");
        }
        if let Some(lat) = rep.detection_latency_secs {
            println!("mean detection latency: {lat:.4}s (SIGKILL -> heartbeat verdict)");
        }
    }

    // Worker perfcounters folded from proc localities (satellite of the
    // flight-recorder work): `/locality/<id>/...` gauges set from the
    // Counters frames each worker piggybacks on its heartbeat stream.
    let worker_counters: Vec<(String, u64)> = crate::perfcounters::global()
        .snapshot()
        .into_iter()
        .filter(|(k, _)| k.starts_with("/locality/"))
        .collect();
    if !worker_counters.is_empty() {
        println!("\nworker counters (folded from proc localities):");
        for (k, v) in &worker_counters {
            println!("{k}  {v}");
        }
    }

    if let Some(path) = args.flags.get("json") {
        let payload_name = format!("run_{}", rep.workload);
        let mut results = run_report_json(&rep);
        if let JsonValue::Obj(m) = &mut results {
            m.insert(
                "counters".to_string(),
                JsonValue::obj(
                    worker_counters.iter().map(|(k, v)| (k.clone(), JsonValue::from(*v))),
                ),
            );
        }
        if path == "-" {
            // Bare `--json`: same envelope as the file path, on stdout.
            let payload = JsonValue::obj([
                ("bench".to_string(), JsonValue::from(payload_name)),
                ("smoke".to_string(), JsonValue::from(false)),
                ("schema_version".to_string(), JsonValue::from(1u64)),
                ("results".to_string(), results),
            ]);
            println!("{}", payload.render());
        } else {
            let sink = BenchCli { smoke: false, json: Some(path.clone()) };
            sink.try_emit(&payload_name, results)
                .map_err(|e| format!("failed to write {path}: {e}"))?;
        }
    }

    if let Some(path) = &trace_out {
        let summary = crate::trace::chrome::export(path)
            .map_err(|e| format!("--trace: write {path}: {e}"))?;
        crate::trace::disable();
        if let Some(dir) = &trace_spool_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
        println!(
            "trace: wrote {path} ({} tracks, {} spans, {} instants, {} events dropped)",
            summary.tracks, summary.spans, summary.instants, summary.dropped
        );
    }
    Ok(())
}

/// The `rhpx run` JSON payload: every [`RunReport`] field, one schema
/// for all zoo members.
fn run_report_json(rep: &RunReport) -> JsonValue {
    JsonValue::obj([
        ("workload".to_string(), JsonValue::from(rep.workload.clone())),
        ("mode".to_string(), JsonValue::from(rep.mode.clone())),
        ("launcher".to_string(), JsonValue::from(rep.launcher.clone())),
        ("wall_secs".to_string(), JsonValue::from(rep.wall_secs)),
        ("tasks".to_string(), JsonValue::from(rep.tasks)),
        ("subdomains".to_string(), JsonValue::from(rep.subdomains)),
        ("failures_injected".to_string(), JsonValue::from(rep.failures_injected)),
        ("silent_corruptions".to_string(), JsonValue::from(rep.silent_corruptions)),
        ("launch_errors".to_string(), JsonValue::from(rep.launch_errors)),
        ("tasks_reexecuted".to_string(), JsonValue::from(rep.tasks_reexecuted)),
        (
            "snapshots".to_string(),
            JsonValue::obj([
                ("saved".to_string(), JsonValue::from(rep.snapshots.saved)),
                ("restored".to_string(), JsonValue::from(rep.snapshots.restored)),
                ("bytes".to_string(), JsonValue::from(rep.snapshots.bytes)),
                ("lost".to_string(), JsonValue::from(rep.snapshots.lost)),
            ]),
        ),
        ("survival_rate".to_string(), JsonValue::from(rep.survival_rate())),
        ("kills_applied".to_string(), JsonValue::from(rep.kills_applied)),
        (
            "recovery_latency_secs".to_string(),
            rep.recovery_latency_secs.map(JsonValue::from).unwrap_or(JsonValue::Null),
        ),
        (
            "detection_latency_secs".to_string(),
            rep.detection_latency_secs.map(JsonValue::from).unwrap_or(JsonValue::Null),
        ),
        (
            "localities".to_string(),
            JsonValue::Arr(
                rep.localities
                    .iter()
                    .map(|l| {
                        JsonValue::obj([
                            ("id".to_string(), JsonValue::from(l.id)),
                            ("executed".to_string(), JsonValue::from(l.tasks_executed)),
                            ("rejected".to_string(), JsonValue::from(l.tasks_rejected)),
                            ("lost".to_string(), JsonValue::from(l.tasks_lost)),
                            ("alive_at_end".to_string(), JsonValue::from(l.alive_at_end)),
                            (
                                "killed_at_task".to_string(),
                                l.killed_at_task
                                    .map(JsonValue::from)
                                    .unwrap_or(JsonValue::Null),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("final_checksum".to_string(), JsonValue::from(rep.final_checksum)),
    ])
}

/// `rhpx worker`: one process-backed locality (see
/// [`crate::distributed::proc`]). Spawned by the parent's `ProcCluster`;
/// connects back, heartbeats, and serves task launches until the parent
/// hangs up or the process is killed.
fn cmd_worker(args: &Args) -> Result<(), String> {
    let connect = args
        .flags
        .get("connect")
        .cloned()
        .ok_or_else(|| "worker: --connect HOST:PORT is required".to_string())?;
    let cfg = WorkerConfig {
        connect,
        id: args.get_usize("id", 0)? as u32,
        heartbeat_ms: args
            .get_usize("heartbeat-ms", proc::DEFAULT_HEARTBEAT_MS as usize)? as u64,
        crash_after: match args.flags.get("crash-after") {
            Some(v) => Some(
                v.parse()
                    .map_err(|_| format!("--crash-after: bad integer {v:?}"))?,
            ),
            None => None,
        },
        trace_spool: args.flags.get("trace-spool").map(std::path::PathBuf::from),
    };
    proc::run_worker(&cfg)
}

/// `rhpx trace convert`: stitch a spool directory (the crash-surviving
/// per-worker `locN.spool` files a traced `--cluster proc:N` run leaves
/// behind) into one Chrome trace-event JSON file — the post-mortem
/// forensics path, usable even when the parent itself died.
fn cmd_trace(args: &Args) -> Result<(), String> {
    match args.positional.first().map(String::as_str) {
        Some("convert") => {}
        other => {
            return Err(format!(
                "trace: unknown subcommand {other:?} (expected `rhpx trace convert \
                 --spool DIR --out PATH`)"
            ))
        }
    }
    let spool = args
        .flags
        .get("spool")
        .ok_or_else(|| "trace convert: --spool DIR is required".to_string())?;
    let out = args.get_str("out", "trace.json");
    let chunks = crate::trace::spool::read_spool_dir(std::path::Path::new(spool));
    if chunks.is_empty() {
        return Err(format!("trace convert: no spool chunks under {spool}"));
    }
    let (tracks, dropped) = crate::trace::spool::tracks_from_chunks(chunks);
    let summary = crate::trace::chrome::export_tracks(&out, &tracks, dropped)
        .map_err(|e| format!("trace convert: write {out}: {e}"))?;
    println!(
        "trace convert: {} -> {} ({} tracks, {} spans, {} instants, {} dropped)",
        spool, out, summary.tracks, summary.spans, summary.instants, summary.dropped
    );
    Ok(())
}

/// Parse `--resilience replay:N|replicate:N|team:N|drain|adaptive[:CEIL]|
/// adaptive_replicate[:CEIL]|checkpoint:K[:mem|disk|agas]`.
///
/// The grammar lives in [`ExecPolicy::parse`] (the single spec-string
/// parser, shared with every harness and test); this wrapper only
/// adapts the typed error to the CLI's string channel.
fn parse_resilience(s: &str) -> Result<ExecPolicy, String> {
    ExecPolicy::parse(s).map_err(|e| format!("--resilience: {e}"))
}

fn parse_mode(s: &str, n: usize) -> Result<Mode, String> {
    Ok(match s {
        "pure" => Mode::Pure,
        "replay" => Mode::Replay { n },
        "replay_checksum" => Mode::ReplayChecksum { n },
        "replicate" => Mode::Replicate { n },
        "replicate_checksum" => Mode::ReplicateChecksum { n },
        "replicate_vote" => Mode::ReplicateVote { n },
        "replicate_replay" => Mode::ReplicateReplay { n, replays: 3 },
        other => return Err(format!("unknown mode {other:?}")),
    })
}

fn cmd_stencil(args: &Args) -> Result<(), String> {
    // Compatibility alias: the generic entry point supersedes this one
    // (`rhpx help` documents the deprecation). Kept because only this
    // path offers the paper's --case a|b geometries and --mode variants.
    eprintln!(
        "note: `rhpx stencil` is the legacy entry point; prefer `rhpx run stencil1d` \
         (see `rhpx run --list`)"
    );
    let scale = args.get_f64("scale", 0.001)?;
    let n = args.get_usize("n", 3)?;
    let workers = args.get_usize(
        "workers",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let mut params = match args.get_str("case", "tiny").as_str() {
        "a" => StencilParams::case_a(scale),
        "b" => StencilParams::case_b(scale),
        "tiny" => StencilParams::tiny(),
        other => return Err(format!("unknown case {other:?}")),
    };
    params.mode = parse_mode(&args.get_str("mode", "pure"), n)?;
    if let Some(spec) = args.flags.get("resilience") {
        if args.flags.contains_key("mode") {
            return Err(
                "--mode and --resilience are mutually exclusive: --mode picks a resilient \
                 call per task, --resilience routes every task through an executor \
                 decorator; drop one of them"
                    .to_string(),
            );
        }
        params.resilience = Some(parse_resilience(spec)?);
    }
    if let Some(spec) = args.flags.get("cluster") {
        if args.flags.contains_key("mode") {
            return Err(
                "--mode and --cluster are mutually exclusive: the cluster route launches \
                 every task through the cluster executor (per-call resilient functions \
                 are bound to a single runtime); select the policy with --resilience"
                    .to_string(),
            );
        }
        let mut cluster = ClusterSpec::parse(spec).map_err(|e| format!("--cluster: {e}"))?;
        cluster.latency_us = args.get_usize("latency-us", 0)? as u64;
        // Worker parity: on the cluster route the localities' own pools
        // do the work (the single runtime is idle), so by default spread
        // --workers across them. Floor division: parity with a pool run
        // is exact only when --workers divides evenly (the help text
        // states this; --loc-workers overrides).
        cluster.workers_per_locality = args
            .get_usize("loc-workers", (workers / cluster.localities).max(1))?
            .max(1);
        params.cluster = Some(cluster);
    } else if args.flags.contains_key("loc-workers") || args.flags.contains_key("latency-us") {
        return Err("--loc-workers/--latency-us only apply to --cluster runs".to_string());
    }
    let p_err = args.get_f64("error-prob", 0.0)? / 100.0;
    if p_err > 0.0 {
        params.error_rate = Some(-p_err.ln());
    }
    let p_silent = args.get_f64("silent-prob", 0.0)? / 100.0;
    if p_silent > 0.0 {
        params.silent_rate = Some(p_silent);
    }
    if args.get_str("backend", "native") == "pjrt" {
        if !crate::runtime::pjrt_available() {
            return Err(pjrt_missing_msg());
        }
        let store = crate::runtime::ArtifactStore::open(std::path::Path::new("artifacts"))
            .map_err(|e| e.to_string())?;
        params.backend = Backend::pjrt(&store, params.nx, params.steps).map_err(|e| e.to_string())?;
    }

    // On the cluster route the localities' own pools execute the tasks
    // and this runtime sits idle — keep it minimal instead of spawning
    // available_parallelism worth of unused threads.
    let rt = Runtime::builder()
        .workers(if params.cluster.is_some() { 1 } else { workers })
        .build();
    println!(
        "stencil: {} subdomains x {} points, {} iterations x {} steps, mode {}, {} tasks{}",
        params.n_sub,
        params.nx,
        params.iterations,
        params.steps,
        params
            .resilience
            .map(|p| p.label())
            .unwrap_or_else(|| params.mode.label()),
        params.total_tasks(),
        params
            .cluster
            .as_ref()
            .map(|c| {
                format!(
                    ", {} localities ({} scheduled kills)",
                    c.localities,
                    c.schedule.events().len()
                )
            })
            .unwrap_or_default()
    );
    let (_, rep) = stencil::run(&rt, &params).map_err(|e| e.to_string())?;
    let mut t = Table::new(
        "stencil result",
        &[
            "mode", "launcher", "wall_s", "tasks", "task/s", "injected", "silent",
            "launch_errors", "reexec", "survival_pct", "checksum",
        ],
    );
    t.add([
        rep.mode.clone(),
        rep.launcher.clone(),
        format!("{:.3}", rep.wall_secs),
        rep.tasks.to_string(),
        format!("{:.0}", rep.tasks as f64 / rep.wall_secs),
        rep.failures_injected.to_string(),
        rep.silent_corruptions.to_string(),
        rep.launch_errors.to_string(),
        rep.tasks_reexecuted.to_string(),
        format!("{:.1}", 100.0 * rep.survival_rate()),
        format!("{:.6e}", rep.final_checksum),
    ]);
    print!("{}", t.render());

    // Checkpoint runs: snapshot-store traffic summary.
    if rep.snapshots.saved > 0 || rep.snapshots.restored > 0 || rep.snapshots.lost > 0 {
        println!(
            "snapshots: {} saved ({} bytes), {} restored, {} lost",
            rep.snapshots.saved, rep.snapshots.bytes, rep.snapshots.restored, rep.snapshots.lost
        );
    }

    // Cluster runs: per-locality placement/survival breakdown.
    if !rep.localities.is_empty() {
        let mut lt = Table::new(
            "cluster placement",
            &["locality", "executed", "rejected", "lost", "alive_at_end", "killed_at_task"],
        );
        for loc in &rep.localities {
            lt.add([
                loc.id.to_string(),
                loc.tasks_executed.to_string(),
                loc.tasks_rejected.to_string(),
                loc.tasks_lost.to_string(),
                loc.alive_at_end.to_string(),
                loc.killed_at_task.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            ]);
        }
        print!("{}", lt.render());
        if let Some(lat) = rep.recovery_latency_secs {
            println!("mean recovery latency: {lat:.4}s (queue drain, or kill -> next barrier)");
        }
    }

    // The executor path publishes its policy state as perfcounters; show
    // them (and fold them into the JSON payload) when it was active.
    // The checkpoint route's store counters live under /checkpoint/.
    let resilience_counters: Vec<(String, u64)> = crate::perfcounters::global()
        .snapshot()
        .into_iter()
        .filter(|(k, _)| {
            k.starts_with("/resilience/stencil/") || k.starts_with("/checkpoint/stencil/")
        })
        .collect();
    if params.resilience.is_some() && !resilience_counters.is_empty() {
        println!("\nresilience counters:");
        for (k, v) in &resilience_counters {
            println!("{k}  {v}");
        }
    }

    if let Some(path) = args.flags.get("json") {
        let mut results: Vec<(String, JsonValue)> = vec![
            ("mode".to_string(), JsonValue::from(rep.mode.clone())),
            ("launcher".to_string(), JsonValue::from(rep.launcher.clone())),
            ("wall_secs".to_string(), JsonValue::from(rep.wall_secs)),
            ("tasks".to_string(), JsonValue::from(rep.tasks)),
            ("subdomains".to_string(), JsonValue::from(rep.subdomains)),
            ("failures_injected".to_string(), JsonValue::from(rep.failures_injected)),
            ("silent_corruptions".to_string(), JsonValue::from(rep.silent_corruptions)),
            ("launch_errors".to_string(), JsonValue::from(rep.launch_errors)),
            ("tasks_reexecuted".to_string(), JsonValue::from(rep.tasks_reexecuted)),
            (
                "snapshots".to_string(),
                JsonValue::obj([
                    ("saved".to_string(), JsonValue::from(rep.snapshots.saved)),
                    ("restored".to_string(), JsonValue::from(rep.snapshots.restored)),
                    ("bytes".to_string(), JsonValue::from(rep.snapshots.bytes)),
                    ("lost".to_string(), JsonValue::from(rep.snapshots.lost)),
                ]),
            ),
            ("survival_rate".to_string(), JsonValue::from(rep.survival_rate())),
            ("kills_applied".to_string(), JsonValue::from(rep.kills_applied)),
            (
                "recovery_latency_secs".to_string(),
                rep.recovery_latency_secs.map(JsonValue::from).unwrap_or(JsonValue::Null),
            ),
            (
                "localities".to_string(),
                JsonValue::Arr(
                    rep.localities
                        .iter()
                        .map(|l| {
                            JsonValue::obj([
                                ("id".to_string(), JsonValue::from(l.id)),
                                ("executed".to_string(), JsonValue::from(l.tasks_executed)),
                                ("rejected".to_string(), JsonValue::from(l.tasks_rejected)),
                                ("lost".to_string(), JsonValue::from(l.tasks_lost)),
                                ("alive_at_end".to_string(), JsonValue::from(l.alive_at_end)),
                                (
                                    "killed_at_task".to_string(),
                                    l.killed_at_task
                                        .map(JsonValue::from)
                                        .unwrap_or(JsonValue::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("final_checksum".to_string(), JsonValue::from(rep.final_checksum)),
        ];
        results.push((
            "resilience_counters".to_string(),
            JsonValue::obj(
                resilience_counters
                    .into_iter()
                    .map(|(k, v)| (k, JsonValue::from(v))),
            ),
        ));
        // Reuse the bench binaries' envelope (bench/smoke/schema_version/
        // results) so every JSON artifact shares one schema authority.
        let sink = BenchCli { smoke: false, json: Some(path.clone()) };
        sink.try_emit("stencil", JsonValue::obj(results))
            .map_err(|e| format!("failed to write {path}: {e}"))?;
    }
    Ok(())
}

/// `rhpx serve`: the long-running resilient task service over TCP (see
/// [`crate::serve`]). With `--journal DIR` accepted jobs survive a
/// daemon kill — restart with the same directory and they complete
/// exactly once.
fn cmd_serve(args: &Args) -> Result<(), String> {
    use crate::checkpoint::{DiskSnapshotStore, MemorySnapshotStore, SnapshotStore};
    use crate::serve::{ServeConfig, Server};
    use std::sync::Arc;

    let addr = args.get_str("addr", "127.0.0.1:8377");
    let cfg = ServeConfig {
        queue_capacity: args.get_usize("queue", 64)?,
        executors: args.get_usize("executors", 2)?.max(1),
        workers: args.get_usize(
            "workers",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )?,
        ..ServeConfig::default()
    };
    let journal: Arc<dyn SnapshotStore> = match args.flags.get("journal") {
        Some(dir) => Arc::new(DiskSnapshotStore::new(std::path::PathBuf::from(dir))),
        None => Arc::new(MemorySnapshotStore::new()),
    };
    let for_secs = args.get_usize("for-secs", 0)?;

    let server = Server::start(cfg, journal);
    let recovered = server.stats();
    if recovered.recovered_pending + recovered.recovered_done > 0 {
        println!(
            "journal recovery: {} pending jobs re-queued, {} completed outcomes cached",
            recovered.recovered_pending, recovered.recovered_done
        );
    }
    let (local, accept) = server.listen(&addr).map_err(|e| format!("--addr {addr}: {e}"))?;
    println!(
        "rhpx serve listening on {local} (queue {}, {} executors{})",
        server.status().queue_capacity,
        args.get_usize("executors", 2)?.max(1),
        args.flags
            .get("journal")
            .map(|d| format!(", journal {d}"))
            .unwrap_or_else(|| ", in-memory journal".into()),
    );

    if for_secs > 0 {
        std::thread::sleep(std::time::Duration::from_secs(for_secs as u64));
        // Drain what was accepted, then stop: a bounded-run exit leaves
        // no acked work incomplete (a kill would, and the journal would
        // cover it on restart).
        let _ = server.drain(std::time::Duration::from_secs(60));
        server.stop();
        let _ = accept.join();
        let s = server.stats();
        println!(
            "served {}s: {} submitted, {} accepted, {} ok, {} failed, {} rejected",
            for_secs,
            s.submitted,
            s.accepted,
            s.completed_ok,
            s.failed,
            s.rejected()
        );
    } else {
        // Run until the process is killed.
        let _ = accept.join();
    }
    Ok(())
}

fn parse_variant(s: &str, n: usize) -> Result<Variant, String> {
    Ok(match s {
        "plain" => Variant::Plain,
        "replay" => Variant::Replay { n },
        "replay_validate" => Variant::ReplayValidate { n },
        "replicate" => Variant::Replicate { n },
        "replicate_validate" => Variant::ReplicateValidate { n },
        "replicate_vote" => Variant::ReplicateVote { n },
        "replicate_vote_validate" => Variant::ReplicateVoteValidate { n },
        other => return Err(format!("unknown variant {other:?}")),
    })
}

fn cmd_workload(args: &Args) -> Result<(), String> {
    let workers = args.get_usize(
        "workers",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )?;
    let n = args.get_usize("n", 3)?;
    let variant = parse_variant(&args.get_str("variant", "replay"), n)?;
    let p = args.get_f64("error-prob", 0.0)? / 100.0;
    let params = WorkloadParams {
        tasks: args.get_usize("tasks", 100_000)?,
        grain_ns: args.get_usize("grain-us", 200)? as u64 * 1000,
        error_rate: if p > 0.0 { Some(-p.ln()) } else { None },
        ..Default::default()
    };
    let rt = Runtime::builder().workers(workers).build();
    let rep = workload::run(&rt, variant, &params);
    let mut t = Table::new(
        "artificial workload",
        &["variant", "tasks", "wall_s", "per_task_us", "overhead_us", "injected", "launch_errors"],
    );
    t.add([
        rep.variant.clone(),
        rep.tasks.to_string(),
        format!("{:.3}", rep.wall_secs),
        format!("{:.3}", rep.per_task_us),
        format!("{:.3}", rep.overhead_us),
        rep.failures_injected.to_string(),
        rep.launch_errors.to_string(),
    ]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_distributed(args: &Args) -> Result<(), String> {
    use crate::agas::LocalityId;
    use crate::distributed::{async_replay_distributed, Cluster, DistBody, NetworkConfig};
    use std::sync::Arc;

    let n_loc = args.get_usize("localities", 3)?;
    let tasks = args.get_usize("tasks", 100)?;
    let latency = args.get_usize("latency-us", 10)? as u64;
    let cl = Cluster::new(n_loc, 1, NetworkConfig { latency_us: latency });
    if let Some(kill) = args.flags.get("kill") {
        let idx: usize = kill.parse().map_err(|_| "bad --kill index".to_string())?;
        if idx >= n_loc {
            return Err(format!("--kill {idx} out of range (localities={n_loc})"));
        }
        cl.kill(LocalityId(idx));
        println!("killed locality {idx}");
    }
    let body: DistBody<usize> = Arc::new(|loc| Ok(loc.id().0));
    let timer = crate::metrics::Timer::start();
    let mut per_loc = vec![0usize; n_loc];
    let mut failed = 0usize;
    for _ in 0..tasks {
        match async_replay_distributed(&cl, n_loc.max(2), Arc::clone(&body)).get() {
            Ok(id) => per_loc[id] += 1,
            Err(_) => failed += 1,
        }
    }
    let wall = timer.elapsed_secs();
    let mut t = Table::new(
        &format!("distributed replay over {n_loc} localities ({tasks} tasks, {wall:.3}s)"),
        &["locality", "tasks_executed", "alive"],
    );
    for (i, count) in per_loc.iter().enumerate() {
        t.add([
            i.to_string(),
            count.to_string(),
            cl.locality(LocalityId(i)).is_alive().to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("failed launches: {failed}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::SnapshotBackend;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positional() {
        let a = parse_args(&argv(&["table1", "--scale", "0.5", "--csv=out.csv"])).unwrap();
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_str("csv", ""), "out.csv");
        assert_eq!(a.get_usize("missing", 9).unwrap(), 9);
    }

    #[test]
    fn missing_flag_value_errors() {
        assert!(parse_args(&argv(&["--scale"])).is_err());
    }

    #[test]
    fn valueless_flags_get_their_implied_values() {
        // Bare --json (trailing, or followed by another flag) means
        // stdout; with a path it keeps the strict `--key value` shape.
        let a = parse_args(&argv(&["--json"])).unwrap();
        assert_eq!(a.get_str("json", ""), "-");
        let a = parse_args(&argv(&["--json", "--no-validate"])).unwrap();
        assert_eq!(a.get_str("json", ""), "-");
        assert_eq!(a.get_str("no-validate", ""), "true");
        let a = parse_args(&argv(&["--json", "out.json"])).unwrap();
        assert_eq!(a.get_str("json", ""), "out.json");
        // --no-validate never swallows a following positional: it is in
        // the valueless set only because it is boolean — but a value is
        // still accepted (`--no-validate true`) for symmetry.
        let a = parse_args(&argv(&["--no-validate", "--seed", "7"])).unwrap();
        assert!(a.flags.contains_key("no-validate"));
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
    }

    #[test]
    fn mode_and_variant_parsing() {
        assert_eq!(parse_mode("replay", 4).unwrap(), Mode::Replay { n: 4 });
        assert!(parse_mode("bogus", 1).is_err());
        assert_eq!(
            parse_variant("replicate_vote", 3).unwrap(),
            Variant::ReplicateVote { n: 3 }
        );
        assert!(parse_variant("bogus", 1).is_err());
    }

    #[test]
    fn dispatch_help_and_info() {
        assert!(dispatch(&argv(&["help"])).is_ok());
        assert!(dispatch(&argv(&["info"])).is_ok());
        assert!(dispatch(&argv(&["nope"])).is_err());
    }

    #[test]
    fn workload_command_smoke() {
        let r = dispatch(&argv(&[
            "workload",
            "--tasks",
            "50",
            "--grain-us",
            "1",
            "--variant",
            "replay",
            "--workers",
            "2",
        ]));
        assert!(r.is_ok(), "{r:?}");
    }

    #[test]
    fn stencil_command_smoke() {
        let r = dispatch(&argv(&[
            "stencil",
            "--case",
            "tiny",
            "--mode",
            "replay",
            "--workers",
            "2",
        ]));
        assert!(r.is_ok(), "{r:?}");
    }

    #[test]
    fn stencil_mode_and_resilience_conflict() {
        let r = dispatch(&argv(&[
            "stencil",
            "--case",
            "tiny",
            "--mode",
            "replay",
            "--resilience",
            "replay:3",
            "--workers",
            "2",
        ]));
        assert!(r.is_err(), "conflicting flags must be rejected");
    }

    #[test]
    fn resilience_flag_parsing() {
        assert_eq!(parse_resilience("replay:4").unwrap(), ExecPolicy::Replay { n: 4 });
        assert_eq!(
            parse_resilience("replicate:3").unwrap(),
            ExecPolicy::Replicate { n: 3 }
        );
        assert_eq!(
            parse_resilience("adaptive").unwrap(),
            ExecPolicy::Adaptive { ceiling: 10 }
        );
        assert_eq!(
            parse_resilience("adaptive:6").unwrap(),
            ExecPolicy::Adaptive { ceiling: 6 }
        );
        assert_eq!(
            parse_resilience("adaptive_replicate").unwrap(),
            ExecPolicy::AdaptiveReplicate { ceiling: 4 }
        );
        assert_eq!(
            parse_resilience("adaptive_replicate:6").unwrap(),
            ExecPolicy::AdaptiveReplicate { ceiling: 6 }
        );
        assert_eq!(parse_resilience("team:3").unwrap(), ExecPolicy::Team { n: 3 });
        assert_eq!(parse_resilience("drain").unwrap(), ExecPolicy::Drain);
        assert!(parse_resilience("bogus").is_err());
        assert!(parse_resilience("replay:0").is_err());
        assert!(parse_resilience("replicate:x").is_err());
        assert!(parse_resilience("adaptive_replicate:0").is_err());
        assert!(parse_resilience("team:0").is_err());
        assert!(parse_resilience("drain:2").is_err());
    }

    #[test]
    fn resilience_checkpoint_flag_parsing() {
        assert_eq!(
            parse_resilience("checkpoint:2").unwrap(),
            ExecPolicy::Checkpoint { every: 2, backend: SnapshotBackend::Auto }
        );
        assert_eq!(
            parse_resilience("checkpoint:1:mem").unwrap(),
            ExecPolicy::Checkpoint { every: 1, backend: SnapshotBackend::Memory }
        );
        assert_eq!(
            parse_resilience("checkpoint:4:disk").unwrap(),
            ExecPolicy::Checkpoint { every: 4, backend: SnapshotBackend::Disk }
        );
        assert_eq!(
            parse_resilience("checkpoint:3:agas").unwrap(),
            ExecPolicy::Checkpoint { every: 3, backend: SnapshotBackend::Agas }
        );
        assert!(parse_resilience("checkpoint:0").is_err(), "K must be >= 1");
        assert!(parse_resilience("checkpoint:x").is_err());
        assert!(parse_resilience("checkpoint:2:tape").is_err(), "unknown backend");
        assert!(parse_resilience("checkpoint").is_err(), "K is required");
    }

    #[test]
    fn stencil_cluster_checkpoint_smoke_and_json() {
        let path = std::env::temp_dir()
            .join(format!("rhpx_stencil_ckpt_{}.json", std::process::id()));
        let r = dispatch(&argv(&[
            "stencil",
            "--cluster",
            "4:kill=10@2",
            "--resilience",
            "checkpoint:2",
            "--workers",
            "2",
            "--json",
            path.to_str().unwrap(),
        ]));
        assert!(r.is_ok(), "{r:?}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""mode":"exec_checkpoint(2)""#), "{text}");
        assert!(text.contains(r#""survival_rate":1"#), "{text}");
        assert!(text.contains(r#""tasks_reexecuted""#), "{text}");
        assert!(text.contains(r#""snapshots":{"#), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_list_prints_the_registry() {
        assert!(dispatch(&argv(&["bench", "--list"])).is_ok());
        assert!(dispatch(&argv(&["bench", "list"])).is_ok());
        // Pin the registry exactly (both directions): a mode added to
        // BENCH_MODES or to cmd_bench's dispatch must update this list —
        // and with it the Makefile BENCHES and the CI bench-smoke loop.
        let names: Vec<&str> = BENCH_MODES.iter().map(|(n, _)| *n).collect();
        assert_eq!(
            names,
            [
                "table1", "table1_exec", "fig2", "table2", "fig3", "table_dist", "table_ckpt",
                "table_zoo", "table_serve", "table_proc", "table_obs"
            ],
            "bench registry changed: update cmd_bench, Makefile BENCHES, and ci.yml to match"
        );
        assert!(dispatch(&argv(&["bench", "nonsense"])).is_err());
        // A literal "list" later in the argv must NOT hijack a real run
        // (it is an ordinary flag value there); this still errors on the
        // unknown mode rather than printing the registry.
        assert!(dispatch(&argv(&["bench", "nonsense", "--csv", "list"])).is_err());
    }

    #[test]
    fn stencil_cluster_command_smoke() {
        let r = dispatch(&argv(&[
            "stencil",
            "--cluster",
            "4:kill=10@2",
            "--resilience",
            "replay:3",
            "--workers",
            "2",
        ]));
        assert!(r.is_ok(), "{r:?}");
    }

    #[test]
    fn stencil_cluster_rejects_mode_and_bad_specs() {
        let r = dispatch(&argv(&[
            "stencil", "--cluster", "4", "--mode", "replay", "--workers", "2",
        ]));
        assert!(r.is_err(), "--mode on the cluster route must be rejected");
        let r = dispatch(&argv(&["stencil", "--cluster", "4:kill=1@9", "--workers", "2"]));
        assert!(r.is_err(), "out-of-range kill locality must be rejected");
        let r = dispatch(&argv(&["stencil", "--cluster", "0", "--workers", "2"]));
        assert!(r.is_err(), "zero localities must be rejected");
        let r = dispatch(&argv(&["stencil", "--loc-workers", "2", "--workers", "2"]));
        assert!(r.is_err(), "--loc-workers without --cluster must be rejected");
    }

    #[test]
    fn stencil_cluster_survival_json_round_trip() {
        let path = std::env::temp_dir()
            .join(format!("rhpx_stencil_cluster_{}.json", std::process::id()));
        let r = dispatch(&argv(&[
            "stencil",
            "--cluster",
            "4:kill=10@2",
            "--resilience",
            "replay:3",
            "--workers",
            "2",
            "--json",
            path.to_str().unwrap(),
        ]));
        assert!(r.is_ok(), "{r:?}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""launcher":"cluster(4)""#), "{text}");
        assert!(text.contains(r#""survival_rate":1"#), "{text}");
        assert!(text.contains(r#""kills_applied":1"#), "{text}");
        assert!(text.contains(r#""killed_at_task":10"#), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stencil_resilience_adaptive_smoke_emits_json() {
        let path = std::env::temp_dir()
            .join(format!("rhpx_stencil_adaptive_{}.json", std::process::id()));
        let r = dispatch(&argv(&[
            "stencil",
            "--case",
            "tiny",
            "--resilience",
            "adaptive",
            "--workers",
            "2",
            "--json",
            path.to_str().unwrap(),
        ]));
        assert!(r.is_ok(), "{r:?}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""mode":"exec_adaptive(max 10)""#), "{text}");
        assert!(text.contains(r#""schema_version":1"#), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn run_lists_the_workload_registry() {
        assert!(dispatch(&argv(&["run", "--list"])).is_ok());
        assert!(dispatch(&argv(&["run", "list"])).is_ok());
        // No positional at all also lists (a bare `rhpx run` is a query,
        // not an error).
        assert!(dispatch(&argv(&["run"])).is_ok());
        assert!(dispatch(&argv(&["run", "bogus"])).is_err());
    }

    #[test]
    fn run_command_smoke_every_workload() {
        for (name, _) in workloads::WORKLOADS {
            let r = dispatch(&argv(&["run", name, "--workers", "2"]));
            assert!(r.is_ok(), "{name}: {r:?}");
        }
    }

    #[test]
    fn run_rejects_cluster_only_flags_off_cluster() {
        let r = dispatch(&argv(&["run", "forkjoin", "--loc-workers", "2", "--workers", "2"]));
        assert!(r.is_err(), "--loc-workers without --cluster must be rejected");
    }

    #[test]
    fn run_rejects_bad_proc_specs_at_parse_time() {
        // These die in ProcSpec::parse / flag validation — no worker
        // processes are ever spawned, so they are safe as unit tests.
        let r = dispatch(&argv(&["run", "forkjoin", "--cluster", "proc:0", "--workers", "2"]));
        assert!(r.is_err(), "zero workers must be rejected");
        let r = dispatch(&argv(&[
            "run", "forkjoin", "--cluster", "proc:3:kill=1@9", "--workers", "2",
        ]));
        assert!(r.is_err(), "out-of-range SIGKILL locality must be rejected");
        let r = dispatch(&argv(&[
            "run", "forkjoin", "--cluster", "proc:3", "--loc-workers", "2", "--workers", "2",
        ]));
        assert!(r.is_err(), "--loc-workers is simulation-only");
        let r = dispatch(&argv(&[
            "run", "forkjoin", "--cluster", "proc:3:crash=0@1", "--workers", "2",
        ]));
        assert!(r.is_err(), "crash launch count is 1-based");
    }

    #[test]
    fn worker_subcommand_requires_connect() {
        let r = dispatch(&argv(&["worker", "--id", "0"]));
        assert!(r.is_err(), "{r:?}");
        let r = dispatch(&argv(&["worker", "--connect", "127.0.0.1:1", "--id", "x"]));
        assert!(r.is_err(), "bad --id must be rejected");
    }

    #[test]
    fn run_cluster_replay_json_round_trip() {
        let path = std::env::temp_dir()
            .join(format!("rhpx_run_jacobi_{}.json", std::process::id()));
        let r = dispatch(&argv(&[
            "run",
            "jacobi",
            "--cluster",
            "4:kill=10@2",
            "--resilience",
            "replay:3",
            "--workers",
            "2",
            "--json",
            path.to_str().unwrap(),
        ]));
        assert!(r.is_ok(), "{r:?}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""bench":"run_jacobi""#), "{text}");
        assert!(text.contains(r#""workload":"jacobi""#), "{text}");
        assert!(text.contains(r#""launcher":"cluster(4)""#), "{text}");
        assert!(text.contains(r#""survival_rate":1"#), "{text}");
        assert!(text.contains(r#""kills_applied":1"#), "{text}");
        assert!(text.contains(r#""final_checksum""#), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn run_bare_json_flag_prints_to_stdout_instead_of_erroring() {
        // The acceptance-spec invocation shape: trailing `--json` with
        // no path. Must run (stdout payload), not die in parse_args.
        let r = dispatch(&argv(&[
            "run",
            "stream",
            "--cluster",
            "4:kill=10@2",
            "--resilience",
            "replay:3",
            "--workers",
            "2",
            "--json",
        ]));
        assert!(r.is_ok(), "{r:?}");
    }

    #[test]
    fn run_checkpoint_policy_smoke() {
        let r = dispatch(&argv(&[
            "run",
            "stencil2d",
            "--resilience",
            "checkpoint:1",
            "--workers",
            "2",
        ]));
        assert!(r.is_ok(), "{r:?}");
    }

    #[test]
    fn serve_rejects_an_unbindable_address() {
        let r = dispatch(&argv(&["serve", "--addr", "not-an-address", "--workers", "2"]));
        assert!(r.is_err(), "bind failure must surface as a CLI error, got {r:?}");
        let r = dispatch(&argv(&["serve", "--addr", "256.0.0.1:1", "--workers", "2"]));
        assert!(r.is_err(), "{r:?}");
    }

    #[test]
    fn serve_bounded_run_smoke() {
        // Ephemeral port, 1-second bounded run: binds, serves, drains,
        // exits cleanly.
        let r = dispatch(&argv(&[
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--for-secs",
            "1",
            "--queue",
            "4",
            "--executors",
            "1",
            "--workers",
            "2",
        ]));
        assert!(r.is_ok(), "{r:?}");
    }

    #[test]
    fn distributed_command_smoke() {
        let r = dispatch(&argv(&[
            "distributed",
            "--localities",
            "2",
            "--tasks",
            "10",
            "--kill",
            "1",
            "--latency-us",
            "0",
        ]));
        assert!(r.is_ok(), "{r:?}");
    }
}
