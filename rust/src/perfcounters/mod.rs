//! Performance counters — HPX-style named runtime instrumentation.
//!
//! HPX exposes `/threads{locality#0}/count/cumulative`-style counters;
//! this is the same idea at the scale of this crate: a process-wide
//! registry of named monotonic counters and gauges, sampled on demand
//! (`rhpx info`), plus interval snapshots for before/after deltas in the
//! benchmark harnesses.
//!
//! Paper mapping: observability substrate (no table/figure of its own).
//! Besides `/scheduler/...`, the adaptive resilience policies publish
//! `/resilience/<name>/count/{attempts,failures}` and
//! `/resilience/<name>/gauge/{budget,error_rate_ppm}` (see
//! [`crate::resilience::executor::AdaptivePolicy`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Kind of instrument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Monotonically increasing count (tasks spawned, failures, …).
    Counter,
    /// Point-in-time value (queue depth, inflight tasks, …).
    Gauge,
}

/// A single named instrument.
pub struct Instrument {
    value: AtomicU64,
    kind: Kind,
}

impl Instrument {
    pub fn increment(&self, by: u64) {
        self.value.fetch_add(by, Ordering::Relaxed);
    }

    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn kind(&self) -> Kind {
        self.kind
    }
}

/// A registry of instruments. Usually accessed through [`global`].
#[derive(Default)]
pub struct Registry {
    instruments: Mutex<BTreeMap<String, Arc<Instrument>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create an instrument. Names follow the HPX convention
    /// `/<component>/<kind>/<what>`, e.g. `/scheduler/count/spawned`.
    pub fn instrument(&self, name: &str, kind: Kind) -> Arc<Instrument> {
        let mut g = self.instruments.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_insert_with(|| {
            Arc::new(Instrument { value: AtomicU64::new(0), kind })
        }))
    }

    /// Shorthand for a counter.
    pub fn counter(&self, name: &str) -> Arc<Instrument> {
        self.instrument(name, Kind::Counter)
    }

    /// Shorthand for a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Instrument> {
        self.instrument(name, Kind::Gauge)
    }

    /// Sample every instrument.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.instruments
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Delta of counters between two snapshots (gauges: the later value).
    pub fn delta(
        &self,
        before: &BTreeMap<String, u64>,
        after: &BTreeMap<String, u64>,
    ) -> BTreeMap<String, u64> {
        let kinds: BTreeMap<String, Kind> = self
            .instruments
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.kind()))
            .collect();
        after
            .iter()
            .map(|(k, &a)| {
                let v = match kinds.get(k) {
                    Some(Kind::Counter) => a.saturating_sub(*before.get(k).unwrap_or(&0)),
                    _ => a,
                };
                (k.clone(), v)
            })
            .collect()
    }

    /// Render a snapshot as an aligned text block.
    pub fn render(&self) -> String {
        let snap = self.snapshot();
        let width = snap.keys().map(|k| k.len()).max().unwrap_or(0);
        let mut out = String::new();
        for (k, v) in snap {
            out.push_str(&format!("{k:<width$}  {v}\n"));
        }
        out
    }
}

/// The process-wide registry.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Publish a [`crate::Runtime`]'s scheduler stats into a registry under
/// `/scheduler/...` (called by `rhpx info` and the harnesses).
pub fn publish_scheduler_stats(reg: &Registry, stats: &crate::scheduler::SchedulerStats) {
    reg.counter("/scheduler/count/spawned").set(stats.spawned);
    reg.counter("/scheduler/count/completed").set(stats.completed);
    reg.counter("/scheduler/count/stolen").set(stats.stolen);
    reg.gauge("/scheduler/gauge/workers").set(stats.workers as u64);
    reg.gauge("/scheduler/gauge/inflight")
        .set(stats.spawned.saturating_sub(stats.completed));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_roundtrip() {
        let reg = Registry::new();
        let c = reg.counter("/x/count/things");
        c.increment(3);
        c.increment(2);
        assert_eq!(c.get(), 5);
        // same name -> same instrument
        assert_eq!(reg.counter("/x/count/things").get(), 5);
        assert_eq!(reg.snapshot()["/x/count/things"], 5);
    }

    #[test]
    fn gauge_sets() {
        let reg = Registry::new();
        let g = reg.gauge("/x/gauge/depth");
        g.set(9);
        g.set(4);
        assert_eq!(g.get(), 4);
        assert_eq!(g.kind(), Kind::Gauge);
    }

    #[test]
    fn delta_subtracts_counters_keeps_gauges() {
        let reg = Registry::new();
        let c = reg.counter("/c");
        let g = reg.gauge("/g");
        c.increment(10);
        g.set(5);
        let before = reg.snapshot();
        c.increment(7);
        g.set(2);
        let after = reg.snapshot();
        let d = reg.delta(&before, &after);
        assert_eq!(d["/c"], 7);
        assert_eq!(d["/g"], 2);
    }

    #[test]
    fn publish_scheduler() {
        let rt = crate::Runtime::builder().workers(2).build();
        let f = crate::async_(&rt, || 1i32);
        let _ = f.get();
        rt.wait_idle();
        let reg = Registry::new();
        publish_scheduler_stats(&reg, &rt.stats());
        let snap = reg.snapshot();
        assert_eq!(snap["/scheduler/count/spawned"], 1);
        assert_eq!(snap["/scheduler/gauge/workers"], 2);
        assert_eq!(snap["/scheduler/gauge/inflight"], 0);
    }

    #[test]
    fn render_is_aligned() {
        let reg = Registry::new();
        reg.counter("/a").increment(1);
        reg.counter("/long/name").increment(2);
        let s = reg.render();
        assert!(s.contains("/a"));
        assert!(s.contains("/long/name"));
        assert_eq!(s.lines().count(), 2);
    }
}
