//! Timers, streaming statistics, and table/CSV rendering for the
//! benchmark harnesses.
//!
//! Paper mapping: measurement substrate for every table/figure —
//! [`busy_wait_ns`] is Listing 3's grain control, [`Table`] renders the
//! paper-shaped rows, and [`bench_json`] carries the CI contract.

pub mod bench_json;
mod stats;
mod table;

pub use bench_json::{BenchCli, JsonValue};
pub use stats::{LatencyHistogram, Stats};
pub use table::Table;

use std::time::{Duration, Instant};

/// Monotonic stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_micros(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e6
    }
}

/// Busy-wait for `delay_ns` nanoseconds — the paper's task-grain control
/// (Listing 3 spins on `high_resolution_clock` until the requested grain
/// has elapsed; sleeping would deschedule the worker and under-report
/// scheduling overheads).
#[inline]
pub fn busy_wait_ns(delay_ns: u64) {
    let start = Instant::now();
    let target = Duration::from_nanos(delay_ns);
    while start.elapsed() < target {
        std::hint::spin_loop();
    }
}

/// Format seconds with 3 decimals (paper tables print e.g. `46.564`).
pub fn fmt_secs(s: f64) -> String {
    format!("{s:.3}")
}

/// Format microseconds with 3 decimals (Table I prints e.g. `0.792`).
pub fn fmt_micros(us: f64) -> String {
    format!("{us:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        busy_wait_ns(1_000_000); // 1 ms
        let e = t.elapsed_secs();
        assert!(e >= 0.001, "elapsed {e}");
        assert!(e < 1.0, "elapsed {e}");
    }

    #[test]
    fn busy_wait_respects_grain() {
        let t = Timer::start();
        busy_wait_ns(200_000); // the paper's 200 µs grain
        let us = t.elapsed_micros();
        assert!(us >= 200.0, "only waited {us} µs");
        assert!(us < 20_000.0, "waited way too long: {us} µs");
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(46.5641), "46.564");
        assert_eq!(fmt_micros(0.7923), "0.792");
    }
}
