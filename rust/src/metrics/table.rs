//! Plain-text table renderer for benchmark output (the harnesses print
//! the same rows the paper's tables report).

/// A simple column-aligned table with an optional title.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add_row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn add<I: IntoIterator<Item = S>, S: ToString>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().map(|c| c.to_string()).collect();
        self.add_row(&row);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..cols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as a JSON value (`{"title", "header", "rows"}`) for the
    /// `--json` bench contract. Cells that parse as numbers are emitted
    /// as JSON numbers so downstream diffing tools can compare them.
    pub fn to_json(&self) -> super::JsonValue {
        use super::JsonValue;
        let cell_value = |s: &str| -> JsonValue {
            match s.parse::<f64>() {
                Ok(x) if x.is_finite() => JsonValue::Num(x),
                _ => JsonValue::Str(s.to_string()),
            }
        };
        let rows: Vec<JsonValue> = self
            .rows
            .iter()
            .map(|row| {
                JsonValue::Obj(
                    self.header
                        .iter()
                        .zip(row.iter())
                        .map(|(h, c)| (h.clone(), cell_value(c)))
                        .collect(),
                )
            })
            .collect();
        JsonValue::obj([
            ("title".to_string(), JsonValue::Str(self.title.clone())),
            (
                "header".to_string(),
                JsonValue::Arr(self.header.iter().map(|h| JsonValue::Str(h.clone())).collect()),
            ),
            ("rows".to_string(), JsonValue::Arr(rows)),
        ])
    }

    /// Render as CSV (header + rows) for the graphing scripts.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.add(["a", "1"]);
        t.add(["longer", "22"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("name"));
        let lines: Vec<&str> = s.lines().collect();
        // header, separator, two rows, plus title line
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new("", &["x", "y"]);
        t.add([1, 2]);
        t.add([3, 4]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n3,4\n");
    }

    #[test]
    fn json_output_parses_numeric_cells() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.add(["a", "1.5"]);
        let j = t.to_json().render();
        assert!(j.contains(r#""title":"demo""#), "{j}");
        assert!(j.contains(r#""value":1.5"#), "{j}");
        assert!(j.contains(r#""name":"a""#), "{j}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.add(["only one"]);
    }
}
