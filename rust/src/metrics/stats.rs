//! Streaming statistics for benchmark reporting.

/// Welford-style streaming accumulator: mean/variance in one pass plus
/// retained samples for exact percentiles (benchmarks keep at most a few
/// thousand samples, so retention is cheap).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.samples.push(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }

    /// Exact percentile (nearest-rank) over retained samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 95% confidence half-width for the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.stddev() / (self.n as f64).sqrt()
        }
    }
}

/// Sub-bucket resolution: each power-of-two magnitude is split into
/// `2^SUB_BITS` linear sub-buckets, bounding the relative quantile error
/// at `2^-SUB_BITS` (≈3.1%).
const SUB_BITS: u32 = 5;
const SUB: usize = 1 << SUB_BITS;
/// Magnitudes 5..=63 each contribute `SUB` buckets on top of the exact
/// 0..32 range, so the whole u64 domain fits in a fixed array.
const HIST_BUCKETS: usize = (64 - SUB_BITS as usize + 1) * SUB;

/// HDR-style log-bucketed latency histogram over `u64` nanoseconds.
///
/// Fixed memory (one `u64` counter per bucket, ~15 KiB), dependency-free,
/// mergeable. Values 0..32 are exact; above that, each power-of-two range
/// is split into 32 linear sub-buckets, so any reported quantile is within
/// `value/32 + 1` of the true nearest-rank sample — tight enough for
/// p50/p99/p999 service reporting without retaining samples (the `Stats`
/// retained-sample path is exact but grows with the run; this one does
/// not, which is what a 10M-task percentile needs).
///
/// Quantiles are reported as the *upper* bound of the containing bucket
/// (clamped to the observed max): conservative for latency budgets — the
/// true sample is never larger than the reported figure.
///
/// ```
/// use rhpx::metrics::LatencyHistogram;
/// let mut h = LatencyHistogram::new();
/// for v in 1..=1000u64 {
///     h.record(v);
/// }
/// let p50 = h.quantile(0.50).unwrap();
/// assert!((500..=517).contains(&p50), "p50 {p50}");
/// assert_eq!(h.quantile(1.0), Some(1000));
/// ```
#[derive(Clone)]
pub struct LatencyHistogram {
    counts: Box<[u64; HIST_BUCKETS]>,
    n: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyHistogram")
            .field("count", &self.n)
            .field("min", &self.min)
            .field("max", &self.max)
            .finish()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            counts: Box::new([0u64; HIST_BUCKETS]),
            n: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index for a value: exact below `SUB`, then
    /// `(magnitude, linear sub-position)` above.
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            v as usize
        } else {
            let k = 63 - v.leading_zeros(); // k >= SUB_BITS
            (((k - SUB_BITS + 1) as usize) << SUB_BITS) | (((v >> (k - SUB_BITS)) as usize) & (SUB - 1))
        }
    }

    /// Inclusive upper bound of bucket `i` (the value a quantile reports).
    fn bucket_high(i: usize) -> u64 {
        if i < SUB {
            i as u64
        } else {
            let k = (i >> SUB_BITS) as u32 + SUB_BITS - 1; // magnitude
            let sub = (i & (SUB - 1)) as u128;
            // u128 keeps the top magnitude's `(64+sub+1) << 58` from
            // overflowing; the final bucket's bound saturates at u64::MAX.
            let high = ((SUB as u128 + sub + 1) << (k - SUB_BITS)) - 1;
            u64::try_from(high).unwrap_or(u64::MAX)
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.n += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a duration in whole nanoseconds (saturating at `u64::MAX`).
    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn min(&self) -> Option<u64> {
        (self.n > 0).then_some(self.min)
    }

    pub fn max(&self) -> Option<u64> {
        (self.n > 0).then_some(self.max)
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Nearest-rank quantile, `q` in `[0, 1]`. Returns the containing
    /// bucket's upper bound clamped to the observed min/max; `None` when
    /// empty.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.n == 0 {
            return None;
        }
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(Self::bucket_high(i).clamp(self.min, self.max));
            }
        }
        Some(self.max) // unreachable in practice: counts sum to n
    }

    /// Bucket-wise merge — associative and commutative, so per-thread
    /// histograms can be combined in any order.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // population sd = 2.0; sample sd = sqrt(32/7)
        assert!((s.stddev() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Stats::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        // nearest-rank median of 1..=100 rounds to 50 or 51
        assert!((s.median() - 50.5).abs() <= 0.5, "median {}", s.median());
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(90.0) - 90.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats() {
        let s = Stats::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.stddev(), 0.0);
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn single_sample() {
        let mut s = Stats::new();
        s.push(3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.ci95(), 0.0);
    }

    // ---- LatencyHistogram -----------------------------------------

    /// Tiny deterministic generator so histogram tests don't depend on
    /// the crate's failure RNG.
    fn lcg(seed: &mut u64) -> u64 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *seed >> 17
    }

    #[test]
    fn histogram_buckets_are_continuous_and_in_bounds() {
        // Every magnitude boundary lands in a bucket whose range
        // contains it, and the index is monotone in the value.
        let mut probe = vec![0u64, u64::MAX];
        for shift in 0..64 {
            let v = 1u64 << shift;
            probe.push(v - 1);
            probe.push(v);
            probe.push(v.saturating_add(1));
        }
        probe.sort_unstable();
        let mut last = 0usize;
        for v in probe {
            let i = LatencyHistogram::index(v);
            assert!(i < HIST_BUCKETS, "v={v} index={i}");
            assert!(LatencyHistogram::bucket_high(i) >= v, "v={v} i={i}");
            assert!(i >= last, "index not monotone at v={v}");
            last = i;
        }
        assert_eq!(LatencyHistogram::index(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_quantile_error_bound() {
        // Reported quantile must be >= the exact nearest-rank sample and
        // within the 2^-5 relative bucket width (+1 for the integer
        // floor) above it.
        let mut h = LatencyHistogram::new();
        let mut exact = Vec::new();
        let mut seed = 0x1CEu64;
        for _ in 0..10_000 {
            let v = lcg(&mut seed) % 10_000_000; // 0..10ms in ns
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999, 1.0] {
            let rank = ((q * exact.len() as f64).ceil() as usize).clamp(1, exact.len());
            let truth = exact[rank - 1];
            let est = h.quantile(q).unwrap();
            assert!(est >= truth, "q={q}: est {est} < exact {truth}");
            let budget = truth + truth / 32 + 1;
            assert!(est <= budget, "q={q}: est {est} > budget {budget} (exact {truth})");
        }
        // q=1.0 lands in the max's bucket and clamps to the exact max.
        assert_eq!(h.quantile(1.0), Some(*exact.last().unwrap()));
        // q=0.0 reports the min's bucket, which may sit above the min by
        // at most one bucket width.
        let min = *exact.first().unwrap();
        let p0 = h.quantile(0.0).unwrap();
        assert!(p0 >= min && p0 <= min + min / 32 + 1, "p0 {p0} min {min}");
    }

    #[test]
    fn histogram_merge_is_associative_and_commutative() {
        let mut seed = 7u64;
        let mut parts = Vec::new();
        for _ in 0..3 {
            let mut h = LatencyHistogram::new();
            for _ in 0..1000 {
                h.record(lcg(&mut seed) % 1_000_000);
            }
            parts.push(h);
        }
        let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(b);
        left.merge(c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(c);
        let mut right = a.clone();
        right.merge(&bc);
        // c ⊕ b ⊕ a
        let mut rev = c.clone();
        rev.merge(b);
        rev.merge(a);
        for h in [&right, &rev] {
            assert_eq!(left.count(), h.count());
            assert_eq!(left.min(), h.min());
            assert_eq!(left.max(), h.max());
            assert_eq!(left.counts[..], h.counts[..]);
            for q in [0.5, 0.99, 0.999] {
                assert_eq!(left.quantile(q), h.quantile(q));
            }
        }
        assert_eq!(left.count(), 3000);
    }

    #[test]
    fn histogram_empty_and_small() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), None);
        assert!(h.mean().is_nan());
        assert_eq!(h.min(), None);
        h.record(0);
        assert_eq!(h.quantile(0.5), Some(0));
        assert_eq!(h.quantile(1.0), Some(0));
        h.record(42);
        assert_eq!(h.min(), Some(0));
        assert_eq!(h.max(), Some(42));
        assert_eq!(h.quantile(1.0), Some(42)); // buckets are width-1 below 64
        assert!((h.mean() - 21.0).abs() < 1e-12);
        let mut d = LatencyHistogram::new();
        d.record_duration(std::time::Duration::from_micros(3));
        assert_eq!(d.quantile(1.0), Some(3000));
    }
}
