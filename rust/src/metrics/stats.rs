//! Streaming statistics for benchmark reporting.

/// Welford-style streaming accumulator: mean/variance in one pass plus
/// retained samples for exact percentiles (benchmarks keep at most a few
/// thousand samples, so retention is cheap).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.samples.push(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }

    /// Exact percentile (nearest-rank) over retained samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 95% confidence half-width for the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.stddev() / (self.n as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // population sd = 2.0; sample sd = sqrt(32/7)
        assert!((s.stddev() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Stats::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        // nearest-rank median of 1..=100 rounds to 50 or 51
        assert!((s.median() - 50.5).abs() <= 0.5, "median {}", s.median());
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(90.0) - 90.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats() {
        let s = Stats::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.stddev(), 0.0);
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn single_sample() {
        let mut s = Stats::new();
        s.push(3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.ci95(), 0.0);
    }
}
