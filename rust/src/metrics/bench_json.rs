//! Machine-readable benchmark output: a dependency-free JSON encoder and
//! the common `--smoke` / `--json <path>` CLI contract every bench binary
//! implements.
//!
//! CI (and any future optimization PR) runs each bench as
//! `cargo run --release --bin <bench> -- --smoke --json BENCH_<bench>.json`
//! and diffs the emitted numbers. `--smoke` shrinks the workload to a
//! seconds-scale run whose *shape* (keys, series) is identical to the
//! full run; `--json` persists the results. Unknown flags are ignored so
//! the binaries also run unchanged under `cargo bench` (which may pass
//! harness flags of its own).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (no external crates in the offline build).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    /// Ordered object (BTreeMap: deterministic output for diffing).
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Convenience constructor for objects from (key, value) pairs.
    pub fn obj<I: IntoIterator<Item = (String, JsonValue)>>(pairs: I) -> JsonValue {
        JsonValue::Obj(pairs.into_iter().collect())
    }

    /// Serialize to a compact JSON string. Non-finite numbers become
    /// `null` (JSON has no NaN/Inf).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(x) => {
                if x.is_finite() {
                    // Integral values print without a fraction for stable diffs.
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            JsonValue::Str(s) => write_escaped(out, s),
            JsonValue::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl JsonValue {
    /// Parse a JSON document (the inverse of [`JsonValue::render`];
    /// dependency-free like the encoder). Accepts exactly the JSON this
    /// crate emits plus standard whitespace; numbers parse through
    /// `f64::from_str`.
    pub fn parse(text: &str) -> Result<JsonValue, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field access (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Minimal recursive-descent JSON parser over the encoder's output
/// grammar (strict JSON; no comments, no trailing commas).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn eat_literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.eat_literal("null", JsonValue::Null),
            Some(b't') => self.eat_literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.eat_literal("false", JsonValue::Bool(false)),
            Some(b'"') => self.string().map(JsonValue::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "non-utf8 number".to_string())?;
        s.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|e| format!("bad number '{s}' at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes verbatim.
                    let start = self.pos;
                    while let Some(b) = self.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "non-utf8 string".to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

impl From<f64> for JsonValue {
    fn from(x: f64) -> Self {
        JsonValue::Num(x)
    }
}

impl From<u64> for JsonValue {
    fn from(x: u64) -> Self {
        JsonValue::Num(x as f64)
    }
}

impl From<usize> for JsonValue {
    fn from(x: usize) -> Self {
        JsonValue::Num(x as f64)
    }
}

impl From<bool> for JsonValue {
    fn from(b: bool) -> Self {
        JsonValue::Bool(b)
    }
}

impl From<&str> for JsonValue {
    fn from(s: &str) -> Self {
        JsonValue::Str(s.to_string())
    }
}

impl From<String> for JsonValue {
    fn from(s: String) -> Self {
        JsonValue::Str(s)
    }
}

/// The common CLI contract of every bench binary.
#[derive(Debug, Clone, Default)]
pub struct BenchCli {
    /// Shrink the workload to a seconds-scale smoke run.
    pub smoke: bool,
    /// Write the results as JSON to this path.
    pub json: Option<String>,
}

impl BenchCli {
    /// Parse `--smoke` and `--json <path>` / `--json=<path>` from the
    /// process arguments, ignoring anything else (cargo's bench runner
    /// may pass flags of its own).
    pub fn parse() -> Self {
        Self::from_args(std::env::args().skip(1))
    }

    /// Parse from an explicit argument iterator (testable core).
    pub fn from_args<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut cli = BenchCli::default();
        let args: Vec<String> = args.into_iter().collect();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--smoke" {
                cli.smoke = true;
            } else if a == "--json" {
                // Only consume a value that isn't itself a flag, so
                // `--json --smoke` (path forgotten) doesn't swallow
                // --smoke and write a file literally named "--smoke".
                match args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        cli.json = Some(v.clone());
                        i += 1;
                    }
                    _ => eprintln!("warning: --json expects a path; ignoring"),
                }
            } else if let Some(path) = a.strip_prefix("--json=") {
                cli.json = Some(path.to_string());
            }
            i += 1;
        }
        cli
    }

    /// The default full-run scale, overridable via `RHPX_BENCH_SCALE`
    /// (shared by every bench binary; `--smoke` still shrinks it).
    pub fn scale_from_env(&self, default: f64) -> f64 {
        let full = std::env::var("RHPX_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(default);
        self.scale(full)
    }

    /// The default repeat count, overridable via `RHPX_BENCH_REPEATS`.
    pub fn repeats_from_env(&self, default: usize) -> usize {
        let full = std::env::var("RHPX_BENCH_REPEATS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(default);
        self.repeats(full)
    }

    /// Scale factor for workload sizing: callers multiply their default
    /// scale by this (smoke runs shrink to ~1/10th and a single repeat).
    pub fn scale(&self, full: f64) -> f64 {
        if self.smoke {
            (full * 0.1).max(1e-4)
        } else {
            full
        }
    }

    /// Repeat count for workload sizing.
    pub fn repeats(&self, full: usize) -> usize {
        if self.smoke {
            1
        } else {
            full
        }
    }

    /// Write `value` (wrapped with standard metadata) to the `--json`
    /// path, if one was given. `name` is the bench name recorded in the
    /// payload. Panics on I/O failure: a bench that silently drops its
    /// results must fail the CI job.
    pub fn emit(&self, name: &str, value: JsonValue) {
        if let Err(e) = self.try_emit(name, value) {
            let path = self.json.as_deref().unwrap_or("<none>");
            panic!("failed to write {path}: {e}");
        }
    }

    /// Fallible variant of [`BenchCli::emit`] for callers that have a
    /// proper error channel (e.g. the `rhpx` CLI): same payload envelope
    /// (`bench`/`smoke`/`schema_version`/`results`), error returned
    /// instead of panicking.
    pub fn try_emit(&self, name: &str, value: JsonValue) -> std::io::Result<()> {
        let Some(path) = &self.json else { return Ok(()) };
        let payload = JsonValue::obj([
            ("bench".to_string(), JsonValue::from(name)),
            ("smoke".to_string(), JsonValue::from(self.smoke)),
            ("schema_version".to_string(), JsonValue::from(1u64)),
            ("results".to_string(), value),
        ]);
        std::fs::write(path, payload.render() + "\n")?;
        println!("(json written to {path})");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars_and_nesting() {
        let v = JsonValue::obj([
            ("a".to_string(), JsonValue::from(1.5)),
            ("b".to_string(), JsonValue::from("x\"y")),
            (
                "c".to_string(),
                JsonValue::Arr(vec![JsonValue::from(true), JsonValue::Null]),
            ),
        ]);
        assert_eq!(v.render(), r#"{"a":1.5,"b":"x\"y","c":[true,null]}"#);
    }

    #[test]
    fn integral_floats_print_without_fraction() {
        assert_eq!(JsonValue::from(3.0).render(), "3");
        assert_eq!(JsonValue::from(3.25).render(), "3.25");
        assert_eq!(JsonValue::Num(f64::NAN).render(), "null");
        assert_eq!(JsonValue::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn escapes_control_characters() {
        assert_eq!(JsonValue::from("a\nb\t\u{1}").render(), "\"a\\nb\\t\\u0001\"");
    }

    #[test]
    fn cli_parses_smoke_and_json() {
        let cli = BenchCli::from_args(
            ["--bench", "--smoke", "--json", "out.json"].map(String::from),
        );
        assert!(cli.smoke);
        assert_eq!(cli.json.as_deref(), Some("out.json"));
        let cli = BenchCli::from_args(["--json=x.json"].map(String::from));
        assert!(!cli.smoke);
        assert_eq!(cli.json.as_deref(), Some("x.json"));
        assert_eq!(cli.repeats(3), 3);
        let smoke = BenchCli { smoke: true, json: None };
        assert_eq!(smoke.repeats(3), 1);
        assert!(smoke.scale(0.01) < 0.01);
    }

    #[test]
    fn json_flag_does_not_swallow_following_flag() {
        let cli = BenchCli::from_args(["--json", "--smoke"].map(String::from));
        assert!(cli.smoke, "--smoke after a valueless --json must still apply");
        assert_eq!(cli.json, None, "a flag is not a valid --json path");
    }

    #[test]
    fn parse_round_trips_encoder_output() {
        let v = JsonValue::obj([
            ("bench".to_string(), JsonValue::from("perf_micro")),
            ("smoke".to_string(), JsonValue::from(true)),
            (
                "results".to_string(),
                JsonValue::Arr(vec![
                    JsonValue::obj([
                        ("name".to_string(), JsonValue::from("async_")),
                        ("ns_per_launch".to_string(), JsonValue::from(123.5)),
                    ]),
                    JsonValue::Null,
                ]),
            ),
        ]);
        let text = v.render();
        assert_eq!(JsonValue::parse(&text).unwrap(), v);
    }

    #[test]
    fn parse_handles_whitespace_escapes_and_numbers() {
        let v = JsonValue::parse(
            " { \"a\\n\\\"b\" : [ -1.5e3 , null , true , false , \"\\u0041\" ] } ",
        )
        .unwrap();
        let arr = v.get("a\n\"b").and_then(JsonValue::as_arr).unwrap();
        assert_eq!(arr[0].as_f64(), Some(-1500.0));
        assert_eq!(arr[1], JsonValue::Null);
        assert_eq!(arr[2].as_bool(), Some(true));
        assert_eq!(arr[4].as_str(), Some("A"));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
            assert!(JsonValue::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn emit_writes_payload() {
        let path =
            std::env::temp_dir().join(format!("rhpx_bench_json_{}.json", std::process::id()));
        let cli = BenchCli { smoke: true, json: Some(path.to_string_lossy().into_owned()) };
        cli.emit("unit", JsonValue::from(42.0));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""bench":"unit""#), "{text}");
        assert!(text.contains(r#""results":42"#), "{text}");
        let _ = std::fs::remove_file(&path);
    }
}
