//! Task-level checkpoint/restart — the third resilience strategy.
//!
//! The paper's §I argues coordinated global C/R is too expensive at
//! extreme scale and answers with task replay/replication. This module
//! implements the middle ground the resilience-design-pattern catalog
//! calls *checkpoint-recovery composed with rollback at task scope*: a
//! failed task restarts from its last validated snapshot instead of
//! re-executing the whole retry chain — completing the strategy triangle
//! (replay / replicate / checkpoint-restart) next to
//! [`super::executor`]'s decorators.
//!
//! Three pieces:
//!
//! * [`CheckpointExecutor`] — a decorator over any
//!   [`TaskLauncher`]: `spawn_checkpointed(key, task)` consults the
//!   snapshot store first (hit → the snapshot is returned without
//!   executing — or even waiting on dependencies, for the dataflow
//!   variants), and a computed result is validated with the existing
//!   predicate machinery *before* it is persisted, so a checkpoint can
//!   never launder a silently corrupted result into a restore point.
//! * [`Snapshots`] — the counter-instrumented store handle shared by
//!   executors and drivers; publishes
//!   `/checkpoint/<name>/count/{saved,restored,bytes,lost}` through
//!   [`crate::perfcounters`].
//! * [`AgasSnapshotStore`] — the distributed backend: every snapshot is
//!   registered as replicated AGAS components
//!   ([`crate::agas::Agas::register_replicated`]) homed on distinct live
//!   localities, so a locality death touches at most one replica; the
//!   survivors are re-homed off the corpse via
//!   [`crate::agas::Agas::migrate`], and only snapshots whose *every*
//!   replica was homed on dead localities are counted lost.
//!
//! The stencil driver composes these into `--resilience checkpoint:K`
//! (snapshot every K wavefront windows, cone-bounded delta replay on
//! locality death) — see [`crate::stencil`] and `docs/ARCHITECTURE.md`
//! ("Choosing a resilience strategy").
//!
//! ```
//! use std::sync::atomic::{AtomicUsize, Ordering};
//! use std::sync::Arc;
//!
//! use rhpx::checkpoint::MemorySnapshotStore;
//! use rhpx::resilience::checkpoint::CheckpointExecutor;
//! use rhpx::resilience::executor::PoolExecutor;
//! use rhpx::Runtime;
//!
//! let rt = Runtime::builder().workers(2).build();
//! let exec = CheckpointExecutor::new(
//!     PoolExecutor::new(&rt),
//!     Arc::new(MemorySnapshotStore::new()),
//!     "doc",
//! );
//! let runs = Arc::new(AtomicUsize::new(0));
//! let r = Arc::clone(&runs);
//! let task = move || {
//!     r.fetch_add(1, Ordering::SeqCst);
//!     vec![42.0f64]
//! };
//! assert_eq!(exec.spawn_checkpointed("t0", task.clone()).get().unwrap(), vec![42.0]);
//! // Second launch under the same key: served from the snapshot store.
//! assert_eq!(exec.spawn_checkpointed("t0", task).get().unwrap(), vec![42.0]);
//! assert_eq!(runs.load(Ordering::SeqCst), 1);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::agas::{Gid, LocalityId};
use crate::api::{run_task_body, IntoTaskResult};
use crate::checkpoint::store::{SnapshotData, SnapshotStore};
use crate::distributed::Cluster;
use crate::error::{TaskError, TaskResult};
use crate::future::{Future, Promise};
use crate::perfcounters::{global, Instrument};

use super::executor::{
    base_spawn_into, with_resolved_deps, ResilientExecutor, TaskFn, TaskLauncher, TaskValidator,
};

// ---------------------------------------------------------------------
// Snapshots: the counter-instrumented store handle
// ---------------------------------------------------------------------

/// Point-in-time snapshot-traffic totals of a [`Snapshots`] handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SnapshotCounts {
    /// Snapshots persisted.
    pub saved: u64,
    /// Snapshots served back (store-first hits and recovery restores).
    pub restored: u64,
    /// Cumulative serialized bytes persisted.
    pub bytes: u64,
    /// Snapshots irrecoverably lost (every replica on a dead locality).
    pub lost: u64,
}

/// A typed, counter-instrumented handle over a [`SnapshotStore`].
///
/// All checkpoint traffic of one subsystem instance flows through one
/// `Snapshots`, which keeps per-run totals (for reports) and mirrors
/// them into the global perfcounter registry under
/// `/checkpoint/<name>/count/{saved,restored,bytes,lost}`.
pub struct Snapshots {
    store: Arc<dyn SnapshotStore>,
    saved: AtomicU64,
    restored: AtomicU64,
    bytes: AtomicU64,
    c_saved: Arc<Instrument>,
    c_restored: Arc<Instrument>,
    c_bytes: Arc<Instrument>,
    c_lost: Arc<Instrument>,
}

impl Snapshots {
    /// Wrap `store`; `name` namespaces the perfcounters.
    pub fn new(store: Arc<dyn SnapshotStore>, name: &str) -> Self {
        let reg = global();
        let base = format!("/checkpoint/{name}");
        Snapshots {
            store,
            saved: AtomicU64::new(0),
            restored: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            c_saved: reg.counter(&format!("{base}/count/saved")),
            c_restored: reg.counter(&format!("{base}/count/restored")),
            c_bytes: reg.counter(&format!("{base}/count/bytes")),
            c_lost: reg.gauge(&format!("{base}/count/lost")),
        }
    }

    /// The wrapped store.
    pub fn store(&self) -> &Arc<dyn SnapshotStore> {
        &self.store
    }

    /// Serialize and persist `value` under `key`.
    pub fn save_value<T: SnapshotData>(&self, key: &str, value: &T) -> TaskResult<()> {
        let bytes = value.to_bytes();
        self.store.save(key, &bytes)?;
        self.saved.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.c_saved.increment(1);
        self.c_bytes.increment(bytes.len() as u64);
        crate::trace::emit(
            crate::trace::EventKind::CheckpointSave,
            crate::trace::key_hash(key),
            bytes.len() as u64,
        );
        Ok(())
    }

    /// Load, decode, and (when a predicate is given) validate a
    /// snapshot. Counts a restore only when a usable value is returned;
    /// an undecodable or invalid snapshot is *dropped* from the store so
    /// it is never consulted again — the caller recomputes.
    pub fn restore_value<T: SnapshotData>(
        &self,
        key: &str,
        validate: Option<&TaskValidator<T>>,
    ) -> Option<T> {
        let bytes = self.store.load(key)?;
        match T::from_bytes(&bytes) {
            Some(v) if validate.map(|check| check(&v)).unwrap_or(true) => {
                self.restored.fetch_add(1, Ordering::Relaxed);
                self.c_restored.increment(1);
                crate::trace::emit(
                    crate::trace::EventKind::CheckpointRestore,
                    crate::trace::key_hash(key),
                    bytes.len() as u64,
                );
                Some(v)
            }
            _ => {
                self.store.remove(key);
                None
            }
        }
    }

    /// Whether a readable snapshot exists under `key`.
    pub fn contains(&self, key: &str) -> bool {
        self.store.contains(key)
    }

    /// Membership hook: propagate a locality death to the backend (the
    /// AGAS store drops/re-homes replicas) and refresh the loss gauge.
    pub fn on_locality_killed(&self, loc: LocalityId) {
        self.store.on_locality_killed(loc);
        self.c_lost.set(self.store.lost());
        crate::trace::emit(
            crate::trace::EventKind::CheckpointRehome,
            loc.0 as u64,
            self.store.lost(),
        );
    }

    /// Current totals (refreshes the loss gauge from the backend).
    pub fn counts(&self) -> SnapshotCounts {
        let lost = self.store.lost();
        self.c_lost.set(lost);
        SnapshotCounts {
            saved: self.saved.load(Ordering::Relaxed),
            restored: self.restored.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            lost,
        }
    }
}

// ---------------------------------------------------------------------
// CheckpointExecutor<E>
// ---------------------------------------------------------------------

/// Decorator: keyed launches are memoized through a snapshot store —
/// §I's checkpoint/restart re-grained to the task level, as a launch
/// policy over any [`TaskLauncher`].
///
/// `spawn_checkpointed(key, task)` consults the store first: a hit
/// returns the snapshot without executing (the dataflow variants do not
/// even wait for their dependencies), a miss executes on the wrapped
/// launcher, validates the result with the usual predicate machinery,
/// and persists it *only* if validation accepted it. Un-keyed launches
/// (the plain [`ResilientExecutor`] surface) pass through undecorated —
/// without an identity there is nothing to restore by.
#[derive(Clone)]
pub struct CheckpointExecutor<E: TaskLauncher> {
    base: E,
    snaps: Arc<Snapshots>,
}

impl<E: TaskLauncher> CheckpointExecutor<E> {
    /// Checkpoint through `store`; `name` namespaces the perfcounters.
    pub fn new(base: E, store: Arc<dyn SnapshotStore>, name: &str) -> Self {
        CheckpointExecutor { base, snaps: Arc::new(Snapshots::new(store, name)) }
    }

    /// Share an existing [`Snapshots`] handle (drivers that also read
    /// the store directly during recovery use this).
    pub fn with_snapshots(base: E, snaps: Arc<Snapshots>) -> Self {
        CheckpointExecutor { base, snaps }
    }

    /// The snapshot handle (stats, direct restores).
    pub fn snapshots(&self) -> &Arc<Snapshots> {
        &self.snaps
    }

    /// The wrapped launcher.
    pub fn base(&self) -> &E {
        &self.base
    }

    /// Keyed launch: snapshot hit → returned without executing; miss →
    /// execute on the base launcher and persist the result.
    pub fn spawn_checkpointed<T, R, F>(&self, key: &str, f: F) -> Future<T>
    where
        T: SnapshotData + Clone + Send + 'static,
        R: IntoTaskResult<T>,
        F: Fn() -> R + Send + Sync + 'static,
    {
        let (p, fut) = Promise::new();
        self.checkpointed_into(key, p, Arc::new(move || run_task_body(&f)), None);
        fut
    }

    /// As [`CheckpointExecutor::spawn_checkpointed`], with a validation
    /// predicate: a rejected result fails the launch *and is never
    /// persisted*; a stored snapshot that no longer validates is dropped
    /// and recomputed.
    pub fn spawn_checkpointed_validate<T, R, F, V>(&self, key: &str, val_f: V, f: F) -> Future<T>
    where
        T: SnapshotData + Clone + Send + 'static,
        R: IntoTaskResult<T>,
        F: Fn() -> R + Send + Sync + 'static,
        V: Fn(&T) -> bool + Send + Sync + 'static,
    {
        let (p, fut) = Promise::new();
        self.checkpointed_into(key, p, Arc::new(move || run_task_body(&f)), Some(Arc::new(val_f)));
        fut
    }

    /// Keyed dataflow: a snapshot hit resolves immediately without
    /// waiting on `deps` (a restart pass flows straight past completed
    /// tasks); a miss resolves the dependencies, executes, validates,
    /// and persists.
    pub fn dataflow_checkpointed<T, U, R, F>(
        &self,
        key: &str,
        f: F,
        deps: Vec<Future<T>>,
    ) -> Future<U>
    where
        T: Clone + Send + Sync + 'static,
        U: SnapshotData + Clone + Send + 'static,
        R: IntoTaskResult<U>,
        F: Fn(&[T]) -> R + Send + Sync + 'static,
    {
        self.dataflow_ck(key, None, f, deps)
    }

    /// As [`CheckpointExecutor::dataflow_checkpointed`], with a
    /// validation predicate applied to both restored snapshots and fresh
    /// results.
    pub fn dataflow_checkpointed_validate<T, U, R, F, V>(
        &self,
        key: &str,
        val_f: V,
        f: F,
        deps: Vec<Future<T>>,
    ) -> Future<U>
    where
        T: Clone + Send + Sync + 'static,
        U: SnapshotData + Clone + Send + 'static,
        R: IntoTaskResult<U>,
        F: Fn(&[T]) -> R + Send + Sync + 'static,
        V: Fn(&U) -> bool + Send + Sync + 'static,
    {
        self.dataflow_ck(key, Some(Arc::new(val_f)), f, deps)
    }

    fn dataflow_ck<T, U, R, F>(
        &self,
        key: &str,
        validate: Option<TaskValidator<U>>,
        f: F,
        deps: Vec<Future<T>>,
    ) -> Future<U>
    where
        T: Clone + Send + Sync + 'static,
        U: SnapshotData + Clone + Send + 'static,
        R: IntoTaskResult<U>,
        F: Fn(&[T]) -> R + Send + Sync + 'static,
    {
        if let Some(v) = self.snaps.restore_value(key, validate.as_ref()) {
            return Future::ready(Ok(v));
        }
        let ex = self.clone();
        let key = key.to_string();
        with_resolved_deps(f, deps, move |p, body| ex.checkpointed_into(&key, p, body, validate))
    }

    fn checkpointed_into<T>(
        &self,
        key: &str,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
    ) where
        T: SnapshotData + Clone + Send + 'static,
    {
        if let Some(v) = self.snaps.restore_value(key, validate.as_ref()) {
            promise.set_value(v);
            return;
        }
        let snaps = Arc::clone(&self.snaps);
        let key = key.to_string();
        self.base.submit(body).on_ready(move |r| match r {
            Ok(v) => match &validate {
                Some(check) if !check(v) => promise.set_error(TaskError::ValidationRejected),
                _ => {
                    // Persist only validated results. A save failure
                    // costs durability, not correctness: the task still
                    // succeeds, and a later restart simply recomputes.
                    let _ = snaps.save_value(&key, v);
                    promise.set_value(v.clone());
                }
            },
            Err(e) => promise.set_error(e.clone()),
        });
    }
}

impl<E: TaskLauncher> ResilientExecutor for CheckpointExecutor<E> {
    fn spawn_into<T>(
        &self,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
    ) where
        T: Clone + Send + 'static,
    {
        // Un-keyed launches have no identity to restore by: single
        // attempt straight through the base launcher.
        base_spawn_into(&self.base, promise, body, validate);
    }

    fn concurrency(&self) -> usize {
        self.base.parallelism()
    }

    fn label(&self) -> String {
        format!("checkpoint({}) over {}", self.snaps.store().label(), self.base.base_label())
    }
}

// ---------------------------------------------------------------------
// AgasSnapshotStore: replicated, locality-death-aware persistence
// ---------------------------------------------------------------------

/// The distributed snapshot backend: each snapshot's bytes are
/// registered as `replicas` AGAS components homed on *distinct live*
/// localities, so one locality death can touch at most one replica.
///
/// On a kill ([`SnapshotStore::on_locality_killed`], wired to the
/// driver's `FaultSchedule`), replicas homed on the corpse that still
/// have a live sibling are re-homed onto a live locality via
/// [`crate::agas::Agas::migrate`] — modeling re-replication from the
/// surviving copy. A snapshot whose *every* replica was homed on dead
/// localities is gone: it is dropped and counted in
/// [`SnapshotStore::lost`] (reads discover the same loss lazily when no
/// detector ran). Lost snapshots are exactly what forces the driver to
/// replay deeper — "restart only the tasks whose snapshots were lost".
pub struct AgasSnapshotStore {
    cluster: Cluster,
    replicas: usize,
    cursor: AtomicUsize,
    index: Mutex<HashMap<String, Vec<Gid>>>,
    lost: AtomicU64,
}

impl AgasSnapshotStore {
    /// Replicate every snapshot `replicas` times across the cluster's
    /// live localities (clamped to the live count at save time).
    pub fn new(cluster: &Cluster, replicas: usize) -> Self {
        AgasSnapshotStore {
            cluster: cluster.clone(),
            replicas: replicas.max(1),
            cursor: AtomicUsize::new(0),
            index: Mutex::new(HashMap::new()),
            lost: AtomicU64::new(0),
        }
    }

    /// The configured replication factor.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    fn gid_is_live(&self, gid: Gid) -> bool {
        self.cluster
            .agas()
            .locate_with_generation(gid)
            .is_some_and(|(home, _)| self.cluster.locality(home).is_alive())
    }

    /// Up to `replicas` distinct live homes, rotated so successive
    /// snapshots spread across the cluster.
    fn live_homes(&self) -> Vec<LocalityId> {
        let alive = self.cluster.alive_ids();
        if alive.is_empty() {
            return Vec::new();
        }
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % alive.len();
        (0..self.replicas.min(alive.len())).map(|i| alive[(start + i) % alive.len()]).collect()
    }

    /// Declare `key` irrecoverable *if* its registration is still the
    /// one the caller observed: drop it and count the loss once. The
    /// guard closes a save/load race — a reader that resolved a stale
    /// gid list (concurrently replaced by a fresh `save`) must not
    /// destroy the just-persisted replacement.
    fn mark_lost_if(&self, key: &str, observed: &[Gid]) {
        let removed = {
            let mut index = self.index.lock().unwrap();
            if index.get(key).is_some_and(|current| current.as_slice() == observed) {
                index.remove(key)
            } else {
                None
            }
        };
        if let Some(gids) = removed {
            for gid in gids {
                self.cluster.agas().unregister(gid);
            }
            self.lost.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl SnapshotStore for AgasSnapshotStore {
    fn save(&self, key: &str, bytes: &[u8]) -> TaskResult<()> {
        let homes = self.live_homes();
        if homes.is_empty() {
            return Err(TaskError::Runtime(
                "agas snapshot store: no live locality to home a replica".into(),
            ));
        }
        let gids = self.cluster.agas().register_replicated(&homes, bytes.to_vec());
        let old = self.index.lock().unwrap().insert(key.to_string(), gids);
        if let Some(old) = old {
            for gid in old {
                self.cluster.agas().unregister(gid);
            }
        }
        Ok(())
    }

    fn load(&self, key: &str) -> Option<Vec<u8>> {
        let gids = self.index.lock().unwrap().get(key)?.clone();
        for gid in &gids {
            if self.gid_is_live(*gid) {
                if let Some(bytes) = self.cluster.agas().resolve::<Vec<u8>>(*gid) {
                    return Some((*bytes).clone());
                }
            }
        }
        // Lazily discovered loss: every replica is homed on a corpse.
        self.mark_lost_if(key, &gids);
        None
    }

    fn contains(&self, key: &str) -> bool {
        // Pure membership probe: no lazy-loss side effect.
        self.index
            .lock()
            .unwrap()
            .get(key)
            .is_some_and(|gids| gids.iter().any(|gid| self.gid_is_live(*gid)))
    }

    fn remove(&self, key: &str) -> bool {
        match self.index.lock().unwrap().remove(key) {
            Some(gids) => {
                for gid in gids {
                    self.cluster.agas().unregister(gid);
                }
                true
            }
            None => false,
        }
    }

    fn len(&self) -> usize {
        self.index.lock().unwrap().len()
    }

    fn lost(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// `loc` died: re-home its replicas that still have a live sibling
    /// (re-replication from the surviving copy, expressed as an AGAS
    /// migration); drop and count snapshots with no live replica left.
    fn on_locality_killed(&self, loc: LocalityId) {
        let agas = self.cluster.agas().clone();
        let mut dead_keys: Vec<(String, Vec<Gid>)> = Vec::new();
        {
            let index = self.index.lock().unwrap();
            for (key, gids) in index.iter() {
                let any_live = gids.iter().any(|gid| self.gid_is_live(*gid));
                if !any_live {
                    dead_keys.push((key.clone(), gids.clone()));
                    continue;
                }
                // Live homes already holding this key (avoid doubling up).
                let live_homes: Vec<LocalityId> = gids
                    .iter()
                    .filter_map(|gid| agas.locate(*gid))
                    .filter(|home| self.cluster.locality(*home).is_alive())
                    .collect();
                for gid in gids {
                    let Some(home) = agas.locate(*gid) else { continue };
                    if self.cluster.locality(home).is_alive() {
                        continue;
                    }
                    let target = self
                        .cluster
                        .alive_ids()
                        .into_iter()
                        .find(|id| !live_homes.contains(id))
                        .or_else(|| self.cluster.alive_ids().first().copied());
                    if let Some(target) = target {
                        agas.migrate(*gid, target);
                    }
                }
            }
        }
        for (key, observed) in dead_keys {
            self.mark_lost_if(&key, &observed);
        }
        let _ = loc; // kills are discovered through cluster liveness
    }

    fn label(&self) -> String {
        format!("agas(x{})", self.replicas)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::MemorySnapshotStore;
    use crate::distributed::NetworkConfig;
    use crate::resilience::executor::PoolExecutor;
    use crate::runtime_handle::Runtime;
    use std::sync::atomic::AtomicUsize;

    fn exec(name: &str) -> CheckpointExecutor<PoolExecutor> {
        let rt = Runtime::builder().workers(2).build();
        CheckpointExecutor::new(
            PoolExecutor::new(&rt),
            Arc::new(MemorySnapshotStore::new()),
            name,
        )
    }

    #[test]
    fn spawn_checkpointed_memoizes_by_key() {
        let ex = exec("test_memo");
        let runs = Arc::new(AtomicUsize::new(0));
        let task = {
            let r = Arc::clone(&runs);
            move || {
                r.fetch_add(1, Ordering::SeqCst);
                vec![7.0f64]
            }
        };
        assert_eq!(ex.spawn_checkpointed("a", task.clone()).get().unwrap(), vec![7.0]);
        assert_eq!(ex.spawn_checkpointed("a", task.clone()).get().unwrap(), vec![7.0]);
        assert_eq!(runs.load(Ordering::SeqCst), 1, "second launch must hit the snapshot");
        // A different key is a different task identity.
        assert_eq!(ex.spawn_checkpointed("b", task).get().unwrap(), vec![7.0]);
        assert_eq!(runs.load(Ordering::SeqCst), 2);
        let counts = ex.snapshots().counts();
        assert_eq!(counts.saved, 2);
        assert_eq!(counts.restored, 1);
        assert!(counts.bytes >= 16);
    }

    #[test]
    fn rejected_results_are_never_persisted() {
        let ex = exec("test_reject");
        let f = ex.spawn_checkpointed_validate("bad", |v: &Vec<f64>| v[0] > 0.0, || vec![-1.0f64]);
        assert_eq!(f.get(), Err(TaskError::ValidationRejected));
        assert!(!ex.snapshots().contains("bad"), "a rejected result must not be a restore point");
        assert_eq!(ex.snapshots().counts().saved, 0);
    }

    #[test]
    fn invalid_stored_snapshot_is_dropped_and_recomputed() {
        let ex = exec("test_stale");
        // Plant a snapshot that the predicate rejects.
        ex.snapshots().save_value("k", &vec![-5.0f64]).unwrap();
        let runs = Arc::new(AtomicUsize::new(0));
        let r = Arc::clone(&runs);
        let f = ex.spawn_checkpointed_validate("k", |v: &Vec<f64>| v[0] > 0.0, move || {
            r.fetch_add(1, Ordering::SeqCst);
            vec![3.0f64]
        });
        assert_eq!(f.get().unwrap(), vec![3.0]);
        assert_eq!(runs.load(Ordering::SeqCst), 1, "invalid snapshot must be recomputed");
        // The store now holds the recomputed, valid value.
        let validator: TaskValidator<Vec<f64>> = Arc::new(|v: &Vec<f64>| v[0] > 0.0);
        assert_eq!(
            ex.snapshots().restore_value::<Vec<f64>>("k", Some(&validator)),
            Some(vec![3.0])
        );
    }

    #[test]
    fn dataflow_hit_resolves_without_waiting_on_dependencies() {
        let ex = exec("test_dfhit");
        ex.snapshots().save_value("df", &vec![9.0f64]).unwrap();
        // A dependency that never resolves: a hit must not wait for it.
        let (_pending, dep) = Promise::<Vec<f64>>::new();
        let f = ex.dataflow_checkpointed("df", |deps: &[Vec<f64>]| deps[0].clone(), vec![dep]);
        assert_eq!(f.get().unwrap(), vec![9.0]);
        assert_eq!(ex.snapshots().counts().restored, 1);
    }

    #[test]
    fn dataflow_miss_executes_validates_and_persists() {
        let ex = exec("test_dfmiss");
        let rt = Runtime::builder().workers(2).build();
        let dep = crate::api::async_(&rt, || vec![2.0f64]);
        let f = ex.dataflow_checkpointed_validate(
            "df2",
            |v: &Vec<f64>| !v.is_empty(),
            |deps: &[Vec<f64>]| vec![deps[0][0] * 10.0],
            vec![dep],
        );
        assert_eq!(f.get().unwrap(), vec![20.0]);
        assert!(ex.snapshots().contains("df2"));
        assert_eq!(ex.snapshots().counts().saved, 1);
    }

    #[test]
    fn unkeyed_surface_is_single_attempt_passthrough() {
        let ex = exec("test_plain");
        assert_eq!(ex.spawn(|| 5i32).get(), Ok(5));
        let f = ex.spawn_validate(|_: &i32| false, || 1i32);
        assert_eq!(f.get(), Err(TaskError::ValidationRejected));
        assert_eq!(ex.label(), "checkpoint(mem) over pool(2)");
    }

    #[test]
    fn checkpoint_counters_are_published() {
        let ex = exec("test_counters_ck");
        let _ = ex.spawn_checkpointed("c", || vec![1.0f64]).get();
        let _ = ex.spawn_checkpointed("c", || vec![1.0f64]).get();
        let snap = global().snapshot();
        assert!(snap["/checkpoint/test_counters_ck/count/saved"] >= 1);
        assert!(snap["/checkpoint/test_counters_ck/count/restored"] >= 1);
        assert!(snap["/checkpoint/test_counters_ck/count/bytes"] >= 8);
        assert!(snap.contains_key("/checkpoint/test_counters_ck/count/lost"));
    }

    // -- the AGAS-replicated backend ------------------------------------

    fn cluster(n: usize) -> Cluster {
        Cluster::new(n, 1, NetworkConfig::default())
    }

    #[test]
    fn agas_store_roundtrips_and_replicates_on_distinct_localities() {
        let cl = cluster(4);
        let store = AgasSnapshotStore::new(&cl, 2);
        store.save("s", &[1, 2, 3]).unwrap();
        assert_eq!(store.load("s"), Some(vec![1, 2, 3]));
        assert!(store.contains("s"));
        assert_eq!(store.len(), 1);
        assert_eq!(cl.agas().len(), 2, "two replicas registered");
        let homes: Vec<_> = (1..=2)
            .map(|g| cl.agas().locate(crate::agas::Gid(g)).unwrap())
            .collect();
        assert_ne!(homes[0], homes[1], "replicas must be homed on distinct localities");
        assert!(store.remove("s"));
        assert!(cl.agas().is_empty(), "remove unregisters every replica");
    }

    #[test]
    fn replicated_snapshot_survives_one_kill_and_is_rehomed() {
        let cl = cluster(3);
        let store = AgasSnapshotStore::new(&cl, 2);
        store.save("s", &[9]).unwrap();
        // Kill whichever locality homes the first replica.
        let victim = cl.agas().locate(crate::agas::Gid(1)).unwrap();
        cl.kill(victim);
        store.on_locality_killed(victim);
        assert_eq!(store.load("s"), Some(vec![9]), "a live replica must survive the kill");
        assert_eq!(store.lost(), 0);
        assert!(cl.agas().migrations() >= 1, "the dead-homed replica must be re-homed");
        assert!(
            cl.agas().gids_homed_on(victim).is_empty(),
            "no replica may remain homed on the corpse"
        );
    }

    #[test]
    fn unreplicated_snapshot_dies_with_its_locality() {
        let cl = cluster(2);
        let store = AgasSnapshotStore::new(&cl, 1);
        store.save("only", &[5]).unwrap();
        let victim = cl.agas().locate(crate::agas::Gid(1)).unwrap();
        cl.kill(victim);
        store.on_locality_killed(victim);
        assert_eq!(store.load("only"), None, "single-replica snapshot is lost");
        assert_eq!(store.lost(), 1);
        assert_eq!(store.load("only"), None, "loss is counted once");
        assert_eq!(store.lost(), 1);
    }

    #[test]
    fn read_discovers_loss_lazily_without_a_detector() {
        let cl = cluster(2);
        let store = AgasSnapshotStore::new(&cl, 1);
        store.save("lazy", &[7]).unwrap();
        let victim = cl.agas().locate(crate::agas::Gid(1)).unwrap();
        cl.kill(victim);
        // No on_locality_killed call: the read itself discovers the loss.
        assert!(!store.contains("lazy"));
        assert_eq!(store.lost(), 0, "contains() is a pure probe");
        assert_eq!(store.load("lazy"), None);
        assert_eq!(store.lost(), 1);
    }

    #[test]
    fn save_with_no_live_locality_errors() {
        let cl = cluster(1);
        cl.kill(LocalityId(0));
        let store = AgasSnapshotStore::new(&cl, 2);
        assert!(store.save("s", &[1]).is_err());
        assert_eq!(store.label(), "agas(x2)");
    }
}
