//! Task Replay (§IV-A): the localized analogue of checkpoint/restart.
//!
//! "When the runtime detects an error it replays the failing task as
//! opposed to completely rolling back of the entire program to the
//! previous checkpoint." A failing attempt (error, panic, or rejected
//! validation) is *rescheduled* — each retry is a fresh task on the
//! scheduler, not a loop inside the current task, so a replayed task
//! yields to other runnable work exactly as HPX's implementation does.

use std::sync::Arc;

use crate::api::{run_task_body, IntoTaskResult};
use crate::error::{ResilienceError, TaskError, TaskResult};
use crate::future::{Future, Promise};
use crate::runtime_handle::Runtime;

pub(crate) type Validator<T> = Arc<dyn Fn(&T) -> bool + Send + Sync>;
pub(crate) type Body<T> = Arc<dyn Fn() -> TaskResult<T> + Send + Sync>;

/// Core replay loop shared by every replay variant (and by the
/// replicate+replay extension): run `body`, accept the result if it is
/// `Ok` and passes `validate`, otherwise reschedule up to `n` total
/// attempts, then surface [`ResilienceError::Exhausted`].
pub(crate) fn replay_impl<T: Send + 'static>(
    rt: &Runtime,
    n: usize,
    body: Body<T>,
    validate: Option<Validator<T>>,
) -> Future<T> {
    let (p, fut) = Promise::new();
    let n = n.max(1);
    schedule_attempt(rt.clone(), p, body, validate, n, 1);
    fut
}

fn schedule_attempt<T: Send + 'static>(
    rt: Runtime,
    promise: Promise<T>,
    body: Body<T>,
    validate: Option<Validator<T>>,
    n: usize,
    attempt: usize,
) {
    let pool = Arc::clone(rt.pool());
    pool.spawn_job(Box::new(move || {
        let outcome = body();
        let outcome = match outcome {
            Ok(v) => match &validate {
                Some(check) if !check(&v) => Err(TaskError::ValidationRejected),
                _ => Ok(v),
            },
            Err(e) => Err(e),
        };
        match outcome {
            Ok(v) => promise.set_value(v),
            Err(_) if attempt < n => {
                schedule_attempt(rt, promise, body, validate, n, attempt + 1);
            }
            Err(e) => {
                promise.set_error(
                    ResilienceError::Exhausted { attempts: attempt, last: e }.into(),
                );
            }
        }
    }));
}

/// `hpxr::async_replay(n, f)` — run `f`, rescheduling on error up to `n`
/// total attempts before re-throwing the last error.
pub fn async_replay<T, R, F>(rt: &Runtime, n: usize, f: F) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
{
    replay_impl(rt, n, Arc::new(move || run_task_body(&f)), None)
}

/// `hpxr::async_replay_validate(n, val_f, f)` — as [`async_replay`], but
/// a result is accepted only if `val_f` returns `true`; a rejected
/// result counts as a failed attempt.
pub fn async_replay_validate<T, R, F, V>(rt: &Runtime, n: usize, val_f: V, f: F) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
    V: Fn(&T) -> bool + Send + Sync + 'static,
{
    replay_impl(rt, n, Arc::new(move || run_task_body(&f)), Some(Arc::new(val_f)))
}

/// Resolve dataflow dependencies then hand the shared values to replay.
///
/// Failed dependencies are *not* replayed (re-running the dependent task
/// cannot repair its inputs — the dependency itself carries its own
/// resilient launch if desired); the dependency error propagates, as in
/// HPX.
pub(crate) fn dataflow_replay_impl<T, U, R, F>(
    rt: &Runtime,
    n: usize,
    f: F,
    deps: Vec<Future<T>>,
    validate: Option<Validator<U>>,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
{
    let rt2 = rt.clone();
    let (p, fut) = Promise::new();
    crate::future::when_all_results(deps).on_ready(move |r| {
        let collapsed = match r {
            Ok(results) => crate::future::collapse_results(results),
            Err(e) => Err(e.clone()),
        };
        match collapsed {
            Ok(values) => {
                let values: Arc<Vec<T>> = Arc::new(values);
                let f = Arc::new(f);
                let body: Body<U> = Arc::new(move || {
                    let values = Arc::clone(&values);
                    let f = Arc::clone(&f);
                    run_task_body(move || f(&values))
                });
                // Drive the replay loop straight into the outer promise: no
                // intermediate future, no result forwarding/cloning.
                schedule_attempt(rt2.clone(), p, body, validate, n.max(1), 1);
            }
            Err(e) => p.set_error(e),
        }
    });
    fut
}

/// `hpxr::dataflow_replay(n, f, deps)` — dataflow whose body is replayed
/// up to `n` times on failure once all dependencies are ready.
pub fn dataflow_replay<T, U, R, F>(rt: &Runtime, n: usize, f: F, deps: Vec<Future<T>>) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
{
    dataflow_replay_impl(rt, n, f, deps, None)
}

/// `hpxr::dataflow_replay_validate(n, val_f, f, deps)` — as
/// [`dataflow_replay`] with a validation predicate on the result.
pub fn dataflow_replay_validate<T, U, R, F, V>(
    rt: &Runtime,
    n: usize,
    val_f: V,
    f: F,
    deps: Vec<Future<T>>,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
    V: Fn(&U) -> bool + Send + Sync + 'static,
{
    dataflow_replay_impl(rt, n, f, deps, Some(Arc::new(val_f)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::async_;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    #[test]
    fn replay_succeeds_first_try() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replay(&rt, 3, move || {
            c.fetch_add(1, Ordering::SeqCst);
            7i32
        });
        assert_eq!(f.get(), Ok(7));
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn replay_retries_until_success() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replay(&rt, 5, move || -> TaskResult<i32> {
            if c.fetch_add(1, Ordering::SeqCst) < 2 {
                Err("transient".into())
            } else {
                Ok(99)
            }
        });
        assert_eq!(f.get(), Ok(99));
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn replay_exhausts_and_reports_last_error() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replay(&rt, 3, move || -> TaskResult<i32> {
            c.fetch_add(1, Ordering::SeqCst);
            Err("permanent".into())
        });
        let err = f.get().unwrap_err();
        match err.as_resilience() {
            Some(ResilienceError::Exhausted { attempts: 3, last }) => {
                assert_eq!(last, &TaskError::App("permanent".to_string()));
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn replay_never_exceeds_n_attempts_on_panic() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f: Future<i32> = async_replay(&rt, 4, move || -> i32 {
            c.fetch_add(1, Ordering::SeqCst);
            panic!("always")
        });
        assert!(f.get().is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn replay_validate_rejects_then_accepts() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        // Returns 0,1,2,...; validator accepts values >= 2.
        let f = async_replay_validate(
            &rt,
            5,
            |v: &usize| *v >= 2,
            move || c.fetch_add(1, Ordering::SeqCst),
        );
        assert_eq!(f.get(), Ok(2));
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn replay_validate_exhaustion_reports_validation() {
        let rt = rt();
        let f = async_replay_validate(&rt, 2, |_: &i32| false, || 1i32);
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::Exhausted { attempts: 2, last }) => {
                assert_eq!(last, &TaskError::ValidationRejected);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replay_exhaustion_runs_exactly_n_attempts_for_each_n() {
        // The exhaustion contract, pinned across a range of n: a body
        // that always fails runs exactly n times and surfaces
        // ResilienceError::Exhausted { attempts: n }.
        for n in 1..=6usize {
            let rt = rt();
            let calls = Arc::new(AtomicUsize::new(0));
            let c = Arc::clone(&calls);
            let f = async_replay(&rt, n, move || -> TaskResult<i32> {
                c.fetch_add(1, Ordering::SeqCst);
                Err("always".into())
            });
            let err = f.get().unwrap_err();
            match err.as_resilience() {
                Some(ResilienceError::Exhausted { attempts, last }) => {
                    assert_eq!(*attempts, n, "n={n}");
                    assert_eq!(last, &TaskError::App("always".to_string()));
                }
                other => panic!("n={n}: unexpected {other:?}"),
            }
            assert_eq!(calls.load(Ordering::SeqCst), n, "exactly n bodies must run");
        }
    }

    #[test]
    fn validator_rejection_counts_as_failed_attempt() {
        // A result the validator rejects burns an attempt exactly like a
        // thrown error: n rejections -> n body executions -> Exhausted
        // with ValidationRejected as the last error.
        let n = 4;
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replay_validate(
            &rt,
            n,
            |_: &i32| false, // reject every result
            move || {
                c.fetch_add(1, Ordering::SeqCst);
                1i32
            },
        );
        let err = f.get().unwrap_err();
        match err.as_resilience() {
            Some(ResilienceError::Exhausted { attempts, last }) => {
                assert_eq!(*attempts, n);
                assert_eq!(last, &TaskError::ValidationRejected);
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(
            calls.load(Ordering::SeqCst),
            n,
            "each rejected result must count as one attempt"
        );
    }

    #[test]
    fn mixed_errors_and_rejections_share_the_attempt_budget() {
        // Attempts 1-2 throw, attempts 3-4 compute but fail validation:
        // the budget is shared, and the *last* failure kind is reported.
        let n = 4;
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replay_validate(
            &rt,
            n,
            |_: &usize| false,
            move || -> TaskResult<usize> {
                let i = c.fetch_add(1, Ordering::SeqCst);
                if i < 2 {
                    Err("thrown".into())
                } else {
                    Ok(i)
                }
            },
        );
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::Exhausted { attempts, last }) => {
                assert_eq!(*attempts, n);
                assert_eq!(last, &TaskError::ValidationRejected);
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(calls.load(Ordering::SeqCst), n);
    }

    #[test]
    fn dataflow_replay_gets_dep_values_each_attempt() {
        let rt = rt();
        let a = async_(&rt, || 10i64);
        let b = async_(&rt, || 20i64);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = dataflow_replay(
            &rt,
            4,
            move |vals: &[i64]| -> TaskResult<i64> {
                if c.fetch_add(1, Ordering::SeqCst) < 2 {
                    Err("flaky".into())
                } else {
                    Ok(vals.iter().sum())
                }
            },
            vec![a, b],
        );
        assert_eq!(f.get(), Ok(30));
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn dataflow_replay_does_not_replay_failed_deps() {
        let rt = rt();
        let bad: Future<i64> = async_(&rt, || -> TaskResult<i64> { Err("dep dead".into()) });
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = dataflow_replay(
            &rt,
            3,
            move |_: &[i64]| -> i64 {
                c.fetch_add(1, Ordering::SeqCst);
                0
            },
            vec![bad],
        );
        match f.get() {
            Err(TaskError::DependencyFailed(_)) => {}
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(calls.load(Ordering::SeqCst), 0, "body must never run");
    }

    #[test]
    fn dataflow_replay_validate_end_to_end() {
        let rt = rt();
        let a = async_(&rt, || 3i64);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = dataflow_replay_validate(
            &rt,
            5,
            |v: &i64| *v > 10,
            move |vals: &[i64]| vals[0] + c.fetch_add(1, Ordering::SeqCst) as i64 * 10,
            vec![a],
        );
        // attempts produce 3, 13 -> second passes validation
        assert_eq!(f.get(), Ok(13));
    }
}
