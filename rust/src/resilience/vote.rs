//! Stock voting functions for the `_vote` replicate variants.

/// Strict-majority vote for comparable results: the value that more than
/// half of the replicas computed, or `None` when no value reaches a
/// strict majority.
///
/// O(n²) pairwise comparison — ballots are replica counts (3–5), not
/// data-sized.
pub fn vote_majority<T: PartialEq + Clone>(ballot: &[T]) -> Option<T> {
    let need = ballot.len() / 2 + 1;
    for (i, candidate) in ballot.iter().enumerate() {
        // Count identical values; skip candidates already counted via an
        // earlier equal element.
        if ballot[..i].iter().any(|b| b == candidate) {
            continue;
        }
        let count = ballot.iter().filter(|b| *b == candidate).count();
        if count >= need {
            return Some(candidate.clone());
        }
    }
    None
}

/// Plurality vote: the most frequent value (ties broken by first
/// occurrence). Always produces a winner on a non-empty ballot.
pub fn vote_plurality<T: PartialEq + Clone>(ballot: &[T]) -> Option<T> {
    let mut best: Option<(usize, &T)> = None;
    for (i, candidate) in ballot.iter().enumerate() {
        if ballot[..i].iter().any(|b| b == candidate) {
            continue;
        }
        let count = ballot.iter().filter(|b| *b == candidate).count();
        if best.map_or(true, |(c, _)| count > c) {
            best = Some((count, candidate));
        }
    }
    best.map(|(_, v)| v.clone())
}

/// Median vote for floating-point results — robust consensus when
/// replicas legitimately differ in the low bits (e.g. non-deterministic
/// reduction orders) and a silent error produces an outlier.
pub fn vote_median_f64(ballot: &[f64]) -> Option<f64> {
    if ballot.is_empty() {
        return None;
    }
    let mut sorted: Vec<f64> = ballot.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    Some(sorted[sorted.len() / 2])
}

/// Approximate-equality majority for floats: values within `tol` of each
/// other count as the same candidate; returns the centroid of the
/// majority cluster.
pub fn vote_majority_approx(ballot: &[f64], tol: f64) -> Option<f64> {
    let need = ballot.len() / 2 + 1;
    for (i, &candidate) in ballot.iter().enumerate() {
        if ballot[..i].iter().any(|b| (b - candidate).abs() <= tol) {
            continue;
        }
        let cluster: Vec<f64> = ballot
            .iter()
            .copied()
            .filter(|b| (b - candidate).abs() <= tol)
            .collect();
        if cluster.len() >= need {
            return Some(cluster.iter().sum::<f64>() / cluster.len() as f64);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn majority_simple() {
        assert_eq!(vote_majority(&[1, 1, 2]), Some(1));
        assert_eq!(vote_majority(&[2, 1, 1]), Some(1));
        assert_eq!(vote_majority(&[1, 2, 3]), None);
        assert_eq!(vote_majority(&[1]), Some(1));
        assert_eq!(vote_majority::<i32>(&[]), None);
    }

    #[test]
    fn majority_requires_strict_majority() {
        assert_eq!(vote_majority(&[1, 1, 2, 2]), None);
        assert_eq!(vote_majority(&[1, 1, 1, 2, 2]), Some(1));
    }

    #[test]
    fn plurality_picks_most_frequent() {
        assert_eq!(vote_plurality(&[3, 1, 3, 2]), Some(3));
        assert_eq!(vote_plurality(&[1, 2]), Some(1)); // tie -> first seen
        assert_eq!(vote_plurality::<i32>(&[]), None);
    }

    #[test]
    fn median_f64() {
        assert_eq!(vote_median_f64(&[1.0, 100.0, 2.0]), Some(2.0));
        assert_eq!(vote_median_f64(&[]), None);
        assert_eq!(vote_median_f64(&[5.0]), Some(5.0));
    }

    #[test]
    fn majority_approx_clusters() {
        // Two close values + one outlier: cluster wins, centroid returned.
        let got = vote_majority_approx(&[1.0000001, 1.0000002, 9.0], 1e-3).unwrap();
        assert!((got - 1.00000015).abs() < 1e-6);
        assert_eq!(vote_majority_approx(&[1.0, 2.0, 3.0], 1e-6), None);
    }
}
