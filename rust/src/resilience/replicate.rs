//! Task Replicate (§IV-B): concurrent redundant execution.
//!
//! "This feature launches N instances of a task concurrently" — all
//! replicas are launched eagerly (the paper explicitly does *not* defer
//! replicas the way Subasi et al. do). Four consensus policies, matching
//! the four API variations:
//!
//! * plain — first replica that completes without error wins;
//! * `_validate` — first replica whose result passes validation wins;
//! * `_vote` — wait for all replicas, vote over every computed result
//!   (defeats silent data corruption that completes "successfully");
//! * `_vote_validate` — wait for all, vote over the validated subset.
//!
//! Failure taxonomy on the way out (paper §IV-B(iv)): if every replica
//! errored, the last error is re-thrown (`AllReplicasFailed`); if finite
//! results were computed but none validated, `ValidationFailed`; if the
//! voting function cannot produce a winner, `NoConsensus`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::api::{run_task_body, IntoTaskResult};
use crate::error::{ResilienceError, TaskError, TaskResult};
use crate::future::{Future, Promise};
use crate::runtime_handle::Runtime;

use super::replay::{Body, Validator};

/// A voting function: select the consensus value from the computed
/// results, or `None` if no consensus exists.
pub type Voter<T> = Arc<dyn Fn(&[T]) -> Option<T> + Send + Sync>;

/// Consensus policy for a replicated launch.
enum Policy<T> {
    /// Resolve with the first acceptable result (plain / `_validate`).
    FirstAcceptable,
    /// Collect all results, then vote (`_vote` / `_vote_validate`).
    Vote(Voter<T>),
}

/// Mutable consensus state, all under one lock (hot path: one lock
/// round-trip per replica completion).
struct ReplicateInner<T> {
    // NB: shared with the decorator layer (`resilience::executor`), which
    // drives `on_replica_done` from launcher futures instead of pool jobs.
    promise: Option<Promise<T>>,
    /// Results that completed without error (and passed validation when a
    /// validator is present); only collected under the vote policy.
    accepted: Vec<T>,
    /// Count of replicas that produced *some* finite result (vote policy
    /// distinguishes "all errored" from "none validated").
    finite_results: usize,
    last_error: Option<TaskError>,
    remaining: usize,
}

pub(crate) struct ReplicateState<T> {
    inner: Mutex<ReplicateInner<T>>,
    policy: Policy<T>,
    replicas: usize,
}

impl<T: Send + 'static> ReplicateState<T> {
    /// Fresh consensus state for `replicas` launches resolving `promise`;
    /// `voter` selects the vote policy, `None` first-acceptable.
    pub(crate) fn new(
        promise: Promise<T>,
        replicas: usize,
        voter: Option<Voter<T>>,
    ) -> Arc<Self> {
        Arc::new(ReplicateState {
            inner: Mutex::new(ReplicateInner {
                promise: Some(promise),
                accepted: Vec::with_capacity(replicas),
                finite_results: 0,
                last_error: None,
                remaining: replicas,
            }),
            policy: match voter {
                Some(v) => Policy::Vote(v),
                None => Policy::FirstAcceptable,
            },
            replicas,
        })
    }

    /// Record one replica's outcome; resolve the launch when the policy
    /// allows (first acceptable result, or all replicas accounted for).
    pub(crate) fn on_replica_done(&self, outcome: TaskResult<T>, validated: Option<bool>) {
        enum Action<T> {
            None,
            Resolve(Promise<T>, T),
            Finish,
        }
        let action = {
            let mut g = self.inner.lock().unwrap();
            let mut action = Action::None;
            match outcome {
                Ok(v) => {
                    g.finite_results += 1;
                    match (&self.policy, validated) {
                        (Policy::FirstAcceptable, Some(false)) => {
                            g.last_error = Some(TaskError::ValidationRejected);
                        }
                        (Policy::FirstAcceptable, _) => {
                            if let Some(p) = g.promise.take() {
                                action = Action::Resolve(p, v);
                            }
                        }
                        (Policy::Vote(_), Some(false)) => {
                            // invalid result: excluded from the ballot
                        }
                        (Policy::Vote(_), _) => g.accepted.push(v),
                    }
                }
                Err(e) => {
                    g.last_error = Some(e);
                }
            }
            g.remaining -= 1;
            if g.remaining == 0 && g.promise.is_some() {
                if matches!(action, Action::None) {
                    action = Action::Finish;
                }
            }
            action
        };
        match action {
            Action::None => {}
            Action::Resolve(p, v) => {
                crate::trace::emit(crate::trace::EventKind::ReplicaWin, self.replicas as u64, 0);
                p.set_value(v)
            }
            Action::Finish => self.finish(),
        }
    }

    /// All replicas have reported and nothing resolved yet.
    fn finish(&self) {
        let (promise, ballot, finite, last_error) = {
            let mut g = self.inner.lock().unwrap();
            let Some(p) = g.promise.take() else { return };
            (
                p,
                std::mem::take(&mut g.accepted),
                g.finite_results,
                g.last_error.take(),
            )
        };
        let all_failed_error = |finite: usize, last: Option<TaskError>| -> ResilienceError {
            if finite > 0 {
                // Results were computed but all rejected by validation.
                ResilienceError::ValidationFailed { replicas: self.replicas }
            } else {
                ResilienceError::AllReplicasFailed {
                    replicas: self.replicas,
                    last: last.unwrap_or(TaskError::App("no replica produced a result".into())),
                }
            }
        };
        match &self.policy {
            Policy::FirstAcceptable => {
                promise.set_error(all_failed_error(finite, last_error).into());
            }
            Policy::Vote(voter) => {
                if ballot.is_empty() {
                    promise.set_error(all_failed_error(finite, last_error).into());
                } else {
                    match voter(&ballot) {
                        Some(winner) => promise.set_value(winner),
                        None => promise.set_error(
                            ResilienceError::NoConsensus { candidates: ballot.len() }.into(),
                        ),
                    }
                }
            }
        }
    }
}

/// Launch `n` replicas of `body` and resolve `promise` per the policy.
pub(crate) fn replicate_impl<T: Send + 'static>(
    rt: &Runtime,
    n: usize,
    promise: Promise<T>,
    body: Body<T>,
    validate: Option<Validator<T>>,
    policy_vote: Option<Voter<T>>,
) {
    let n = n.max(1);
    let state = ReplicateState::new(promise, n, policy_vote);

    for _ in 0..n {
        let state = Arc::clone(&state);
        let body = Arc::clone(&body);
        let validate = validate.clone();
        rt.pool().spawn_job(Box::new(move || {
            let outcome = body();
            match outcome {
                Ok(v) => {
                    let validated = validate.as_ref().map(|check| check(&v));
                    state.on_replica_done(Ok(v), validated);
                }
                Err(e) => state.on_replica_done(Err(e), None),
            }
        }));
    }
}

/// Wrap `body` so each replica privately retries up to `attempts` times
/// (validation included in the retry criterion) before reporting — the
/// paper's future-work refinement of replicate ("allowing any failed
/// replicated task to replay until its computed without error
/// detection"), giving "finer consensus in case of soft failures".
pub(crate) fn with_retries<T: Send + 'static>(
    body: Body<T>,
    validate: Option<Validator<T>>,
    attempts: usize,
) -> Body<T> {
    let attempts = attempts.max(1);
    Arc::new(move || {
        let mut last: Option<TaskError> = None;
        for _ in 0..attempts {
            match body() {
                Ok(v) => {
                    if validate.as_ref().map_or(true, |check| check(&v)) {
                        return Ok(v);
                    }
                    last = Some(TaskError::ValidationRejected);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("attempts >= 1 recorded an error"))
    })
}

// ---------------------------------------------------------------------
// Replica teams (first-result-wins with loser cancellation)
// ---------------------------------------------------------------------

/// Shared cancellation flag of a replica team (TeaMPI-style). Cloned into
/// every replica; set by the team when the first acceptable result
/// resolves the future. Replicas are expected to check it at body entry
/// (and, for dataflow tasks, between dependency resolution and launch)
/// and retire with [`TaskError::Cancelled`] instead of doing the work.
///
/// The token is advisory: a replica that never checks still runs to
/// completion, but its late result is dropped — the team's promise has
/// already been taken, so a cancelled replica can never write into a
/// resolved future.
#[derive(Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        CancelToken::default()
    }

    /// Retire the remaining team members.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// Mutable first-result-wins state, all under one lock.
struct TeamInner<T> {
    promise: Option<Promise<T>>,
    remaining: usize,
    /// Replicas that produced a finite result (even if validation then
    /// rejected it) — distinguishes `ValidationFailed` from
    /// `AllReplicasFailed` when nobody wins.
    finite_results: usize,
    /// Losers that retired via the cancel token instead of running.
    retired: usize,
    last_error: Option<TaskError>,
}

/// A first-result-wins replica team: the first replica whose result is
/// acceptable (no error, and positively validated when a validator is in
/// play) resolves the shared future and cancels the rest of the team
/// through a [`CancelToken`]. This differs from the paper's plain
/// replicate (§IV-B), which lets every replica run to completion: a team
/// sheds the losers' work, trading replicate's silent-corruption ballot
/// for near-replay cost with replicate's fail-fast latency.
///
/// The team is consensus machinery only — it does not launch anything.
/// Callers (the `team:N` mode of `ReplicateExecutor`, the deterministic
/// schedule tests) fan the replicas out themselves and funnel outcomes
/// into [`report`](ReplicaTeam::report) or
/// [`run_replica`](ReplicaTeam::run_replica).
pub struct ReplicaTeam<T> {
    inner: Mutex<TeamInner<T>>,
    token: CancelToken,
    replicas: usize,
}

impl<T: Send + 'static> ReplicaTeam<T> {
    /// A team expecting `replicas` reports; the future resolves with the
    /// first acceptable result, or the team-wide failure when none is.
    pub fn new(replicas: usize) -> (Arc<Self>, Future<T>) {
        let (p, fut) = Promise::new();
        (Self::with_promise(p, replicas), fut)
    }

    /// A team resolving an existing promise (the decorator layer's
    /// `spawn_into` contract hands the promise in).
    pub(crate) fn with_promise(promise: Promise<T>, replicas: usize) -> Arc<Self> {
        let replicas = replicas.max(1);
        Arc::new(ReplicaTeam {
            inner: Mutex::new(TeamInner {
                promise: Some(promise),
                remaining: replicas,
                finite_results: 0,
                retired: 0,
                last_error: None,
            }),
            token: CancelToken::new(),
            replicas,
        })
    }

    /// The team's shared cancellation token.
    pub fn token(&self) -> CancelToken {
        self.token.clone()
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Losers that retired through the cancel token so far.
    pub fn retired(&self) -> usize {
        self.inner.lock().unwrap().retired
    }

    /// Replicas that have not reported yet.
    pub fn outstanding(&self) -> usize {
        self.inner.lock().unwrap().remaining
    }

    /// Check the token, run `body` if the team is still racing, and
    /// report the outcome — the whole per-replica protocol in one call.
    pub fn run_replica<F>(&self, body: F)
    where
        F: FnOnce() -> TaskResult<T>,
    {
        if self.token.is_cancelled() {
            self.report(Err(TaskError::Cancelled), None);
            return;
        }
        self.report(body(), None);
    }

    /// Record one replica's outcome. The first `Ok` not rejected by
    /// validation takes the promise, resolves it, and cancels the token
    /// (in that order, under the team lock, so no later report can win).
    /// `Err(Cancelled)` is an orderly loser retirement, not a failure.
    /// When every replica has reported and nothing won: validation
    /// rejections yield `ValidationFailed`, otherwise `AllReplicasFailed`
    /// with the last real error.
    pub fn report(&self, outcome: TaskResult<T>, validated: Option<bool>) {
        enum Action<T> {
            None,
            Resolve(Promise<T>, T),
            Fail(Promise<T>, usize, Option<TaskError>),
        }
        let action = {
            let mut g = self.inner.lock().unwrap();
            g.remaining = g.remaining.saturating_sub(1);
            let mut action = Action::None;
            match outcome {
                Ok(v) => {
                    g.finite_results += 1;
                    if validated == Some(false) {
                        g.last_error = Some(TaskError::ValidationRejected);
                    } else if let Some(p) = g.promise.take() {
                        // Cancel while still holding the lock: by the
                        // time any other replica can observe an
                        // un-cancelled token and report, the promise is
                        // already gone.
                        self.token.cancel();
                        action = Action::Resolve(p, v);
                    }
                }
                Err(TaskError::Cancelled) => {
                    g.retired += 1;
                    crate::trace::emit(
                        crate::trace::EventKind::ReplicaCancel,
                        self.replicas as u64,
                        g.retired as u64,
                    );
                }
                Err(e) => {
                    g.last_error = Some(e);
                }
            }
            if g.remaining == 0 && g.promise.is_some() {
                if let Some(p) = g.promise.take() {
                    action = Action::Fail(p, g.finite_results, g.last_error.take());
                }
            }
            action
        };
        match action {
            Action::None => {}
            Action::Resolve(p, v) => {
                crate::trace::emit(crate::trace::EventKind::ReplicaWin, self.replicas as u64, 0);
                p.set_value(v)
            }
            Action::Fail(p, finite, last) => {
                let err = if finite > 0 {
                    ResilienceError::ValidationFailed { replicas: self.replicas }
                } else {
                    ResilienceError::AllReplicasFailed {
                        replicas: self.replicas,
                        last: last
                            .unwrap_or(TaskError::App("no replica produced a result".into())),
                    }
                };
                p.set_error(err.into());
            }
        }
    }
}

// ---------------------------------------------------------------------
// async_* wrappers (Listing 2)
// ---------------------------------------------------------------------

fn make_body<T, R, F>(f: F) -> Body<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
{
    Arc::new(move || run_task_body(&f))
}

/// `hpxr::async_replicate(n, f)` — launch `n` concurrent instances of
/// `f`; resolve with the first result that completes without error.
pub fn async_replicate<T, R, F>(rt: &Runtime, n: usize, f: F) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
{
    let (p, fut) = Promise::new();
    replicate_impl(rt, n, p, make_body(f), None, None);
    fut
}

/// `hpxr::async_replicate_validate(n, val_f, f)` — first result that is
/// positively validated wins.
pub fn async_replicate_validate<T, R, F, V>(rt: &Runtime, n: usize, val_f: V, f: F) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
    V: Fn(&T) -> bool + Send + Sync + 'static,
{
    let (p, fut) = Promise::new();
    replicate_impl(rt, n, p, make_body(f), Some(Arc::new(val_f)), None);
    fut
}

/// `hpxr::async_replicate_vote(n, vote_f, f)` — wait for all replicas and
/// build a consensus over every computed result (silent-error defence).
pub fn async_replicate_vote<T, R, F, W>(rt: &Runtime, n: usize, vote_f: W, f: F) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
    W: Fn(&[T]) -> Option<T> + Send + Sync + 'static,
{
    let (p, fut) = Promise::new();
    replicate_impl(rt, n, p, make_body(f), None, Some(Arc::new(vote_f)));
    fut
}

/// `hpxr::async_replicate_vote_validate(n, vote_f, val_f, f)` — wait for
/// all replicas, vote over the positively validated subset.
pub fn async_replicate_vote_validate<T, R, F, V, W>(
    rt: &Runtime,
    n: usize,
    vote_f: W,
    val_f: V,
    f: F,
) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
    V: Fn(&T) -> bool + Send + Sync + 'static,
    W: Fn(&[T]) -> Option<T> + Send + Sync + 'static,
{
    let (p, fut) = Promise::new();
    replicate_impl(rt, n, p, make_body(f), Some(Arc::new(val_f)), Some(Arc::new(vote_f)));
    fut
}

/// Replicate-of-replays (§Future-Work, implemented): `n` concurrent
/// replicas, each privately retrying up to `replay_n` times before it
/// reports; consensus by vote when `vote_f` is given, else first-OK.
pub fn async_replicate_replay<T, R, F, W>(
    rt: &Runtime,
    n: usize,
    replay_n: usize,
    vote_f: Option<W>,
    f: F,
) -> Future<T>
where
    T: Send + 'static,
    R: IntoTaskResult<T>,
    F: Fn() -> R + Send + Sync + 'static,
    W: Fn(&[T]) -> Option<T> + Send + Sync + 'static,
{
    let (p, fut) = Promise::new();
    let body = with_retries(make_body(f), None, replay_n);
    let voter: Option<Voter<T>> = vote_f.map(|w| Arc::new(w) as Voter<T>);
    replicate_impl(rt, n, p, body, None, voter);
    fut
}

// ---------------------------------------------------------------------
// dataflow_* wrappers (Listing 2)
// ---------------------------------------------------------------------

/// Shared plumbing: resolve deps, build a `Body` over the shared values,
/// then replicate it into the outer promise.
fn dataflow_replicate_common<T, U, R, F>(
    rt: &Runtime,
    n: usize,
    f: F,
    deps: Vec<Future<T>>,
    validate: Option<Validator<U>>,
    voter: Option<Voter<U>>,
    replay_each: usize,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
{
    let rt2 = rt.clone();
    let (p, fut) = Promise::new();
    crate::future::when_all_results(deps).on_ready(move |r| {
        let collapsed = match r {
            Ok(results) => crate::future::collapse_results(results),
            Err(e) => Err(e.clone()),
        };
        match collapsed {
            Ok(values) => {
                let values: Arc<Vec<T>> = Arc::new(values);
                let f = Arc::new(f);
                let base: Body<U> = Arc::new(move || {
                    let values = Arc::clone(&values);
                    let f = Arc::clone(&f);
                    run_task_body(move || f(&values))
                });
                let body = if replay_each > 1 {
                    with_retries(base, validate.clone(), replay_each)
                } else {
                    base
                };
                replicate_impl(&rt2, n, p, body, validate, voter);
            }
            Err(e) => p.set_error(e),
        }
    });
    fut
}

/// `hpxr::dataflow_replicate(n, f, deps)`.
pub fn dataflow_replicate<T, U, R, F>(
    rt: &Runtime,
    n: usize,
    f: F,
    deps: Vec<Future<T>>,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
{
    dataflow_replicate_common(rt, n, f, deps, None, None, 1)
}

/// `hpxr::dataflow_replicate_validate(n, val_f, f, deps)`.
pub fn dataflow_replicate_validate<T, U, R, F, V>(
    rt: &Runtime,
    n: usize,
    val_f: V,
    f: F,
    deps: Vec<Future<T>>,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
    V: Fn(&U) -> bool + Send + Sync + 'static,
{
    dataflow_replicate_common(rt, n, f, deps, Some(Arc::new(val_f)), None, 1)
}

/// `hpxr::dataflow_replicate_vote(n, vote_f, f, deps)`.
pub fn dataflow_replicate_vote<T, U, R, F, W>(
    rt: &Runtime,
    n: usize,
    vote_f: W,
    f: F,
    deps: Vec<Future<T>>,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
    W: Fn(&[U]) -> Option<U> + Send + Sync + 'static,
{
    dataflow_replicate_common(rt, n, f, deps, None, Some(Arc::new(vote_f)), 1)
}

/// `hpxr::dataflow_replicate_vote_validate(n, vote_f, val_f, f, deps)`.
pub fn dataflow_replicate_vote_validate<T, U, R, F, V, W>(
    rt: &Runtime,
    n: usize,
    vote_f: W,
    val_f: V,
    f: F,
    deps: Vec<Future<T>>,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
    V: Fn(&U) -> bool + Send + Sync + 'static,
    W: Fn(&[U]) -> Option<U> + Send + Sync + 'static,
{
    dataflow_replicate_common(rt, n, f, deps, Some(Arc::new(val_f)), Some(Arc::new(vote_f)), 1)
}

/// Dataflow replicate-of-replays (§Future-Work, implemented).
pub fn dataflow_replicate_replay<T, U, R, F>(
    rt: &Runtime,
    n: usize,
    replay_n: usize,
    f: F,
    deps: Vec<Future<T>>,
) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
{
    dataflow_replicate_common(rt, n, f, deps, None, None, replay_n.max(2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::async_;
    use crate::resilience::vote::vote_majority;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    #[test]
    fn replicate_first_ok_wins() {
        let rt = rt();
        let f = async_replicate(&rt, 3, || 11i32);
        assert_eq!(f.get(), Ok(11));
        rt.wait_idle(); // remaining replicas still run to completion
        assert_eq!(rt.stats().spawned, 3);
    }

    #[test]
    fn replicate_all_replicas_launched_eagerly() {
        // The paper: "we replicate the tasks and do not defer the launch
        // of any task" — all n run even after an early success.
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replicate(&rt, 4, move || {
            c.fetch_add(1, Ordering::SeqCst);
            1i32
        });
        assert_eq!(f.get(), Ok(1));
        rt.wait_idle();
        assert_eq!(calls.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn replicate_survives_partial_failures() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replicate(&rt, 3, move || -> TaskResult<usize> {
            // First two replicas fail; the third succeeds.
            let i = c.fetch_add(1, Ordering::SeqCst);
            if i < 2 {
                Err("replica died".into())
            } else {
                Ok(i)
            }
        });
        assert_eq!(f.get(), Ok(2));
    }

    #[test]
    fn replicate_all_fail_reports_last_error() {
        let rt = rt();
        let f: Future<i32> =
            async_replicate(&rt, 3, || -> TaskResult<i32> { Err("dead".into()) });
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::AllReplicasFailed { replicas: 3, last }) => {
                assert_eq!(last, &TaskError::App("dead".to_string()));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicate_validate_filters() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replicate_validate(
            &rt,
            4,
            |v: &usize| *v >= 2,
            move || c.fetch_add(1, Ordering::SeqCst),
        );
        let v = f.get().unwrap();
        assert!(v >= 2, "validated result only: got {v}");
    }

    #[test]
    fn replicate_validate_none_validates() {
        let rt = rt();
        let f = async_replicate_validate(&rt, 3, |_: &i32| false, || 5i32);
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::ValidationFailed { replicas: 3 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicate_vote_defeats_silent_minority_corruption() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replicate_vote(&rt, 3, vote_majority, move || {
            // One replica silently corrupts its result.
            if c.fetch_add(1, Ordering::SeqCst) == 0 {
                666i64
            } else {
                42i64
            }
        });
        assert_eq!(f.get(), Ok(42));
    }

    #[test]
    fn replicate_vote_validate_combines_filters() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = async_replicate_vote_validate(
            &rt,
            4,
            vote_majority,
            |v: &i64| *v < 100,
            move || {
                let i = c.fetch_add(1, Ordering::SeqCst);
                if i == 0 {
                    666i64 // rejected by validation
                } else {
                    7i64
                }
            },
        );
        assert_eq!(f.get(), Ok(7));
    }

    #[test]
    fn replicate_vote_no_consensus() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        // All distinct values, majority threshold unreachable.
        let f = async_replicate_vote(&rt, 3, vote_majority, move || {
            c.fetch_add(1, Ordering::SeqCst) as i64
        });
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::NoConsensus { candidates: 3 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicate_replay_recovers_flaky_replicas() {
        let rt = rt();
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        // Every first call of a replica fails; retries succeed.
        let f = async_replicate_replay::<i64, TaskResult<i64>, _, fn(&[i64]) -> Option<i64>>(
            &rt,
            2,
            3,
            None,
            move || {
                let i = c.fetch_add(1, Ordering::SeqCst);
                if i % 2 == 0 {
                    Err("flaky".into())
                } else {
                    Ok(5)
                }
            },
        );
        assert_eq!(f.get(), Ok(5));
    }

    #[test]
    fn team_first_result_wins_and_cancels_losers() {
        let (team, fut) = ReplicaTeam::<i32>::new(3);
        assert!(!team.token().is_cancelled());
        team.report(Ok(7), None);
        assert!(team.token().is_cancelled(), "winner must retire the team");
        assert_eq!(fut.get_copy(), Ok(7));
        // Losers checking the token retire without running their bodies.
        let ran = std::cell::Cell::new(false);
        team.run_replica(|| {
            ran.set(true);
            Ok(99)
        });
        team.run_replica(|| {
            ran.set(true);
            Ok(98)
        });
        assert!(!ran.get(), "cancelled replicas must not execute");
        assert_eq!(team.retired(), 2);
        assert_eq!(team.outstanding(), 0);
        // The future still holds the winner's value.
        assert_eq!(fut.get_copy(), Ok(7));
    }

    #[test]
    fn team_late_uncancelled_result_is_dropped() {
        // A replica that never checks the token loses the race: its Ok
        // arrives after the promise was taken and vanishes.
        let (team, fut) = ReplicaTeam::<i32>::new(2);
        team.report(Ok(1), None);
        team.report(Ok(2), None);
        assert_eq!(fut.get_copy(), Ok(1));
        assert_eq!(team.retired(), 0);
    }

    #[test]
    fn team_validation_rejection_does_not_win() {
        let (team, fut) = ReplicaTeam::<i32>::new(2);
        team.report(Ok(666), Some(false));
        assert!(!team.token().is_cancelled(), "rejected result must not cancel");
        team.report(Ok(42), Some(true));
        assert_eq!(fut.get_copy(), Ok(42));
    }

    #[test]
    fn team_all_rejected_reports_validation_failure() {
        let (team, fut) = ReplicaTeam::<i32>::new(2);
        team.report(Ok(1), Some(false));
        team.report(Ok(2), Some(false));
        match fut.get().unwrap_err().as_resilience() {
            Some(ResilienceError::ValidationFailed { replicas: 2 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn team_all_failed_reports_last_error() {
        let (team, fut) = ReplicaTeam::<i32>::new(2);
        team.report(Err("first".into()), None);
        team.report(Err("second".into()), None);
        match fut.get().unwrap_err().as_resilience() {
            Some(ResilienceError::AllReplicasFailed { replicas: 2, last }) => {
                assert_eq!(last, &TaskError::App("second".to_string()));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn team_retirement_is_not_a_failure() {
        // One real failure plus one retirement: the retirement must not
        // overwrite the real error in the team-wide report.
        let (team, fut) = ReplicaTeam::<i32>::new(2);
        team.report(Err("real".into()), None);
        team.token().cancel();
        team.run_replica(|| Ok(5));
        match fut.get().unwrap_err().as_resilience() {
            Some(ResilienceError::AllReplicasFailed { last, .. }) => {
                assert_eq!(last, &TaskError::App("real".to_string()));
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(team.retired(), 1);
    }

    #[test]
    fn dataflow_replicate_end_to_end() {
        let rt = rt();
        let a = async_(&rt, || 2i64);
        let b = async_(&rt, || 3i64);
        let f = dataflow_replicate(&rt, 3, |v: &[i64]| v[0] * v[1], vec![a, b]);
        assert_eq!(f.get(), Ok(6));
    }

    #[test]
    fn dataflow_replicate_vote_validate_end_to_end() {
        let rt = rt();
        let a = async_(&rt, || 10i64);
        let f = dataflow_replicate_vote_validate(
            &rt,
            3,
            vote_majority,
            |v: &i64| *v > 0,
            |vals: &[i64]| vals[0] * 2,
            vec![a],
        );
        assert_eq!(f.get(), Ok(20));
    }

    #[test]
    fn dataflow_replicate_propagates_dep_failure() {
        let rt = rt();
        let bad: Future<i64> = async_(&rt, || -> TaskResult<i64> { Err("dep".into()) });
        let f = dataflow_replicate(&rt, 3, |v: &[i64]| v[0], vec![bad]);
        match f.get() {
            Err(TaskError::DependencyFailed(_)) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn dataflow_replicate_replay_end_to_end() {
        let rt = rt();
        let a = async_(&rt, || 1i64);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = dataflow_replicate_replay(
            &rt,
            2,
            3,
            move |v: &[i64]| -> TaskResult<i64> {
                if c.fetch_add(1, Ordering::SeqCst) == 0 {
                    Err("first attempt dies".into())
                } else {
                    Ok(v[0] + 100)
                }
            },
            vec![a],
        );
        assert_eq!(f.get(), Ok(101));
    }
}
