//! Resilient executor decorators + adaptive budget policy (§IV as an
//! executor surface; implements the paper's future-work "special
//! executors that will manage the aspects of resiliency").
//!
//! The free functions of [`crate::resilience`] make one *call site*
//! resilient. This module makes a whole *launch path* resilient: a
//! [`TaskLauncher`] says where attempts physically run (a scheduler pool,
//! a simulated cluster), and the decorators [`ReplayExecutor`] /
//! [`ReplicateExecutor`] wrap any launcher so that every task submitted
//! through them transparently gains replay or replication semantics —
//! validated and voting variants included. Call sites written against
//! [`ResilientExecutor`] (or the [`crate::async_on`] /
//! [`crate::dataflow_on`] free functions) never change; the policy is
//! swapped by swapping the executor, exactly like TeaMPI decorates the
//! MPI launch path.
//!
//! On top of the fixed-budget decorators, [`AdaptivePolicy`] tunes the
//! replay/replication budget *n* online from the observed per-executor
//! error rate (an EWMA over recent attempts), published through
//! [`crate::perfcounters`] under `/resilience/<name>/...`. Both knobs
//! are selectable declaratively through [`PolicySpec`]: `Adaptive` tunes
//! the *retry* budget of a replay decorator, `AdaptiveReplicate` tunes
//! the eager *fan-out width* of a replicate decorator — and
//! [`PolicySpec::build_over`] constructs either one over any launcher,
//! pool or cluster, which is how the distributed stencil route
//! (`rhpx stencil --cluster …`) gets its resilience.
//!
//! ```
//! use rhpx::resilience::executor::{PoolExecutor, ReplayExecutor, ResilientExecutor};
//! use rhpx::Runtime;
//!
//! let rt = Runtime::builder().workers(2).build();
//! // Swap this executor — not the call sites — to change the policy.
//! let exec = ReplayExecutor::new(PoolExecutor::new(&rt), 3);
//! let f = exec.spawn(|| 21i32 * 2);
//! assert_eq!(f.get(), Ok(42));
//! ```

use std::sync::{Arc, Mutex};

use crate::api::{run_task_body, IntoTaskResult};
use crate::error::{ResilienceError, TaskError, TaskResult};
use crate::future::{when_all_results, Future, Promise};
use crate::perfcounters::{global, Instrument};
use crate::runtime_handle::Runtime;

use super::replicate::{with_retries, ReplicaTeam, ReplicateState};
use super::Voter;

/// A re-runnable task body, shared across attempts and replicas.
pub type TaskFn<T> = Arc<dyn Fn() -> TaskResult<T> + Send + Sync>;

/// A shared validation predicate over a computed result.
pub type TaskValidator<T> = Arc<dyn Fn(&T) -> bool + Send + Sync>;

// ---------------------------------------------------------------------
// Base launchers
// ---------------------------------------------------------------------

/// Where task attempts physically run.
///
/// A launcher submits *one* execution of a body and resolves the returned
/// future with its outcome; the resilience decorators call it once per
/// attempt (replay) or once per replica (replicate). Implementors:
/// [`PoolExecutor`] (a [`Runtime`]'s scheduler pool) and
/// [`crate::distributed::ClusterExecutor`] (round-robin over simulated
/// localities).
pub trait TaskLauncher: Clone + Send + Sync + 'static {
    /// Submit one execution of `body`.
    fn submit<T: Send + 'static>(&self, body: TaskFn<T>) -> Future<T>;

    /// Sample a placement token for one resilient launch. Decorators
    /// call this once per launch and pass it, with each attempt/replica
    /// index, to [`TaskLauncher::submit_seq`] — so a launcher with a
    /// placement notion can guarantee deterministic spread per launch
    /// (the cluster launcher maps `token + seq` onto successive
    /// localities: every retry lands on the *next* locality and replicas
    /// fan out to distinct ones, even when many launches interleave).
    /// Launchers with no placement notion return 0.
    fn placement_token(&self) -> usize {
        0
    }

    /// Submit attempt/replica number `seq` (0-based) of the launch that
    /// sampled `token`. The default ignores placement.
    fn submit_seq<T: Send + 'static>(
        &self,
        body: TaskFn<T>,
        token: usize,
        seq: usize,
    ) -> Future<T> {
        let _ = (token, seq);
        self.submit(body)
    }

    /// How many attempts can make progress concurrently.
    fn parallelism(&self) -> usize;

    /// Human-readable description of the substrate (for reports).
    fn base_label(&self) -> String;
}

/// The scheduler-backed base launcher: every submission is a fresh job on
/// the [`Runtime`]'s work-stealing pool (so a replayed attempt yields to
/// other runnable work, exactly like the free-function replay).
#[derive(Clone)]
pub struct PoolExecutor {
    rt: Runtime,
}

impl PoolExecutor {
    pub fn new(rt: &Runtime) -> Self {
        PoolExecutor { rt: rt.clone() }
    }

    /// The runtime this launcher submits to.
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl TaskLauncher for PoolExecutor {
    fn submit<T: Send + 'static>(&self, body: TaskFn<T>) -> Future<T> {
        let (p, fut) = Promise::new();
        self.rt.pool().spawn_job(Box::new(move || {
            p.set_result(run_task_body(move || body()));
        }));
        fut
    }

    fn parallelism(&self) -> usize {
        self.rt.workers()
    }

    fn base_label(&self) -> String {
        format!("pool({})", self.rt.workers())
    }
}

// ---------------------------------------------------------------------
// The executor surface
// ---------------------------------------------------------------------

/// The executor-routed launch surface: `async_(exec, f)` call sites are
/// written once against this trait, and gain (or lose) resiliency by
/// swapping the executor instance — never the call.
///
/// [`PoolExecutor`] implements it with single-attempt semantics (the
/// baseline); [`ReplayExecutor`] and [`ReplicateExecutor`] decorate any
/// [`TaskLauncher`] with the paper's replay/replicate policies.
pub trait ResilientExecutor: Clone + Send + Sync + 'static {
    /// Core launch: drive `body` (checked by `validate` when present)
    /// into `promise` under this executor's policy. The provided
    /// convenience methods below all funnel through here.
    fn spawn_into<T>(
        &self,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
    ) where
        T: Clone + Send + 'static;

    /// Parallelism hint (used by algorithms for chunking).
    fn concurrency(&self) -> usize;

    /// Policy description, e.g. `replay(3) over pool(4)`.
    fn label(&self) -> String;

    /// Launch `f` under this executor's policy.
    fn spawn<T, R, F>(&self, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        R: IntoTaskResult<T>,
        F: Fn() -> R + Send + Sync + 'static,
    {
        let (p, fut) = Promise::new();
        self.spawn_into(p, Arc::new(move || run_task_body(&f)), None);
        fut
    }

    /// Launch `f`; a result is acceptable only if `val_f` returns `true`
    /// (a rejected result counts as a failed attempt, as in the
    /// `*_validate` free functions).
    fn spawn_validate<T, R, F, V>(&self, val_f: V, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        R: IntoTaskResult<T>,
        F: Fn() -> R + Send + Sync + 'static,
        V: Fn(&T) -> bool + Send + Sync + 'static,
    {
        let (p, fut) = Promise::new();
        self.spawn_into(p, Arc::new(move || run_task_body(&f)), Some(Arc::new(val_f)));
        fut
    }

    /// Dataflow through this executor: run `f` over the dependency values
    /// once all of `deps` are ready. Failed dependencies are not retried
    /// (the dependency carries its own resilient launch if desired); the
    /// body itself runs under this executor's policy.
    fn dataflow<T, U, R, F>(&self, f: F, deps: Vec<Future<T>>) -> Future<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Clone + Send + 'static,
        R: IntoTaskResult<U>,
        F: Fn(&[T]) -> R + Send + Sync + 'static,
    {
        dataflow_into(self, f, deps, None)
    }

    /// As [`ResilientExecutor::dataflow`], with a validation predicate on
    /// the body's result.
    fn dataflow_validate<T, U, R, F, V>(&self, val_f: V, f: F, deps: Vec<Future<T>>) -> Future<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Clone + Send + 'static,
        R: IntoTaskResult<U>,
        F: Fn(&[T]) -> R + Send + Sync + 'static,
        V: Fn(&U) -> bool + Send + Sync + 'static,
    {
        dataflow_into(self, f, deps, Some(Arc::new(val_f)))
    }
}

/// Resolve `deps`, build the shared re-runnable body over the collapsed
/// values, and hand it — with the outer promise — to `sink` (no
/// intermediate future, mirroring the free-function dataflow variants).
/// Failed dependencies skip `sink` and poison the promise directly.
/// Shared with the checkpoint decorator ([`super::checkpoint`]).
pub(crate) fn with_resolved_deps<T, U, R, F, G>(f: F, deps: Vec<Future<T>>, sink: G) -> Future<U>
where
    T: Clone + Send + Sync + 'static,
    U: Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
    G: FnOnce(Promise<U>, TaskFn<U>) + Send + 'static,
{
    let (p, fut) = Promise::new();
    when_all_results(deps).on_ready(move |r| {
        let collapsed = match r {
            Ok(results) => crate::future::collapse_results(results),
            Err(e) => Err(e.clone()),
        };
        match collapsed {
            Ok(values) => {
                let values: Arc<Vec<T>> = Arc::new(values);
                let f = Arc::new(f);
                let body: TaskFn<U> = Arc::new(move || {
                    let values = Arc::clone(&values);
                    let f = Arc::clone(&f);
                    run_task_body(move || f(&values))
                });
                sink(p, body);
            }
            Err(e) => p.set_error(e),
        }
    });
    fut
}

/// Resolve `deps`, then drive the body into the outer promise through the
/// executor's policy.
fn dataflow_into<EX, T, U, R, F>(
    ex: &EX,
    f: F,
    deps: Vec<Future<T>>,
    validate: Option<TaskValidator<U>>,
) -> Future<U>
where
    EX: ResilientExecutor,
    T: Clone + Send + Sync + 'static,
    U: Clone + Send + 'static,
    R: IntoTaskResult<U>,
    F: Fn(&[T]) -> R + Send + Sync + 'static,
{
    let ex = ex.clone();
    with_resolved_deps(f, deps, move |p, body| ex.spawn_into(p, body, validate))
}

/// Single-attempt `spawn_into` shared by the base (undecorated)
/// executors: run once; a validation rejection surfaces as
/// [`TaskError::ValidationRejected`] with no retry.
pub(crate) fn base_spawn_into<E, T>(
    base: &E,
    promise: Promise<T>,
    body: TaskFn<T>,
    validate: Option<TaskValidator<T>>,
) where
    E: TaskLauncher,
    T: Clone + Send + 'static,
{
    base.submit(body).on_ready(move |r| match r {
        Ok(v) => match &validate {
            Some(check) if !check(v) => {
                crate::trace::emit(crate::trace::EventKind::ValidateFail, 0, 0);
                promise.set_error(TaskError::ValidationRejected)
            }
            Some(_) => {
                crate::trace::emit(crate::trace::EventKind::ValidatePass, 0, 0);
                promise.set_value(v.clone())
            }
            None => promise.set_value(v.clone()),
        },
        Err(e) => promise.set_error(e.clone()),
    });
}

impl ResilientExecutor for PoolExecutor {
    fn spawn_into<T>(
        &self,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
    ) where
        T: Clone + Send + 'static,
    {
        base_spawn_into(self, promise, body, validate);
    }

    fn concurrency(&self) -> usize {
        self.parallelism()
    }

    fn label(&self) -> String {
        self.base_label()
    }
}

// ---------------------------------------------------------------------
// Budget: fixed n, or adaptively tuned
// ---------------------------------------------------------------------

/// The attempt/replica budget of a decorator: a fixed `n`, or one tuned
/// online by an [`AdaptivePolicy`].
#[derive(Clone)]
pub enum Budget {
    /// A fixed budget, as in the paper's `async_replay(n, …)`.
    Fixed(usize),
    /// Budget sampled from the policy at each launch.
    Adaptive(Arc<AdaptivePolicy>),
}

impl Budget {
    /// The budget to use for a launch starting now.
    pub fn n(&self) -> usize {
        match self {
            Budget::Fixed(n) => (*n).max(1),
            Budget::Adaptive(p) => p.budget(),
        }
    }

    /// Feed one attempt outcome back into the policy (no-op when fixed).
    fn record(&self, failed: bool) {
        if let Budget::Adaptive(p) = self {
            p.record(failed);
        }
    }

    fn label(&self) -> String {
        match self {
            Budget::Fixed(n) => n.to_string(),
            Budget::Adaptive(p) => format!("adaptive(max {})", p.ceiling()),
        }
    }
}

/// Configuration for an [`AdaptivePolicy`].
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// EWMA weight of the newest observation, in `(0, 1]`.
    pub alpha: f64,
    /// Minimum budget (used while the observed error rate is ~0).
    pub floor: usize,
    /// Hard ceiling the budget never exceeds.
    pub ceiling: usize,
    /// Desired probability that a launch still fails after `n` attempts:
    /// the policy picks the smallest `n` with `p^n <= target` (clamped to
    /// `[floor, ceiling]`), where `p` is the EWMA error rate.
    pub target: f64,
    /// Perfcounter namespace: instruments are registered under
    /// `/resilience/<name>/...` in the global registry.
    pub name: String,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            alpha: 0.1,
            floor: 2,
            ceiling: 8,
            target: 1e-4,
            name: "default".to_string(),
        }
    }
}

/// Online tuner for the replay/replication budget `n`.
///
/// Every attempt outcome is folded into an exponentially weighted moving
/// average of the per-attempt error rate; [`AdaptivePolicy::budget`]
/// translates that rate into the smallest `n` meeting the configured
/// residual-failure target, clamped to `[floor, ceiling]`. The observed
/// rate and current budget are published as performance counters
/// (`/resilience/<name>/gauge/error_rate_ppm`, `.../gauge/budget`) plus
/// monotonic attempt/failure counts.
///
/// ```
/// use rhpx::resilience::executor::{AdaptiveConfig, AdaptivePolicy};
///
/// let policy = AdaptivePolicy::new(AdaptiveConfig {
///     alpha: 0.5,
///     floor: 1,
///     ceiling: 6,
///     target: 0.01,
///     name: "doc".to_string(),
/// });
/// assert_eq!(policy.budget(), 1); // quiet: the floor
/// for _ in 0..8 {
///     policy.record(true); // failure spike
/// }
/// assert_eq!(policy.budget(), 6); // clamped at the ceiling
/// for _ in 0..12 {
///     policy.record(false); // quiet period
/// }
/// assert_eq!(policy.budget(), 1); // decays back to the floor
/// ```
pub struct AdaptivePolicy {
    cfg: AdaptiveConfig,
    ewma: Mutex<f64>,
    attempts: Arc<Instrument>,
    failures: Arc<Instrument>,
    budget_gauge: Arc<Instrument>,
    rate_gauge: Arc<Instrument>,
}

impl AdaptivePolicy {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        let reg = global();
        let base = format!("/resilience/{}", cfg.name);
        let policy = AdaptivePolicy {
            attempts: reg.counter(&format!("{base}/count/attempts")),
            failures: reg.counter(&format!("{base}/count/failures")),
            budget_gauge: reg.gauge(&format!("{base}/gauge/budget")),
            rate_gauge: reg.gauge(&format!("{base}/gauge/error_rate_ppm")),
            ewma: Mutex::new(0.0),
            cfg,
        };
        policy.budget_gauge.set(policy.budget() as u64);
        policy
    }

    /// A policy with default tuning under the given counter namespace.
    pub fn named(name: &str) -> Self {
        AdaptivePolicy::new(AdaptiveConfig { name: name.to_string(), ..Default::default() })
    }

    /// Fold one attempt outcome into the error-rate estimate.
    pub fn record(&self, failed: bool) {
        self.attempts.increment(1);
        if failed {
            self.failures.increment(1);
        }
        let p = {
            let mut g = self.ewma.lock().unwrap();
            let x = if failed { 1.0 } else { 0.0 };
            *g = self.cfg.alpha * x + (1.0 - self.cfg.alpha) * *g;
            *g
        };
        self.rate_gauge.set((p * 1e6) as u64);
        self.budget_gauge.set(self.budget_for(p) as u64);
    }

    /// The current EWMA per-attempt error-rate estimate, in `[0, 1]`.
    pub fn error_rate(&self) -> f64 {
        *self.ewma.lock().unwrap()
    }

    /// The budget `n` a launch starting now should use.
    pub fn budget(&self) -> usize {
        self.budget_for(self.error_rate())
    }

    /// The configured hard ceiling.
    pub fn ceiling(&self) -> usize {
        self.cfg.ceiling.max(self.cfg.floor.max(1))
    }

    /// Total attempts observed (from the perfcounter).
    pub fn attempts(&self) -> u64 {
        self.attempts.get()
    }

    /// Total failed attempts observed (from the perfcounter).
    pub fn failures(&self) -> u64 {
        self.failures.get()
    }

    fn budget_for(&self, p: f64) -> usize {
        let floor = self.cfg.floor.max(1);
        let ceiling = self.cfg.ceiling.max(floor);
        if !(p > 0.0) {
            return floor;
        }
        if p >= 1.0 {
            return ceiling;
        }
        let target = self.cfg.target.clamp(1e-12, 0.5);
        let raw = (target.ln() / p.ln()).ceil();
        if !raw.is_finite() || raw <= floor as f64 {
            floor
        } else {
            (raw as usize).min(ceiling)
        }
    }
}

// ---------------------------------------------------------------------
// ReplayExecutor<E>
// ---------------------------------------------------------------------

/// Decorator: every task spawned through it is replayed up to the budget
/// on failure (error, panic, or rejected validation), each retry being a
/// fresh submission on the wrapped launcher — §IV-A (task replay) as a
/// launch policy instead of a call-site change.
///
/// ```
/// use std::sync::atomic::{AtomicUsize, Ordering};
/// use std::sync::Arc;
///
/// use rhpx::resilience::executor::{ReplayExecutor, ResilientExecutor};
/// use rhpx::{Runtime, TaskResult};
///
/// let rt = Runtime::builder().workers(2).build();
/// let exec = ReplayExecutor::new(rt.executor(), 5);
/// let calls = Arc::new(AtomicUsize::new(0));
/// let c = Arc::clone(&calls);
/// let f = exec.spawn(move || -> TaskResult<i32> {
///     if c.fetch_add(1, Ordering::SeqCst) < 2 {
///         Err("transient".into())
///     } else {
///         Ok(99)
///     }
/// });
/// assert_eq!(f.get(), Ok(99));
/// assert_eq!(calls.load(Ordering::SeqCst), 3);
/// ```
#[derive(Clone)]
pub struct ReplayExecutor<E: TaskLauncher> {
    base: E,
    budget: Budget,
}

impl<E: TaskLauncher> ReplayExecutor<E> {
    /// Replay up to `n` total attempts per launch.
    pub fn new(base: E, n: usize) -> Self {
        ReplayExecutor { base, budget: Budget::Fixed(n.max(1)) }
    }

    /// Replay with the budget tuned online by `policy`.
    pub fn adaptive(base: E, policy: Arc<AdaptivePolicy>) -> Self {
        ReplayExecutor { base, budget: Budget::Adaptive(policy) }
    }

    /// The budget a launch starting now would receive.
    pub fn current_budget(&self) -> usize {
        self.budget.n()
    }

    /// The adaptive policy, when this executor uses one.
    pub fn policy(&self) -> Option<&Arc<AdaptivePolicy>> {
        match &self.budget {
            Budget::Adaptive(p) => Some(p),
            Budget::Fixed(_) => None,
        }
    }

    /// The wrapped launcher (the substrate attempts run on).
    pub fn base(&self) -> &E {
        &self.base
    }
}

fn replay_attempt<E, T>(
    base: E,
    budget: Budget,
    promise: Promise<T>,
    body: TaskFn<T>,
    validate: Option<TaskValidator<T>>,
    token: usize,
    n: usize,
    attempt: usize,
) where
    E: TaskLauncher,
    T: Clone + Send + 'static,
{
    let fut = base.submit_seq(Arc::clone(&body), token, attempt - 1);
    fut.on_ready(move |r| {
        let outcome = match r {
            Ok(v) => match &validate {
                Some(check) if !check(v) => {
                    crate::trace::emit(crate::trace::EventKind::ValidateFail, token as u64, 0);
                    Err(TaskError::ValidationRejected)
                }
                Some(_) => {
                    crate::trace::emit(crate::trace::EventKind::ValidatePass, token as u64, 0);
                    Ok(v.clone())
                }
                None => Ok(v.clone()),
            },
            Err(e) => Err(e.clone()),
        };
        match outcome {
            Ok(v) => {
                budget.record(false);
                promise.set_value(v);
            }
            Err(_) if attempt < n => {
                budget.record(true);
                crate::trace::emit(
                    crate::trace::EventKind::ReplayAttempt,
                    token as u64,
                    (attempt + 1) as u64,
                );
                replay_attempt(base, budget, promise, body, validate, token, n, attempt + 1);
            }
            Err(e) => {
                budget.record(true);
                promise.set_error(
                    ResilienceError::Exhausted { attempts: attempt, last: e }.into(),
                );
            }
        }
    });
}

impl<E: TaskLauncher> ResilientExecutor for ReplayExecutor<E> {
    fn spawn_into<T>(
        &self,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
    ) where
        T: Clone + Send + 'static,
    {
        let n = self.budget.n();
        let token = self.base.placement_token();
        replay_attempt(
            self.base.clone(),
            self.budget.clone(),
            promise,
            body,
            validate,
            token,
            n,
            1,
        );
    }

    fn concurrency(&self) -> usize {
        self.base.parallelism()
    }

    fn label(&self) -> String {
        format!("replay({}) over {}", self.budget.label(), self.base.base_label())
    }
}

// ---------------------------------------------------------------------
// ReplicateExecutor<E>
// ---------------------------------------------------------------------

/// Decorator: every task spawned through it is launched as `n` eager
/// replicas on the wrapped launcher — §IV-B (task replicate) as a launch
/// policy. Consensus is the same machinery as the free functions
/// ([`ReplicateState`](crate::resilience) internals, shared code): first
/// acceptable result wins, or — via [`ReplicateExecutor::spawn_vote`] —
/// all replicas are awaited and a voting function picks the winner.
///
/// ```
/// use rhpx::resilience::executor::{PoolExecutor, ReplicateExecutor};
/// use rhpx::resilience::vote_majority;
/// use rhpx::Runtime;
///
/// let rt = Runtime::builder().workers(2).build();
/// let exec = ReplicateExecutor::new(PoolExecutor::new(&rt), 3);
/// let f = exec.spawn_vote(vote_majority, || 7i64);
/// assert_eq!(f.get(), Ok(7));
/// ```
#[derive(Clone)]
pub struct ReplicateExecutor<E: TaskLauncher> {
    base: E,
    budget: Budget,
    /// Per-replica private replay attempts (the paper's future-work
    /// replicate-of-replays refinement); 1 = off.
    replay_each: usize,
    /// First-result-wins mode ([`ReplicateExecutor::team`]): the first
    /// acceptable replica resolves the future and a shared
    /// [`CancelToken`](super::CancelToken) retires the losers.
    first_wins: bool,
}

impl<E: TaskLauncher> ReplicateExecutor<E> {
    /// Launch `n` eager replicas per task.
    pub fn new(base: E, n: usize) -> Self {
        ReplicateExecutor {
            base,
            budget: Budget::Fixed(n.max(1)),
            replay_each: 1,
            first_wins: false,
        }
    }

    /// Replicate with the width tuned online by `policy`.
    pub fn adaptive(base: E, policy: Arc<AdaptivePolicy>) -> Self {
        ReplicateExecutor {
            base,
            budget: Budget::Adaptive(policy),
            replay_each: 1,
            first_wins: false,
        }
    }

    /// A first-result-wins replica *team* of width `n` (TeaMPI-style):
    /// replicas still fan out eagerly, but the first one whose result is
    /// acceptable resolves the future and cancels the rest through a
    /// shared [`CancelToken`](super::CancelToken), checked at each
    /// replica's body entry. Losers still queued when the token flips
    /// retire without executing — team mode sheds most of replication's
    /// eager-compute overhead while keeping its fail-fast latency.
    /// Selected as `team:N` through [`PolicySpec`].
    pub fn team(base: E, n: usize) -> Self {
        ReplicateExecutor {
            base,
            budget: Budget::Fixed(n.max(1)),
            replay_each: 1,
            first_wins: true,
        }
    }

    /// Whether this executor races replicas first-result-wins.
    pub fn is_team(&self) -> bool {
        self.first_wins
    }

    /// Let each replica privately retry up to `attempts` times before it
    /// reports (replicate-of-replays, §Future-Work). With an adaptive
    /// budget, the policy sees one outcome per *replica* (the retried
    /// aggregate), not one per inner attempt.
    pub fn with_replay(mut self, attempts: usize) -> Self {
        self.replay_each = attempts.max(1);
        self
    }

    /// The replica count a launch starting now would receive.
    pub fn current_budget(&self) -> usize {
        self.budget.n()
    }

    /// The adaptive policy, when this executor uses one.
    pub fn policy(&self) -> Option<&Arc<AdaptivePolicy>> {
        match &self.budget {
            Budget::Adaptive(p) => Some(p),
            Budget::Fixed(_) => None,
        }
    }

    /// The wrapped launcher (the substrate replicas run on).
    pub fn base(&self) -> &E {
        &self.base
    }

    fn replicate_into<T>(
        &self,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
        voter: Option<Voter<T>>,
    ) where
        T: Clone + Send + 'static,
    {
        let n = self.budget.n();
        // With per-replica retries, `with_retries` already validates each
        // inner attempt — an `Ok` coming out of it is validated, so the
        // consensus layer must not re-run (and re-price) the predicate.
        let (body, validate) = if self.replay_each > 1 {
            (with_retries(body, validate, self.replay_each), None)
        } else {
            (body, validate)
        };
        // First-result-wins team mode replaces the consensus state with a
        // ReplicaTeam; a vote needs every ballot, so an explicit voter
        // keeps the all-replicas semantics even on a team executor.
        if self.first_wins && voter.is_none() {
            self.team_into(promise, body, validate, n);
            return;
        }
        let state = ReplicateState::new(promise, n, voter);
        let token = self.base.placement_token();
        for i in 0..n {
            crate::trace::emit(crate::trace::EventKind::ReplicaLaunch, token as u64, i as u64);
            let state = Arc::clone(&state);
            let validate = validate.clone();
            let budget = self.budget.clone();
            self.base.submit_seq(Arc::clone(&body), token, i).on_ready(move |r| match r {
                Ok(v) => {
                    let validated = validate.as_ref().map(|check| check(v));
                    budget.record(validated == Some(false));
                    state.on_replica_done(Ok(v.clone()), validated);
                }
                Err(e) => {
                    budget.record(true);
                    state.on_replica_done(Err(e.clone()), None);
                }
            });
        }
    }

    /// Fan `n` replicas out first-result-wins: every replica's body is
    /// guarded by the team's [`CancelToken`](super::CancelToken) — a
    /// replica whose slot comes up after the race is decided reports
    /// [`TaskError::Cancelled`] instead of executing. For dataflow
    /// launches the guard sits between dependency resolution and the
    /// body (the deps resolve once, before fan-out), so a team whose
    /// race ended while deps were pending sheds all of its bodies.
    fn team_into<T>(
        &self,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
        n: usize,
    ) where
        T: Clone + Send + 'static,
    {
        let team = ReplicaTeam::with_promise(promise, n);
        let token = self.base.placement_token();
        for i in 0..n {
            crate::trace::emit(crate::trace::EventKind::ReplicaLaunch, token as u64, i as u64);
            let team = Arc::clone(&team);
            let cancel = team.token();
            let validate = validate.clone();
            let budget = self.budget.clone();
            let body = Arc::clone(&body);
            let guarded: TaskFn<T> = Arc::new(move || {
                if cancel.is_cancelled() {
                    return Err(TaskError::Cancelled);
                }
                body()
            });
            self.base.submit_seq(guarded, token, i).on_ready(move |r| match r {
                Ok(v) => {
                    let validated = validate.as_ref().map(|check| check(v));
                    budget.record(validated == Some(false));
                    team.report(Ok(v.clone()), validated);
                }
                Err(e) => {
                    // A retirement is the cancellation protocol working,
                    // not a substrate failure — keep it out of any
                    // adaptive error-rate estimate.
                    if !matches!(e, TaskError::Cancelled) {
                        budget.record(true);
                    }
                    team.report(Err(e.clone()), None);
                }
            });
        }
    }

    /// Replicated launch with consensus by vote: wait for all replicas,
    /// then `vote_f` picks the winner over every computed result (the
    /// silent-error defence of the `*_vote` free functions).
    pub fn spawn_vote<T, R, F, W>(&self, vote_f: W, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        R: IntoTaskResult<T>,
        F: Fn() -> R + Send + Sync + 'static,
        W: Fn(&[T]) -> Option<T> + Send + Sync + 'static,
    {
        let (p, fut) = Promise::new();
        self.replicate_into(
            p,
            Arc::new(move || run_task_body(&f)),
            None,
            Some(Arc::new(vote_f)),
        );
        fut
    }

    /// As [`ReplicateExecutor::spawn_vote`], voting only over the
    /// positively validated subset of results.
    pub fn spawn_vote_validate<T, R, F, V, W>(&self, vote_f: W, val_f: V, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        R: IntoTaskResult<T>,
        F: Fn() -> R + Send + Sync + 'static,
        V: Fn(&T) -> bool + Send + Sync + 'static,
        W: Fn(&[T]) -> Option<T> + Send + Sync + 'static,
    {
        let (p, fut) = Promise::new();
        self.replicate_into(
            p,
            Arc::new(move || run_task_body(&f)),
            Some(Arc::new(val_f)),
            Some(Arc::new(vote_f)),
        );
        fut
    }

    /// Voting dataflow through this executor (all replicas awaited, then
    /// `vote_f` decides), for call sites that also carry dependencies.
    pub fn dataflow_vote<T, U, R, F, W>(
        &self,
        vote_f: W,
        f: F,
        deps: Vec<Future<T>>,
    ) -> Future<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Clone + Send + 'static,
        R: IntoTaskResult<U>,
        F: Fn(&[T]) -> R + Send + Sync + 'static,
        W: Fn(&[U]) -> Option<U> + Send + Sync + 'static,
    {
        let ex = self.clone();
        let voter: Voter<U> = Arc::new(vote_f);
        with_resolved_deps(f, deps, move |p, body| {
            ex.replicate_into(p, body, None, Some(voter))
        })
    }
}

impl<E: TaskLauncher> ResilientExecutor for ReplicateExecutor<E> {
    fn spawn_into<T>(
        &self,
        promise: Promise<T>,
        body: TaskFn<T>,
        validate: Option<TaskValidator<T>>,
    ) where
        T: Clone + Send + 'static,
    {
        self.replicate_into(promise, body, validate, None);
    }

    fn concurrency(&self) -> usize {
        self.base.parallelism()
    }

    fn label(&self) -> String {
        let kind = if self.first_wins { "team" } else { "replicate" };
        format!("{kind}({}) over {}", self.budget.label(), self.base.base_label())
    }
}

// ---------------------------------------------------------------------
// Declarative policy selection (shared by the CLI-facing layers)
// ---------------------------------------------------------------------

/// Quiet-state width of the [`PolicySpec::AdaptiveReplicate`] policy.
/// Replicas are *eager* compute — unlike replay attempts they cost a full
/// body execution even when nothing fails — so the floor stays at the
/// smallest width that still tolerates one loss at launch time (a
/// replicated launch cannot retro-widen once its replicas are in
/// flight). The policy widens toward the ceiling as failures are
/// observed.
pub const ADAPTIVE_REPLICATE_FLOOR: usize = 2;

/// Declarative decorator selection shared by the CLI-facing layers (the
/// stencil driver's `--resilience` route re-exports this as
/// `stencil::ExecPolicy`; the workload bench path as
/// `workload::ExecVariant`), so the labels and the construction logic
/// live in exactly one place.
///
/// The two adaptive arms share one [`AdaptivePolicy`] mechanism but tune
/// different knobs: [`PolicySpec::Adaptive`] maps to
/// [`ReplayExecutor::adaptive`] (the budget is *retries*, cheap while
/// quiet), while [`PolicySpec::AdaptiveReplicate`] maps to
/// [`ReplicateExecutor::adaptive`] (the budget is eager *fan-out width*,
/// which can mask failures without adding retry latency — the right
/// trade when the substrate is a cluster and a dead locality would
/// otherwise stall every retry chain routed through it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicySpec {
    /// `ReplayExecutor(n)` over the base launcher.
    Replay { n: usize },
    /// `ReplicateExecutor(n)` over the base launcher (first validated
    /// replica wins).
    Replicate { n: usize },
    /// [`ReplicateExecutor::team`]`(n)` over the base launcher:
    /// first-result-wins replica team — the first acceptable replica
    /// resolves the future and the losers retire through a shared
    /// [`CancelToken`](super::CancelToken) instead of running.
    Team { n: usize },
    /// No decoration, but standalone submissions are routed over *live*
    /// localities only and the substrate's kill-time lineage drain is
    /// the sole recovery mechanism: queued-but-unexecuted tasks on a
    /// corpse re-materialize onto survivors. The cheapest survival mode
    /// measured by `table_dist` — no retries, no replicas, just the
    /// resilient-work-stealing drain plus membership-aware placement.
    Drain,
    /// Adaptive replay: the retry budget is tuned online by an
    /// [`AdaptivePolicy`] and never exceeds `ceiling`.
    Adaptive { ceiling: usize },
    /// Adaptive replication *width*: the eager fan-out is tuned online
    /// by an [`AdaptivePolicy`] between [`ADAPTIVE_REPLICATE_FLOOR`] and
    /// `ceiling`, so sustained failures widen the replica set instead of
    /// lengthening retry chains.
    AdaptiveReplicate { ceiling: usize },
    /// Task-level checkpoint/restart
    /// ([`super::checkpoint::CheckpointExecutor`]): snapshot every
    /// `every` wavefront windows into the selected [`SnapshotBackend`];
    /// on failure, restore from the last snapshot and replay only the
    /// delta. Drivers with checkpoint-aware loops (the stencil) own the
    /// keying/restart strategy; through the generic [`BuiltExecutor`]
    /// surface un-keyed launches pass through undecorated.
    Checkpoint { every: usize, backend: SnapshotBackend },
}

/// Which [`crate::checkpoint::store::SnapshotStore`] backend a
/// [`PolicySpec::Checkpoint`] policy persists into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotBackend {
    /// Route-appropriate default: in-memory on a pool, AGAS-replicated
    /// (factor 2) on a cluster.
    Auto,
    /// In-memory (lower bound on persistence cost).
    Memory,
    /// On-disk, fsynced (models persistent-storage I/O cost).
    Disk,
    /// AGAS-replicated across live localities
    /// ([`super::checkpoint::AgasSnapshotStore`]); requires a cluster.
    Agas,
}

impl SnapshotBackend {
    /// Short CLI/report token (`checkpoint:K:<this>`).
    pub fn token(&self) -> &'static str {
        match self {
            SnapshotBackend::Auto => "auto",
            SnapshotBackend::Memory => "mem",
            SnapshotBackend::Disk => "disk",
            SnapshotBackend::Agas => "agas",
        }
    }

    /// Inverse of [`SnapshotBackend::token`] (plus the `memory` long
    /// form the CLI has always accepted).
    pub fn parse(s: &str) -> Result<SnapshotBackend, PolicyParseError> {
        match s {
            "auto" => Ok(SnapshotBackend::Auto),
            "mem" | "memory" => Ok(SnapshotBackend::Memory),
            "disk" => Ok(SnapshotBackend::Disk),
            "agas" => Ok(SnapshotBackend::Agas),
            other => Err(PolicyParseError::UnknownBackend { got: other.to_string() }),
        }
    }
}

/// Why a policy spec string failed to parse ([`PolicySpec::parse`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PolicyParseError {
    /// The spec named no known policy.
    UnknownPolicy { spec: String },
    /// A count/ceiling/interval was missing, non-numeric, or zero.
    BadCount { what: &'static str, got: String },
    /// `checkpoint:K:<backend>` named no known snapshot backend.
    UnknownBackend { got: String },
}

impl std::fmt::Display for PolicyParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicyParseError::UnknownPolicy { spec } => write!(
                f,
                "unknown policy spec {spec:?} (expected replay:N, replicate:N, team:N, \
                 drain, adaptive[:CEIL], adaptive_replicate[:CEIL], or \
                 checkpoint:K[:mem|disk|agas])"
            ),
            PolicyParseError::BadCount { what, got } => {
                write!(f, "{what}: bad count {got:?} (expected an integer >= 1)")
            }
            PolicyParseError::UnknownBackend { got } => write!(
                f,
                "checkpoint: unknown backend {got:?} (expected auto, mem, disk, or agas)"
            ),
        }
    }
}

impl std::error::Error for PolicyParseError {}

impl PolicySpec {
    pub fn label(&self) -> String {
        match self {
            PolicySpec::Replay { n } => format!("exec_replay({n})"),
            PolicySpec::Replicate { n } => format!("exec_replicate({n})"),
            PolicySpec::Team { n } => format!("exec_team({n})"),
            PolicySpec::Drain => "exec_drain".to_string(),
            PolicySpec::Adaptive { ceiling } => format!("exec_adaptive(max {ceiling})"),
            PolicySpec::AdaptiveReplicate { ceiling } => {
                format!("exec_adaptive_replicate(max {ceiling})")
            }
            PolicySpec::Checkpoint { every, backend: SnapshotBackend::Auto } => {
                format!("exec_checkpoint({every})")
            }
            PolicySpec::Checkpoint { every, backend } => {
                format!("exec_checkpoint({every},{})", backend.token())
            }
        }
    }

    /// The canonical CLI spec string: what `--resilience` accepts and
    /// what [`PolicySpec::parse`] round-trips. [`SnapshotBackend::Auto`]
    /// renders without a backend suffix, exactly as users write it
    /// (`checkpoint:2`), so `parse(token()) == *self` for every variant.
    pub fn token(&self) -> String {
        match self {
            PolicySpec::Replay { n } => format!("replay:{n}"),
            PolicySpec::Replicate { n } => format!("replicate:{n}"),
            PolicySpec::Team { n } => format!("team:{n}"),
            PolicySpec::Drain => "drain".to_string(),
            PolicySpec::Adaptive { ceiling } => format!("adaptive:{ceiling}"),
            PolicySpec::AdaptiveReplicate { ceiling } => format!("adaptive_replicate:{ceiling}"),
            PolicySpec::Checkpoint { every, backend: SnapshotBackend::Auto } => {
                format!("checkpoint:{every}")
            }
            PolicySpec::Checkpoint { every, backend } => {
                format!("checkpoint:{every}:{}", backend.token())
            }
        }
    }

    /// Parse a `--resilience`-style spec string:
    /// `replay:N | replicate:N | team:N | drain | adaptive[:CEIL]
    /// | adaptive_replicate[:CEIL] | checkpoint:K[:auto|mem|disk|agas]`.
    /// The bare adaptive forms default their ceilings (10 for replay
    /// budgets, 4 for replication width); every count must be ≥ 1. This
    /// is the single spec-string parser in the tree — the CLI and the
    /// workload engine both call it.
    pub fn parse(s: &str) -> Result<PolicySpec, PolicyParseError> {
        if s == "adaptive" {
            return Ok(PolicySpec::Adaptive { ceiling: 10 });
        }
        if s == "adaptive_replicate" {
            return Ok(PolicySpec::AdaptiveReplicate { ceiling: 4 });
        }
        if s == "drain" {
            return Ok(PolicySpec::Drain);
        }
        let parse_n = |v: &str, what: &'static str| -> Result<usize, PolicyParseError> {
            v.parse()
                .ok()
                .filter(|n| *n >= 1)
                .ok_or(PolicyParseError::BadCount { what, got: v.to_string() })
        };
        if let Some(v) = s.strip_prefix("checkpoint:") {
            let (every, backend) = match v.split_once(':') {
                None => (v, SnapshotBackend::Auto),
                Some((every, b)) => (every, SnapshotBackend::parse(b)?),
            };
            return Ok(PolicySpec::Checkpoint { every: parse_n(every, "checkpoint")?, backend });
        }
        if let Some(v) = s.strip_prefix("adaptive_replicate:") {
            return Ok(PolicySpec::AdaptiveReplicate {
                ceiling: parse_n(v, "adaptive_replicate")?,
            });
        }
        if let Some(v) = s.strip_prefix("adaptive:") {
            return Ok(PolicySpec::Adaptive { ceiling: parse_n(v, "adaptive")? });
        }
        if let Some(v) = s.strip_prefix("replay:") {
            return Ok(PolicySpec::Replay { n: parse_n(v, "replay")? });
        }
        if let Some(v) = s.strip_prefix("replicate:") {
            return Ok(PolicySpec::Replicate { n: parse_n(v, "replicate")? });
        }
        if let Some(v) = s.strip_prefix("team:") {
            return Ok(PolicySpec::Team { n: parse_n(v, "team")? });
        }
        Err(PolicyParseError::UnknownPolicy { spec: s.to_string() })
    }

    /// Eager-compute multiplier: replicate runs the body `n` times even
    /// without failures; replay (fixed or adaptive) runs it once. The
    /// adaptive-replicate arm reports its quiet-state width (the floor) —
    /// the actual width grows with the observed error rate.
    pub fn compute_multiplier(&self) -> usize {
        match self {
            PolicySpec::Replicate { n } => *n,
            // Worst case: every replica starts before the winner cancels.
            // In practice the token retires still-queued losers, which is
            // exactly the overhead gap `table_dist` measures.
            PolicySpec::Team { n } => *n,
            PolicySpec::AdaptiveReplicate { ceiling } => {
                ADAPTIVE_REPLICATE_FLOOR.min((*ceiling).max(1))
            }
            _ => 1,
        }
    }

    /// Whether a cluster substrate under this policy should route
    /// standalone submissions over live localities only
    /// ([`ClusterExecutor::alive_routed`](crate::distributed)). The
    /// drain policy has no per-task retry or replica to absorb a
    /// routed-to-corpse rejection, so — like the checkpoint strategy's
    /// driver route — it must consume the membership view; every other
    /// policy keeps the full ring so its placement guarantees (and the
    /// control arm's failure signal) are unchanged.
    pub fn routes_alive_only(&self) -> bool {
        matches!(self, PolicySpec::Drain)
    }

    /// Build the decorator over `rt`'s pool. `name` namespaces the
    /// adaptive perfcounters; `floor` is the adaptive minimum budget,
    /// clamped so the requested ceiling is always honored exactly.
    pub fn build(&self, rt: &Runtime, name: &str, floor: usize) -> BuiltExecutor {
        self.build_over(PoolExecutor::new(rt), name, floor)
    }

    /// Build the decorator over any [`TaskLauncher`] — the seam the
    /// distributed stencil route goes through: the same spec that builds
    /// a pool decorator builds a cluster decorator, so swapping the
    /// substrate never changes the policy selection logic.
    ///
    /// `floor` applies to the adaptive *replay* arm only; the
    /// adaptive-replicate arm pins its floor at
    /// [`ADAPTIVE_REPLICATE_FLOOR`] because every quiet-state replica is
    /// paid in eager compute (see the constant's docs).
    pub fn build_over<E: TaskLauncher>(
        &self,
        base: E,
        name: &str,
        floor: usize,
    ) -> BuiltExecutor<E> {
        match *self {
            PolicySpec::Replay { n } => BuiltExecutor::Replay(ReplayExecutor::new(base, n)),
            PolicySpec::Replicate { n } => {
                BuiltExecutor::Replicate(ReplicateExecutor::new(base, n))
            }
            PolicySpec::Team { n } => {
                BuiltExecutor::Replicate(ReplicateExecutor::team(base, n))
            }
            // Drain is a substrate property (lineage drain + alive
            // routing), not a decorator: the launch path stays single.
            PolicySpec::Drain => BuiltExecutor::Single(base),
            PolicySpec::Adaptive { ceiling } => {
                let ceiling = ceiling.max(1);
                let policy = Arc::new(AdaptivePolicy::new(AdaptiveConfig {
                    floor: floor.clamp(1, ceiling),
                    ceiling,
                    name: name.to_string(),
                    ..AdaptiveConfig::default()
                }));
                BuiltExecutor::Replay(ReplayExecutor::adaptive(base, policy))
            }
            PolicySpec::AdaptiveReplicate { ceiling } => {
                let ceiling = ceiling.max(1);
                let policy = Arc::new(AdaptivePolicy::new(AdaptiveConfig {
                    floor: ADAPTIVE_REPLICATE_FLOOR.clamp(1, ceiling),
                    ceiling,
                    name: name.to_string(),
                    ..AdaptiveConfig::default()
                }));
                BuiltExecutor::Replicate(ReplicateExecutor::adaptive(base, policy))
            }
            PolicySpec::Checkpoint { backend, .. } => {
                // The generic builder has no cluster in hand: `Agas`
                // (and `Auto` on a cluster) is resolved by the stencil
                // driver, which constructs the replicated store itself;
                // here `Auto`/`Agas` degrade to the in-memory backend.
                // The disk dir is unique per build — two checkpoint
                // executors in one process must never serve each
                // other's snapshot files.
                let store: Arc<dyn crate::checkpoint::store::SnapshotStore> = match backend {
                    SnapshotBackend::Disk => Arc::new(crate::checkpoint::DiskSnapshotStore::new(
                        crate::checkpoint::store::unique_temp_dir("rhpx_snapshots"),
                    )),
                    _ => Arc::new(crate::checkpoint::MemorySnapshotStore::new()),
                };
                BuiltExecutor::Checkpoint(super::checkpoint::CheckpointExecutor::new(
                    base, store, name,
                ))
            }
        }
    }
}

/// A decorator built from a [`PolicySpec`] over some launcher — a small
/// dispatch facade so call sites need not be generic over the concrete
/// decorator type. The [`BuiltExecutor::Single`] variant is the
/// undecorated baseline (one attempt per task, no retries): it is what
/// the cluster stencil route runs *without* `--resilience`, so the
/// failure experiment has a control arm that shares every other code
/// path with the resilient runs.
#[derive(Clone)]
pub enum BuiltExecutor<E: TaskLauncher = PoolExecutor> {
    /// No decoration: one attempt per task straight through the base
    /// launcher (a rejected validation surfaces with no retry).
    Single(E),
    Replay(ReplayExecutor<E>),
    Replicate(ReplicateExecutor<E>),
    /// Task-level checkpoint/restart. Through this generic surface
    /// (un-keyed launches) it behaves like [`BuiltExecutor::Single`];
    /// the keyed memoizing surface is reached via
    /// [`BuiltExecutor::checkpoint`].
    Checkpoint(super::checkpoint::CheckpointExecutor<E>),
}

impl<E: TaskLauncher> BuiltExecutor<E> {
    /// Launch `f` under the built policy.
    pub fn spawn<T, R, F>(&self, f: F) -> Future<T>
    where
        T: Clone + Send + 'static,
        R: IntoTaskResult<T>,
        F: Fn() -> R + Send + Sync + 'static,
    {
        match self {
            BuiltExecutor::Single(base) => {
                let (p, fut) = Promise::new();
                base_spawn_into(base, p, Arc::new(move || run_task_body(&f)), None);
                fut
            }
            BuiltExecutor::Replay(ex) => ex.spawn(f),
            BuiltExecutor::Replicate(ex) => ex.spawn(f),
            BuiltExecutor::Checkpoint(ex) => ex.spawn(f),
        }
    }

    /// Validated dataflow under the built policy.
    pub fn dataflow_validate<T, U, R, F, V>(
        &self,
        val_f: V,
        f: F,
        deps: Vec<Future<T>>,
    ) -> Future<U>
    where
        T: Clone + Send + Sync + 'static,
        U: Clone + Send + 'static,
        R: IntoTaskResult<U>,
        F: Fn(&[T]) -> R + Send + Sync + 'static,
        V: Fn(&U) -> bool + Send + Sync + 'static,
    {
        match self {
            BuiltExecutor::Single(base) => {
                let base = base.clone();
                let validate: TaskValidator<U> = Arc::new(val_f);
                with_resolved_deps(f, deps, move |p, body| {
                    base_spawn_into(&base, p, body, Some(validate))
                })
            }
            BuiltExecutor::Replay(ex) => ex.dataflow_validate(val_f, f, deps),
            BuiltExecutor::Replicate(ex) => ex.dataflow_validate(val_f, f, deps),
            BuiltExecutor::Checkpoint(ex) => ex.dataflow_validate(val_f, f, deps),
        }
    }

    /// Policy description of the underlying decorator.
    pub fn label(&self) -> String {
        match self {
            BuiltExecutor::Single(base) => format!("single over {}", base.base_label()),
            BuiltExecutor::Replay(ex) => ex.label(),
            BuiltExecutor::Replicate(ex) => ex.label(),
            BuiltExecutor::Checkpoint(ex) => ex.label(),
        }
    }

    /// Description of the substrate attempts run on (e.g. `pool(4)`,
    /// `cluster(4)`), independent of the policy wrapped around it.
    pub fn base_label(&self) -> String {
        match self {
            BuiltExecutor::Single(base) => base.base_label(),
            BuiltExecutor::Replay(ex) => ex.base().base_label(),
            BuiltExecutor::Replicate(ex) => ex.base().base_label(),
            BuiltExecutor::Checkpoint(ex) => ex.base().base_label(),
        }
    }

    /// The checkpoint decorator, when this executor is one — the door to
    /// the keyed memoizing surface (`spawn_checkpointed`, snapshot
    /// stats) that the generic launch methods cannot express.
    pub fn checkpoint(&self) -> Option<&super::checkpoint::CheckpointExecutor<E>> {
        match self {
            BuiltExecutor::Checkpoint(ex) => Some(ex),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resilience::vote_majority;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn rt() -> Runtime {
        Runtime::builder().workers(2).build()
    }

    fn replay(n: usize) -> ReplayExecutor<PoolExecutor> {
        ReplayExecutor::new(PoolExecutor::new(&rt()), n)
    }

    fn replicate(n: usize) -> ReplicateExecutor<PoolExecutor> {
        ReplicateExecutor::new(PoolExecutor::new(&rt()), n)
    }

    // -- the existing replay-exhaustion suite, through the decorator ----

    #[test]
    fn replay_decorator_succeeds_first_try() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replay(3).spawn(move || {
            c.fetch_add(1, Ordering::SeqCst);
            7i32
        });
        assert_eq!(f.get(), Ok(7));
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn replay_decorator_retries_until_success() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replay(5).spawn(move || -> TaskResult<i32> {
            if c.fetch_add(1, Ordering::SeqCst) < 2 {
                Err("transient".into())
            } else {
                Ok(99)
            }
        });
        assert_eq!(f.get(), Ok(99));
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn replay_decorator_exhaustion_runs_exactly_n_attempts_for_each_n() {
        for n in 1..=6usize {
            let calls = Arc::new(AtomicUsize::new(0));
            let c = Arc::clone(&calls);
            let f = replay(n).spawn(move || -> TaskResult<i32> {
                c.fetch_add(1, Ordering::SeqCst);
                Err("always".into())
            });
            let err = f.get().unwrap_err();
            match err.as_resilience() {
                Some(ResilienceError::Exhausted { attempts, last }) => {
                    assert_eq!(*attempts, n, "n={n}");
                    assert_eq!(last, &TaskError::App("always".to_string()));
                }
                other => panic!("n={n}: unexpected {other:?}"),
            }
            assert_eq!(calls.load(Ordering::SeqCst), n, "exactly n bodies must run");
        }
    }

    #[test]
    fn replay_decorator_never_exceeds_n_attempts_on_panic() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f: Future<i32> = replay(4).spawn(move || -> i32 {
            c.fetch_add(1, Ordering::SeqCst);
            panic!("always")
        });
        assert!(f.get().is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn replay_decorator_validation_rejection_counts_as_failed_attempt() {
        let n = 4;
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replay(n).spawn_validate(
            |_: &i32| false,
            move || {
                c.fetch_add(1, Ordering::SeqCst);
                1i32
            },
        );
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::Exhausted { attempts, last }) => {
                assert_eq!(*attempts, n);
                assert_eq!(last, &TaskError::ValidationRejected);
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(calls.load(Ordering::SeqCst), n);
    }

    #[test]
    fn replay_decorator_validate_rejects_then_accepts() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replay(5).spawn_validate(
            |v: &usize| *v >= 2,
            move || c.fetch_add(1, Ordering::SeqCst),
        );
        assert_eq!(f.get(), Ok(2));
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn replay_decorator_dataflow_matches_free_function_semantics() {
        let rt = rt();
        let ex = ReplayExecutor::new(PoolExecutor::new(&rt), 4);
        let a = crate::api::async_(&rt, || 10i64);
        let b = crate::api::async_(&rt, || 20i64);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.dataflow(
            move |vals: &[i64]| -> TaskResult<i64> {
                if c.fetch_add(1, Ordering::SeqCst) < 2 {
                    Err("flaky".into())
                } else {
                    Ok(vals.iter().sum())
                }
            },
            vec![a, b],
        );
        assert_eq!(f.get(), Ok(30));
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn replay_decorator_dataflow_does_not_replay_failed_deps() {
        let rt = rt();
        let ex = ReplayExecutor::new(PoolExecutor::new(&rt), 3);
        let bad: Future<i64> =
            crate::api::async_(&rt, || -> TaskResult<i64> { Err("dep dead".into()) });
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.dataflow(
            move |_: &[i64]| -> i64 {
                c.fetch_add(1, Ordering::SeqCst);
                0
            },
            vec![bad],
        );
        match f.get() {
            Err(TaskError::DependencyFailed(_)) => {}
            other => panic!("unexpected: {other:?}"),
        }
        assert_eq!(calls.load(Ordering::SeqCst), 0, "body must never run");
    }

    // -- the existing replicate/validation suite, through the decorator -

    #[test]
    fn replicate_decorator_launches_all_replicas_eagerly() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let rt = rt();
        let ex = ReplicateExecutor::new(PoolExecutor::new(&rt), 4);
        let f = ex.spawn(move || {
            c.fetch_add(1, Ordering::SeqCst);
            1i32
        });
        assert_eq!(f.get(), Ok(1));
        rt.wait_idle();
        assert_eq!(calls.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn replicate_decorator_all_fail_reports_last_error() {
        let f: Future<i32> =
            replicate(3).spawn(|| -> TaskResult<i32> { Err("dead".into()) });
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::AllReplicasFailed { replicas: 3, last }) => {
                assert_eq!(last, &TaskError::App("dead".to_string()));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicate_decorator_validate_none_validates() {
        let f = replicate(3).spawn_validate(|_: &i32| false, || 5i32);
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::ValidationFailed { replicas: 3 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicate_decorator_vote_defeats_silent_minority_corruption() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replicate(3).spawn_vote(vote_majority, move || {
            if c.fetch_add(1, Ordering::SeqCst) == 0 {
                666i64
            } else {
                42i64
            }
        });
        assert_eq!(f.get(), Ok(42));
    }

    #[test]
    fn replicate_decorator_vote_no_consensus() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replicate(3).spawn_vote(vote_majority, move || {
            c.fetch_add(1, Ordering::SeqCst) as i64
        });
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::NoConsensus { candidates: 3 }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn replicate_decorator_vote_validate_combines_filters() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replicate(4).spawn_vote_validate(
            vote_majority,
            |v: &i64| *v < 100,
            move || {
                let i = c.fetch_add(1, Ordering::SeqCst);
                if i == 0 {
                    666i64
                } else {
                    7i64
                }
            },
        );
        assert_eq!(f.get(), Ok(7));
    }

    #[test]
    fn replicate_decorator_dataflow_vote_end_to_end() {
        let rt = rt();
        let ex = ReplicateExecutor::new(PoolExecutor::new(&rt), 3);
        let a = crate::api::async_(&rt, || 10i64);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.dataflow_vote(
            vote_majority,
            move |vals: &[i64]| {
                // One replica silently corrupts its result.
                if c.fetch_add(1, Ordering::SeqCst) == 0 {
                    -1i64
                } else {
                    vals[0] * 2
                }
            },
            vec![a],
        );
        assert_eq!(f.get(), Ok(20));
    }

    #[test]
    fn replicate_decorator_with_replay_recovers_flaky_replicas() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = replicate(2).with_replay(3).spawn(move || -> TaskResult<i64> {
            let i = c.fetch_add(1, Ordering::SeqCst);
            if i % 2 == 0 {
                Err("flaky".into())
            } else {
                Ok(5)
            }
        });
        assert_eq!(f.get(), Ok(5));
    }

    // -- replica teams through the decorator ----------------------------

    #[test]
    fn team_decorator_sheds_loser_work_on_a_serial_pool() {
        // One worker ⇒ replicas run strictly in submission order: the
        // first wins and cancels, so the queued losers' bodies never run.
        let rt = Runtime::builder().workers(1).build();
        let ex = ReplicateExecutor::team(PoolExecutor::new(&rt), 3);
        assert!(ex.is_team());
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.spawn(move || {
            c.fetch_add(1, Ordering::SeqCst);
            5i32
        });
        assert_eq!(f.get(), Ok(5));
        rt.wait_idle();
        assert_eq!(
            calls.load(Ordering::SeqCst),
            1,
            "cancelled losers must not execute their bodies"
        );
    }

    #[test]
    fn team_decorator_survives_failing_replicas() {
        let rt = Runtime::builder().workers(1).build();
        let ex = ReplicateExecutor::team(PoolExecutor::new(&rt), 3);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.spawn(move || -> TaskResult<i32> {
            if c.fetch_add(1, Ordering::SeqCst) == 0 {
                Err("first replica dies".into())
            } else {
                Ok(8)
            }
        });
        assert_eq!(f.get(), Ok(8));
    }

    #[test]
    fn team_decorator_all_fail_reports_team_failure() {
        let rt = rt();
        let ex = ReplicateExecutor::team(PoolExecutor::new(&rt), 3);
        let f: Future<i32> = ex.spawn(|| -> TaskResult<i32> { Err("dead".into()) });
        match f.get().unwrap_err().as_resilience() {
            Some(ResilienceError::AllReplicasFailed { replicas: 3, .. }) => {}
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn team_decorator_validation_gates_the_win() {
        let rt = Runtime::builder().workers(1).build();
        let ex = ReplicateExecutor::team(PoolExecutor::new(&rt), 3);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.spawn_validate(
            |v: &usize| *v >= 1,
            move || c.fetch_add(1, Ordering::SeqCst),
        );
        // Replica 0 computes 0 (rejected); replica 1 computes 1 (wins);
        // replica 2 is cancelled.
        assert_eq!(f.get(), Ok(1));
        rt.wait_idle();
        assert_eq!(calls.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn team_decorator_dataflow_checks_token_after_deps() {
        let rt = Runtime::builder().workers(1).build();
        let ex = ReplicateExecutor::team(PoolExecutor::new(&rt), 3);
        let a = crate::api::async_(&rt, || 4i64);
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.dataflow(
            move |vals: &[i64]| {
                c.fetch_add(1, Ordering::SeqCst);
                vals[0] * 10
            },
            vec![a],
        );
        assert_eq!(f.get(), Ok(40));
        rt.wait_idle();
        assert_eq!(calls.load(Ordering::SeqCst), 1, "losers shed after deps resolved");
    }

    #[test]
    fn team_label_names_the_mode() {
        let rt = rt();
        let ex = ReplicateExecutor::team(PoolExecutor::new(&rt), 3);
        assert_eq!(ex.label(), "team(3) over pool(2)");
        assert!(!ReplicateExecutor::new(PoolExecutor::new(&rt), 3).is_team());
    }

    #[test]
    fn policy_spec_team_and_drain_build_and_describe() {
        let rt = rt();
        assert_eq!(PolicySpec::Team { n: 3 }.label(), "exec_team(3)");
        assert_eq!(PolicySpec::Team { n: 3 }.compute_multiplier(), 3);
        assert_eq!(PolicySpec::Drain.label(), "exec_drain");
        assert_eq!(PolicySpec::Drain.compute_multiplier(), 1);
        assert!(PolicySpec::Drain.routes_alive_only());
        assert!(!PolicySpec::Team { n: 3 }.routes_alive_only());
        assert!(!PolicySpec::Replay { n: 3 }.routes_alive_only());
        let built = PolicySpec::Team { n: 3 }.build(&rt, "test_team_spec", 1);
        match &built {
            BuiltExecutor::Replicate(ex) => assert!(ex.is_team()),
            _ => panic!("team spec must build a team replicate decorator"),
        }
        assert_eq!(built.spawn(|| 2i32).get(), Ok(2));
        assert_eq!(built.label(), "team(3) over pool(2)");
        let drained = PolicySpec::Drain.build(&rt, "test_drain_spec", 1);
        assert!(matches!(drained, BuiltExecutor::Single(_)));
        assert_eq!(drained.spawn(|| 6i32).get(), Ok(6));
    }

    #[test]
    fn pool_executor_is_the_plain_baseline() {
        let rt = rt();
        let ex = PoolExecutor::new(&rt);
        assert_eq!(ex.spawn(|| 5i32).get(), Ok(5));
        // single attempt: a rejected validation surfaces with no retry
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let f = ex.spawn_validate(|_: &i32| false, move || {
            c.fetch_add(1, Ordering::SeqCst);
            1i32
        });
        assert_eq!(f.get(), Err(TaskError::ValidationRejected));
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        assert_eq!(ResilientExecutor::concurrency(&ex), 2);
    }

    // -- adaptive policy ------------------------------------------------

    fn policy(name: &str) -> AdaptivePolicy {
        AdaptivePolicy::new(AdaptiveConfig {
            alpha: 0.5,
            floor: 1,
            ceiling: 6,
            target: 0.01,
            name: name.to_string(),
        })
    }

    #[test]
    fn adaptive_error_spike_raises_budget() {
        let p = policy("test_spike");
        assert_eq!(p.budget(), 1, "quiet policy sits at the floor");
        let mut raised = false;
        for _ in 0..10 {
            p.record(true);
            raised |= p.budget() > 1;
        }
        assert!(raised, "a failure spike must raise the budget");
        assert_eq!(p.budget(), 6, "sustained failures saturate at the ceiling");
        assert_eq!(p.failures(), 10);
        assert_eq!(p.attempts(), 10);
    }

    #[test]
    fn adaptive_quiet_period_decays_budget_back() {
        let p = policy("test_decay");
        for _ in 0..10 {
            p.record(true);
        }
        assert_eq!(p.budget(), 6);
        for _ in 0..20 {
            p.record(false);
        }
        assert_eq!(p.budget(), 1, "quiet period must decay back to the floor");
        assert!(p.error_rate() < 0.01);
    }

    #[test]
    fn adaptive_budget_never_exceeds_ceiling() {
        let p = policy("test_ceiling");
        for i in 0..200 {
            p.record(i % 7 != 0); // heavy but mixed failure pattern
            assert!(p.budget() <= 6, "budget exceeded the ceiling");
            assert!(p.budget() >= 1, "budget fell below the floor");
        }
    }

    #[test]
    fn adaptive_policy_publishes_perfcounters() {
        let p = policy("test_counters");
        p.record(true);
        p.record(false);
        let snap = global().snapshot();
        assert!(snap["/resilience/test_counters/count/attempts"] >= 2);
        assert!(snap["/resilience/test_counters/count/failures"] >= 1);
        assert!(snap.contains_key("/resilience/test_counters/gauge/budget"));
        assert!(snap.contains_key("/resilience/test_counters/gauge/error_rate_ppm"));
    }

    #[test]
    fn adaptive_replay_executor_survives_error_burst() {
        let rt = rt();
        let policy = Arc::new(AdaptivePolicy::new(AdaptiveConfig {
            alpha: 0.5,
            floor: 4,
            ceiling: 8,
            target: 1e-4,
            name: "test_exec".to_string(),
        }));
        let ex = ReplayExecutor::adaptive(PoolExecutor::new(&rt), Arc::clone(&policy));
        assert_eq!(ex.current_budget(), 4);
        // Fail twice then succeed, repeatedly: every launch recovers, and
        // the policy observes a high error rate and raises the budget.
        for _ in 0..10 {
            let calls = Arc::new(AtomicUsize::new(0));
            let c = Arc::clone(&calls);
            let f = ex.spawn(move || -> TaskResult<i32> {
                if c.fetch_add(1, Ordering::SeqCst) < 2 {
                    Err("burst".into())
                } else {
                    Ok(1)
                }
            });
            assert_eq!(f.get(), Ok(1));
        }
        assert!(policy.failures() >= 20);
        assert!(policy.error_rate() > 0.1);
        assert!(ex.current_budget() > 4, "observed errors must raise the budget");
        assert!(ex.current_budget() <= 8);
        assert!(ex.policy().is_some());
    }

    #[test]
    fn policy_spec_builds_and_honors_ceiling() {
        let rt = rt();
        assert_eq!(PolicySpec::Replay { n: 3 }.label(), "exec_replay(3)");
        assert_eq!(PolicySpec::Replicate { n: 2 }.compute_multiplier(), 2);
        assert_eq!(PolicySpec::Adaptive { ceiling: 9 }.compute_multiplier(), 1);
        // A requested ceiling below the suggested floor wins: the built
        // adaptive policy never exceeds what the user asked for.
        let built = PolicySpec::Adaptive { ceiling: 2 }.build(&rt, "test_spec", 5);
        match &built {
            BuiltExecutor::Replay(ex) => {
                assert_eq!(ex.current_budget(), 2);
                assert_eq!(ex.policy().unwrap().ceiling(), 2);
            }
            _ => panic!("adaptive builds a replay decorator"),
        }
        assert_eq!(built.spawn(|| 1i32).get(), Ok(1));
        assert_eq!(built.label(), "replay(adaptive(max 2)) over pool(2)");
    }

    #[test]
    fn policy_spec_adaptive_replicate_builds_replicate_decorator() {
        let rt = rt();
        assert_eq!(
            PolicySpec::AdaptiveReplicate { ceiling: 4 }.label(),
            "exec_adaptive_replicate(max 4)"
        );
        // Quiet-state eager compute is the floor width, not 1.
        assert_eq!(
            PolicySpec::AdaptiveReplicate { ceiling: 4 }.compute_multiplier(),
            ADAPTIVE_REPLICATE_FLOOR
        );
        // A ceiling below the floor wins (degenerates to width 1).
        assert_eq!(PolicySpec::AdaptiveReplicate { ceiling: 1 }.compute_multiplier(), 1);
        let built = PolicySpec::AdaptiveReplicate { ceiling: 4 }.build(&rt, "test_adrep", 5);
        match &built {
            BuiltExecutor::Replicate(ex) => {
                assert_eq!(ex.current_budget(), ADAPTIVE_REPLICATE_FLOOR);
                assert_eq!(ex.policy().unwrap().ceiling(), 4);
            }
            _ => panic!("adaptive_replicate must build a replicate decorator"),
        }
        assert_eq!(built.spawn(|| 9i32).get(), Ok(9));
        assert_eq!(built.label(), "replicate(adaptive(max 4)) over pool(2)");
        assert_eq!(built.base_label(), "pool(2)");
    }

    #[test]
    fn adaptive_replicate_widens_under_observed_failure() {
        let rt = rt();
        let built = PolicySpec::AdaptiveReplicate { ceiling: 6 }.build(&rt, "test_adrep_w", 5);
        let BuiltExecutor::Replicate(ex) = &built else { panic!("wrong decorator") };
        let policy = Arc::clone(ex.policy().unwrap());
        assert_eq!(ex.current_budget(), ADAPTIVE_REPLICATE_FLOOR);
        // A failure burst (fed through the same record path the replicas
        // use) must widen the next launch's fan-out toward the ceiling.
        for _ in 0..20 {
            policy.record(true);
        }
        assert!(ex.current_budget() > ADAPTIVE_REPLICATE_FLOOR);
        assert!(ex.current_budget() <= 6);
        // And a quiet period must narrow it back to the floor.
        for _ in 0..50 {
            policy.record(false);
        }
        assert_eq!(ex.current_budget(), ADAPTIVE_REPLICATE_FLOOR);
    }

    #[test]
    fn policy_spec_checkpoint_builds_passthrough_with_keyed_surface() {
        let rt = rt();
        let spec = PolicySpec::Checkpoint { every: 2, backend: SnapshotBackend::Auto };
        assert_eq!(spec.label(), "exec_checkpoint(2)");
        assert_eq!(
            PolicySpec::Checkpoint { every: 4, backend: SnapshotBackend::Disk }.label(),
            "exec_checkpoint(4,disk)"
        );
        assert_eq!(spec.compute_multiplier(), 1, "checkpointing adds no eager compute");
        let built = spec.build(&rt, "test_spec_ck", 1);
        // Un-keyed surface: single-attempt passthrough.
        assert_eq!(built.spawn(|| 11i32).get(), Ok(11));
        assert_eq!(built.label(), "checkpoint(mem) over pool(2)");
        assert_eq!(built.base_label(), "pool(2)");
        // Keyed surface reachable through the accessor.
        let ck = built.checkpoint().expect("checkpoint spec builds a checkpoint decorator");
        assert_eq!(ck.spawn_checkpointed("k", || vec![1.0f64]).get().unwrap(), vec![1.0]);
        assert_eq!(ck.snapshots().counts().saved, 1);
        assert!(BuiltExecutor::Single(PoolExecutor::new(&rt)).checkpoint().is_none());
    }

    #[test]
    fn single_built_executor_is_the_undecorated_baseline() {
        let rt = rt();
        let built: BuiltExecutor = BuiltExecutor::Single(PoolExecutor::new(&rt));
        assert_eq!(built.spawn(|| 3i32).get(), Ok(3));
        assert_eq!(built.label(), "single over pool(2)");
        assert_eq!(built.base_label(), "pool(2)");
        // One attempt only: a rejected validation surfaces with no retry.
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let a = crate::api::async_(&rt, || 1i64);
        let f = built.dataflow_validate(
            |_: &i64| false,
            move |vals: &[i64]| {
                c.fetch_add(1, Ordering::SeqCst);
                vals[0]
            },
            vec![a],
        );
        assert_eq!(f.get(), Err(TaskError::ValidationRejected));
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn labels_describe_policy_and_substrate() {
        assert_eq!(replay(3).label(), "replay(3) over pool(2)");
        let rt = rt();
        let ad = ReplicateExecutor::adaptive(
            PoolExecutor::new(&rt),
            Arc::new(AdaptivePolicy::named("test_label")),
        );
        assert_eq!(ad.label(), "replicate(adaptive(max 8)) over pool(2)");
    }

    #[test]
    fn policy_spec_parses_every_token_back() {
        let specs = [
            PolicySpec::Replay { n: 3 },
            PolicySpec::Replicate { n: 2 },
            PolicySpec::Team { n: 3 },
            PolicySpec::Drain,
            PolicySpec::Adaptive { ceiling: 10 },
            PolicySpec::AdaptiveReplicate { ceiling: 4 },
            PolicySpec::Checkpoint { every: 2, backend: SnapshotBackend::Auto },
            PolicySpec::Checkpoint { every: 1, backend: SnapshotBackend::Memory },
            PolicySpec::Checkpoint { every: 4, backend: SnapshotBackend::Disk },
            PolicySpec::Checkpoint { every: 3, backend: SnapshotBackend::Agas },
        ];
        for spec in specs {
            assert_eq!(PolicySpec::parse(&spec.token()), Ok(spec), "{}", spec.token());
        }
    }

    #[test]
    fn policy_spec_parse_grammar_and_errors() {
        assert_eq!(PolicySpec::parse("adaptive"), Ok(PolicySpec::Adaptive { ceiling: 10 }));
        assert_eq!(
            PolicySpec::parse("adaptive_replicate"),
            Ok(PolicySpec::AdaptiveReplicate { ceiling: 4 })
        );
        assert_eq!(
            PolicySpec::parse("checkpoint:2:memory"),
            Ok(PolicySpec::Checkpoint { every: 2, backend: SnapshotBackend::Memory })
        );
        assert_eq!(
            PolicySpec::parse("checkpoint:2:auto"),
            Ok(PolicySpec::Checkpoint { every: 2, backend: SnapshotBackend::Auto })
        );
        assert_eq!(PolicySpec::parse("team:4"), Ok(PolicySpec::Team { n: 4 }));
        assert_eq!(PolicySpec::parse("drain"), Ok(PolicySpec::Drain));
        assert_eq!(
            PolicySpec::parse("team:0"),
            Err(PolicyParseError::BadCount { what: "team", got: "0".into() })
        );
        assert_eq!(
            PolicySpec::parse("drain:2"),
            Err(PolicyParseError::UnknownPolicy { spec: "drain:2".into() }),
            "drain takes no count"
        );
        assert_eq!(
            PolicySpec::parse("bogus"),
            Err(PolicyParseError::UnknownPolicy { spec: "bogus".into() })
        );
        assert_eq!(
            PolicySpec::parse("replay:0"),
            Err(PolicyParseError::BadCount { what: "replay", got: "0".into() })
        );
        assert_eq!(
            PolicySpec::parse("replicate:x"),
            Err(PolicyParseError::BadCount { what: "replicate", got: "x".into() })
        );
        assert_eq!(
            PolicySpec::parse("checkpoint:2:tape"),
            Err(PolicyParseError::UnknownBackend { got: "tape".into() })
        );
        assert!(PolicySpec::parse("checkpoint").is_err(), "K is required");
        // The error type renders a usable message (the CLI shows it).
        let msg = PolicySpec::parse("bogus").unwrap_err().to_string();
        assert!(msg.contains("unknown policy spec"), "{msg}");
    }
}
