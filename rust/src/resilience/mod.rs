//! `rhpx::resilience` — the paper's contribution (§IV).
//!
//! Two families of resiliency primitives, each implemented as an
//! extension of the base `async_`/`dataflow` launch API so existing code
//! migrates by changing only the launch call:
//!
//! * **Task Replay** (§IV-A) — the localized analogue of
//!   checkpoint/restart: a failing task is rescheduled up to *n* times
//!   before its error is re-thrown. Variants: plain, and `_validate`
//!   (a user predicate must accept the result).
//! * **Task Replicate** (§IV-B) — *n* instances launched concurrently
//!   (none deferred, unlike Subasi et al.); variants select the first
//!   successful result, the first *validated* result, or run a *vote*
//!   over all (optionally validated) results to defeat silent errors.
//!
//! Plus the paper's future-work extension, implemented here: replay
//! nested inside replicate (`*_replicate_replay`) so each replica
//! individually retries before the consensus step ("finer consensus in
//! case of soft failures").
//!
//! A TeaMPI-style refinement of replicate also lives here:
//! [`ReplicaTeam`] / [`CancelToken`] implement first-result-wins replica
//! teams — the first validated replica resolves the future and the
//! losers retire through a shared cancellation token instead of running
//! to completion (selected as `team:N` through
//! [`executor::PolicySpec`]). See `docs/FAULT_MODEL.md` for the
//! team-cancellation fault row.
//!
//! The second surface over the same machinery lives in [`executor`]:
//! resilient executor *decorators* that make whole launch paths (instead
//! of single call sites) resilient, with an optional adaptive budget
//! tuned from the observed error rate.
//!
//! The third strategy lives in [`checkpoint`]: task-level
//! checkpoint/restart ([`checkpoint::CheckpointExecutor`]), where a
//! failed task restarts from its last validated snapshot — backed by the
//! shared [`crate::checkpoint::store::SnapshotStore`] abstraction with
//! an AGAS-replicated distributed backend
//! ([`checkpoint::AgasSnapshotStore`]). See `docs/ARCHITECTURE.md`
//! ("Choosing a resilience strategy") for when each of the three wins.

pub mod checkpoint;
pub mod executor;
mod replay;
mod replicate;
pub mod vote;

pub use replay::{
    async_replay, async_replay_validate, dataflow_replay, dataflow_replay_validate,
};
pub use replicate::{
    async_replicate, async_replicate_replay, async_replicate_validate, async_replicate_vote,
    async_replicate_vote_validate, dataflow_replicate, dataflow_replicate_replay,
    dataflow_replicate_validate, dataflow_replicate_vote, dataflow_replicate_vote_validate,
};
pub use replicate::{CancelToken, ReplicaTeam, Voter};
pub use vote::{vote_majority, vote_majority_approx, vote_median_f64, vote_plurality};

use crate::error::ResilienceError;

/// Result type returned by every resilient launch.
pub type ResilientResult<T> = Result<T, ResilienceError>;
