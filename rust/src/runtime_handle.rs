//! The `Runtime` facade: owns the scheduler and runtime-wide services.

use std::sync::Arc;

use crate::config::RuntimeConfig;
use crate::scheduler::{Pool, Scheduler, SchedulerStats};

struct Inner {
    scheduler: Scheduler,
    config: RuntimeConfig,
}

/// A running rhpx runtime instance (the analogue of an initialized HPX
/// runtime on one locality). Cheap to clone; the worker threads shut
/// down when the last clone is dropped.
#[derive(Clone)]
pub struct Runtime {
    inner: Arc<Inner>,
}

impl Runtime {
    /// Start building a runtime.
    pub fn builder() -> RuntimeBuilder {
        RuntimeBuilder { config: RuntimeConfig::default() }
    }

    /// Start a runtime from a parsed configuration.
    pub fn from_config(config: RuntimeConfig) -> Self {
        let scheduler = Scheduler::new(config.workers);
        Runtime { inner: Arc::new(Inner { scheduler, config }) }
    }

    /// The scheduler pool (used by the launch APIs).
    pub fn pool(&self) -> &Arc<Pool> {
        self.inner.scheduler.pool()
    }

    /// The scheduler-backed base executor for this runtime — the launcher
    /// the resilience decorators wrap (see
    /// [`crate::resilience::executor`]): wrap the return value in a
    /// `ReplayExecutor`/`ReplicateExecutor` and pass it to
    /// [`crate::async_on`] to make a launch path resilient.
    pub fn executor(&self) -> crate::resilience::executor::PoolExecutor {
        crate::resilience::executor::PoolExecutor::new(self)
    }

    /// Runtime configuration in effect.
    pub fn config(&self) -> &RuntimeConfig {
        &self.inner.config
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.pool().workers()
    }

    /// Block until all currently spawned tasks have finished.
    pub fn wait_idle(&self) {
        self.inner.scheduler.wait_idle();
    }

    /// Scheduler counters (spawned / completed / stolen).
    pub fn stats(&self) -> SchedulerStats {
        self.pool().stats()
    }
}

/// Builder for [`Runtime`].
pub struct RuntimeBuilder {
    config: RuntimeConfig,
}

impl RuntimeBuilder {
    /// Set the number of worker threads (defaults to available
    /// parallelism).
    pub fn workers(mut self, n: usize) -> Self {
        self.config.workers = n.max(1);
        self
    }

    /// Replace the whole configuration.
    pub fn config(mut self, config: RuntimeConfig) -> Self {
        self.config = config;
        self
    }

    pub fn build(self) -> Runtime {
        Runtime::from_config(self.config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults() {
        let rt = Runtime::builder().build();
        assert!(rt.workers() >= 1);
    }

    #[test]
    fn wait_idle_sees_all_tasks() {
        let rt = Runtime::builder().workers(2).build();
        let n = 100;
        let counter = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        for _ in 0..n {
            let c = Arc::clone(&counter);
            crate::api::apply(&rt, move || {
                c.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            });
        }
        rt.wait_idle();
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), n);
        let stats = rt.stats();
        assert_eq!(stats.spawned, stats.completed);
    }
}
