//! Error types for tasks and the resiliency layer.
//!
//! In the paper a "failure" is a manifestation of a failing task: a task
//! that throws an exception, or whose result a user-supplied validation
//! function rejects (§III-B). In Rust we model "throwing" as a task body
//! returning `Err(TaskError)` or panicking (panics are caught at the task
//! boundary and converted into [`TaskError::Panic`]).

use std::fmt;

/// An error produced by a single task execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskError {
    /// The task body returned an application-level error ("threw").
    App(String),
    /// The task body panicked; the payload is the panic message.
    Panic(String),
    /// An error injected by the failure-injection substrate (§V-C).
    Injected { site: &'static str },
    /// The dependencies of a dataflow task failed, so the task never ran.
    DependencyFailed(String),
    /// Executing an AOT compute artifact through PJRT failed.
    Runtime(String),
    /// A user validation function rejected the computed result.
    ValidationRejected,
    /// The task was retired before (or instead of) producing a result
    /// because a sibling replica in its [`ReplicaTeam`] already won the
    /// first-result-wins race. Losers report this instead of a value; the
    /// team treats it as an orderly retirement, not a failure.
    ///
    /// [`ReplicaTeam`]: crate::resilience::ReplicaTeam
    Cancelled,
    /// A resilient launch ultimately failed (replay exhausted, all
    /// replicas failed, ...). Wrapping it in `TaskError` lets resilient
    /// futures flow through `dataflow` dependencies unchanged.
    Resilience(Box<ResilienceError>),
}

impl TaskError {
    /// Short classification tag used in logs and metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            TaskError::App(_) => "app",
            TaskError::Panic(_) => "panic",
            TaskError::Injected { .. } => "injected",
            TaskError::DependencyFailed(_) => "dependency",
            TaskError::Runtime(_) => "runtime",
            TaskError::ValidationRejected => "validation",
            TaskError::Cancelled => "cancelled",
            TaskError::Resilience(_) => "resilience",
        }
    }

    /// The wrapped resilience error, if this failure came from a
    /// resilient launch.
    pub fn as_resilience(&self) -> Option<&ResilienceError> {
        match self {
            TaskError::Resilience(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ResilienceError> for TaskError {
    fn from(e: ResilienceError) -> Self {
        TaskError::Resilience(Box::new(e))
    }
}

impl fmt::Display for TaskError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TaskError::App(m) => write!(f, "task error: {m}"),
            TaskError::Panic(m) => write!(f, "task panicked: {m}"),
            TaskError::Injected { site } => write!(f, "injected failure at {site}"),
            TaskError::DependencyFailed(m) => write!(f, "dependency failed: {m}"),
            TaskError::Runtime(m) => write!(f, "runtime error: {m}"),
            TaskError::ValidationRejected => write!(f, "result failed validation"),
            TaskError::Cancelled => write!(f, "task retired by replica-team cancellation"),
            TaskError::Resilience(e) => write!(f, "resilient launch failed: {e}"),
        }
    }
}

impl std::error::Error for TaskError {}

impl From<String> for TaskError {
    fn from(m: String) -> Self {
        TaskError::App(m)
    }
}

impl From<&str> for TaskError {
    fn from(m: &str) -> Self {
        TaskError::App(m.to_string())
    }
}

/// Errors surfaced by the resiliency APIs (§IV).
///
/// These mirror the exceptions HPX re-throws when a resilient launch
/// ultimately fails: replay exhausts its `n` trials, every replica of a
/// replicated task fails, or finite results are computed but none passes
/// validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResilienceError {
    /// `async_replay`/`dataflow_replay` exceeded the allowed number of
    /// trials; carries the last task error encountered.
    Exhausted { attempts: usize, last: TaskError },
    /// Every replica of a replicated task failed; carries the last error.
    AllReplicasFailed { replicas: usize, last: TaskError },
    /// Replicas produced finite results but none passed the validation
    /// check (paper §IV-B(iv): "an exception is re-thrown").
    ValidationFailed { replicas: usize },
    /// The voting function could not build a consensus from the results.
    NoConsensus { candidates: usize },
}

impl ResilienceError {
    /// The last underlying task error, when one exists.
    pub fn last_task_error(&self) -> Option<&TaskError> {
        match self {
            ResilienceError::Exhausted { last, .. } => Some(last),
            ResilienceError::AllReplicasFailed { last, .. } => Some(last),
            _ => None,
        }
    }
}

impl fmt::Display for ResilienceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ResilienceError::Exhausted { attempts, last } => {
                write!(f, "replay exhausted after {attempts} attempts; last: {last}")
            }
            ResilienceError::AllReplicasFailed { replicas, last } => {
                write!(f, "all {replicas} replicas failed; last: {last}")
            }
            ResilienceError::ValidationFailed { replicas } => {
                write!(f, "no result of {replicas} replicas passed validation")
            }
            ResilienceError::NoConsensus { candidates } => {
                write!(f, "voting failed to reach consensus over {candidates} candidates")
            }
        }
    }
}

impl std::error::Error for ResilienceError {}

/// Convenience alias used throughout the crate for task-result values.
pub type TaskResult<T> = Result<T, TaskError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_error_display_and_kind() {
        let e = TaskError::App("boom".into());
        assert_eq!(e.kind(), "app");
        assert!(e.to_string().contains("boom"));
        let p = TaskError::Panic("oops".into());
        assert_eq!(p.kind(), "panic");
        let i = TaskError::Injected { site: "stencil" };
        assert_eq!(i.kind(), "injected");
        assert!(i.to_string().contains("stencil"));
    }

    #[test]
    fn cancelled_is_its_own_kind() {
        let c = TaskError::Cancelled;
        assert_eq!(c.kind(), "cancelled");
        assert!(c.to_string().contains("replica-team"));
        assert!(c.as_resilience().is_none());
    }

    #[test]
    fn from_str_conversions() {
        let e: TaskError = "bad".into();
        assert_eq!(e, TaskError::App("bad".to_string()));
        let e: TaskError = String::from("worse").into();
        assert_eq!(e, TaskError::App("worse".to_string()));
    }

    #[test]
    fn resilience_error_last() {
        let last = TaskError::App("x".into());
        let e = ResilienceError::Exhausted { attempts: 3, last: last.clone() };
        assert_eq!(e.last_task_error(), Some(&last));
        assert!(e.to_string().contains("3 attempts"));
        let v = ResilienceError::ValidationFailed { replicas: 4 };
        assert_eq!(v.last_task_error(), None);
    }
}
