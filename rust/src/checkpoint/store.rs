//! `SnapshotStore` — the shared snapshot-persistence abstraction.
//!
//! Both checkpointing subsystems sit on this one abstraction so the
//! ablation bench compares like-for-like:
//!
//! * the coordinated global-C/R baseline ([`crate::checkpoint`], the §I
//!   strawman) persists whole-application snapshots through it;
//! * the task-level checkpoint/restart strategy
//!   ([`crate::resilience::checkpoint`]) persists per-task snapshots
//!   through it — same bytes-in/bytes-out contract, different grain.
//!
//! Backends here: [`MemorySnapshotStore`] (lower bound on persistence
//! cost) and [`DiskSnapshotStore`] (models the paper's "persistent
//! storage" with its I/O cost, fsync included). The AGAS-replicated
//! backend — snapshots registered under [`crate::agas::Gid`]s so they
//! survive locality death — lives in
//! [`crate::resilience::checkpoint::AgasSnapshotStore`], next to the
//! cluster machinery it depends on.
//!
//! Paper mapping: §I (the cost model of checkpoint/restart) and the
//! ORNL resilience-design-pattern "checkpoint-recovery" pattern at task
//! scope.

use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::agas::LocalityId;
use crate::error::{TaskError, TaskResult};

/// State that round-trips through a snapshot store.
///
/// The two halves are inverses: `from_bytes(&x.to_bytes())` must
/// reconstruct a value indistinguishable from `x` (the property test in
/// `rust/tests/properties.rs` pins this for the stencil domain state,
/// checksum included).
pub trait SnapshotData: Sized {
    /// Serialize for persistence.
    fn to_bytes(&self) -> Vec<u8>;

    /// Reconstruct from persisted bytes; `None` if the bytes are not a
    /// valid encoding.
    fn from_bytes(bytes: &[u8]) -> Option<Self>;
}

impl SnapshotData for Vec<f64> {
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len() * 8);
        for v in self {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    fn from_bytes(bytes: &[u8]) -> Option<Self> {
        if bytes.len() % 8 != 0 {
            return None;
        }
        Some(
            bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().expect("chunks_exact(8)")))
                .collect(),
        )
    }
}

impl SnapshotData for Vec<Vec<f64>> {
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        for row in self {
            out.extend_from_slice(&(row.len() as u64).to_le_bytes());
            for v in row {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    fn from_bytes(bytes: &[u8]) -> Option<Self> {
        // Lengths come from untrusted persisted bytes: bound every count
        // against the data actually present (and use checked arithmetic)
        // so a corrupted snapshot decodes to `None`, never a panic or an
        // absurd allocation.
        let read_u64 = |at: usize| -> Option<u64> {
            bytes.get(at..at.checked_add(8)?).map(|s| {
                u64::from_le_bytes(s.try_into().expect("8 bytes"))
            })
        };
        let rows = usize::try_from(read_u64(0)?).ok()?;
        if rows > bytes.len() / 8 {
            return None; // each row costs at least its 8-byte header
        }
        let mut out = Vec::with_capacity(rows);
        let mut pos = 8usize;
        for _ in 0..rows {
            let len = usize::try_from(read_u64(pos)?).ok()?;
            pos = pos.checked_add(8)?;
            let end = pos.checked_add(len.checked_mul(8)?)?;
            let row = bytes.get(pos..end)?;
            out.push(Vec::<f64>::from_bytes(row)?);
            pos = end;
        }
        if pos != bytes.len() {
            return None;
        }
        Some(out)
    }
}

/// A keyed store of snapshot bytes.
///
/// Implementations are thread-safe; keys are crate-generated and may
/// contain `/`-free ASCII plus `-`/`_`/`.` (the disk backend sanitizes
/// anything else). The membership hook and loss counter exist for
/// backends with a durability notion tied to cluster membership (the
/// AGAS backend); the local backends never lose anything.
pub trait SnapshotStore: Send + Sync + 'static {
    /// Persist `bytes` under `key`, replacing any previous snapshot.
    fn save(&self, key: &str, bytes: &[u8]) -> TaskResult<()>;

    /// Read a snapshot back; `None` if absent (or irrecoverably lost).
    fn load(&self, key: &str) -> Option<Vec<u8>>;

    /// Whether a readable snapshot exists under `key`.
    fn contains(&self, key: &str) -> bool {
        self.load(key).is_some()
    }

    /// Drop a snapshot; returns true if one existed.
    fn remove(&self, key: &str) -> bool;

    /// Number of stored snapshots.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshots irrecoverably lost so far (backends tied to cluster
    /// membership; local backends return 0).
    fn lost(&self) -> u64 {
        0
    }

    /// Enumerate stored keys (order unspecified). Restart paths scan
    /// this to find journaled work a previous process left behind (the
    /// `rhpx serve` job journal). Backends that cannot enumerate return
    /// an empty list — callers must treat enumeration as best-effort.
    ///
    /// Disk caveat: a fresh instance recovers keys from *file names*,
    /// which are sanitized; enumeration is exact only for keys that
    /// were already filename-safe (ASCII alphanumeric plus `-_.`), which
    /// crate-generated journal keys are.
    fn keys(&self) -> Vec<String> {
        Vec::new()
    }

    /// Membership hook: `loc` was declared dead. Backends homing
    /// replicas on localities react (drop or re-home); local backends
    /// ignore it.
    fn on_locality_killed(&self, loc: LocalityId) {
        let _ = loc;
    }

    /// Human-readable backend description (for reports).
    fn label(&self) -> String;
}

/// In-memory backend: the lower bound on persistence cost (no I/O, no
/// serialization amortization — bytes are stored as handed in).
#[derive(Default)]
pub struct MemorySnapshotStore {
    map: Mutex<HashMap<String, Arc<Vec<u8>>>>,
}

impl MemorySnapshotStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl SnapshotStore for MemorySnapshotStore {
    fn save(&self, key: &str, bytes: &[u8]) -> TaskResult<()> {
        self.map.lock().unwrap().insert(key.to_string(), Arc::new(bytes.to_vec()));
        Ok(())
    }

    fn load(&self, key: &str) -> Option<Vec<u8>> {
        self.map.lock().unwrap().get(key).map(|b| (**b).clone())
    }

    fn contains(&self, key: &str) -> bool {
        self.map.lock().unwrap().contains_key(key)
    }

    fn remove(&self, key: &str) -> bool {
        self.map.lock().unwrap().remove(key).is_some()
    }

    fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    fn keys(&self) -> Vec<String> {
        self.map.lock().unwrap().keys().cloned().collect()
    }

    fn label(&self) -> String {
        "mem".to_string()
    }
}

#[cfg(test)]
thread_local! {
    /// Test hook: force the post-create write/sync path of
    /// [`DiskSnapshotStore::save`] to fail, so the partial-file cleanup
    /// is exercised deterministically (a real mid-write failure needs a
    /// full disk, which a unit test cannot portably arrange).
    pub(crate) static FAIL_DISK_WRITES: std::cell::Cell<bool> =
        const { std::cell::Cell::new(false) };
}

/// A temp-dir path that is unique per call *within* this process (pid +
/// sequence), for disk stores that must not collide across runs or
/// executors in one process.
pub fn unique_temp_dir(prefix: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "{prefix}_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// On-disk backend: one fsynced file per key under `dir`, modeling the
/// global-I/O cost of persistent checkpoint storage.
///
/// An in-memory index caches key → path, but reads fall back to the
/// directory itself, so a fresh process pointed at an existing store
/// directory restores snapshots persisted by an earlier one (the
/// restart path [`crate::checkpoint::CheckpointStore::reload`]
/// documents). [`SnapshotStore::len`] counts only keys this instance
/// has touched.
pub struct DiskSnapshotStore {
    dir: PathBuf,
    index: Mutex<HashMap<String, PathBuf>>,
}

impl DiskSnapshotStore {
    /// Store under `dir` (created if missing; creation failure surfaces
    /// on the first [`DiskSnapshotStore::save`]).
    pub fn new(dir: PathBuf) -> Self {
        let _ = std::fs::create_dir_all(&dir);
        DiskSnapshotStore { dir, index: Mutex::new(HashMap::new()) }
    }

    /// The backing directory.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    fn path_for(&self, key: &str) -> PathBuf {
        let safe: String = key
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        self.dir.join(format!("{safe}.bin"))
    }
}

impl SnapshotStore for DiskSnapshotStore {
    /// Write-then-fsync. A failure *after* the file was created removes
    /// the partially written file before the error surfaces — a
    /// truncated snapshot must never be mistaken for a valid restore
    /// point by a later run scanning the directory.
    fn save(&self, key: &str, bytes: &[u8]) -> TaskResult<()> {
        let path = self.path_for(key);
        let mut f = std::fs::File::create(&path)
            .map_err(|e| TaskError::Runtime(format!("snapshot create {path:?}: {e}")))?;
        let written: std::io::Result<()> = (|| {
            #[cfg(test)]
            if FAIL_DISK_WRITES.with(|h| h.get()) {
                return Err(std::io::Error::other("injected write failure"));
            }
            f.write_all(bytes)?;
            f.sync_all()
        })();
        if let Err(e) = written {
            drop(f);
            let _ = std::fs::remove_file(&path);
            return Err(TaskError::Runtime(format!("snapshot write {path:?}: {e}")));
        }
        self.index.lock().unwrap().insert(key.to_string(), path);
        Ok(())
    }

    fn load(&self, key: &str) -> Option<Vec<u8>> {
        let indexed = self.index.lock().unwrap().get(key).cloned();
        let path = match indexed {
            Some(path) => path,
            // Not written by this instance: probe the directory, so a
            // restarted process restores what a previous one persisted.
            None => self.path_for(key),
        };
        let bytes = std::fs::read(&path).ok()?;
        self.index.lock().unwrap().insert(key.to_string(), path);
        Some(bytes)
    }

    fn contains(&self, key: &str) -> bool {
        self.index.lock().unwrap().contains_key(key) || self.path_for(key).exists()
    }

    fn remove(&self, key: &str) -> bool {
        let indexed = self.index.lock().unwrap().remove(key);
        let path = indexed.unwrap_or_else(|| self.path_for(key));
        std::fs::remove_file(path).is_ok()
    }

    fn len(&self) -> usize {
        self.index.lock().unwrap().len()
    }

    /// Index keys plus on-disk `*.bin` stems, so a fresh instance can
    /// enumerate what a previous process journaled (see the trait-level
    /// sanitization caveat).
    fn keys(&self) -> Vec<String> {
        let mut keys: std::collections::HashSet<String> =
            self.index.lock().unwrap().keys().cloned().collect();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                if let Some(stem) = name.to_str().and_then(|n| n.strip_suffix(".bin")) {
                    keys.insert(stem.to_string());
                }
            }
        }
        keys.into_iter().collect()
    }

    fn label(&self) -> String {
        "disk".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rhpx_store_{name}_{}", std::process::id()))
    }

    #[test]
    fn vec_f64_roundtrip() {
        let v = vec![1.5f64, -2.25, 0.0, f64::MAX];
        assert_eq!(Vec::<f64>::from_bytes(&v.to_bytes()), Some(v));
        assert_eq!(Vec::<f64>::from_bytes(&[0u8; 7]), None, "ragged length rejected");
    }

    #[test]
    fn vec_vec_f64_roundtrip() {
        let v = vec![vec![1.0f64, 2.0], vec![], vec![3.5]];
        assert_eq!(Vec::<Vec<f64>>::from_bytes(&v.to_bytes()), Some(v.clone()));
        // 8 (outer len) + 8+16 (row 0) + 8 (row 1) + 8+8 (row 2)
        assert_eq!(v.to_bytes().len(), 8 + 8 + 16 + 8 + 8 + 8);
        let mut truncated = v.to_bytes();
        truncated.pop();
        assert_eq!(Vec::<Vec<f64>>::from_bytes(&truncated), None);
        // Corrupted counts must decode to None, not panic or allocate:
        // a huge row count…
        assert_eq!(Vec::<Vec<f64>>::from_bytes(&[0xFF; 16]), None);
        // …and a huge row length.
        let mut bad_len = Vec::new();
        bad_len.extend_from_slice(&1u64.to_le_bytes());
        bad_len.extend_from_slice(&u64::MAX.to_le_bytes());
        assert_eq!(Vec::<Vec<f64>>::from_bytes(&bad_len), None);
    }

    #[test]
    fn memory_store_save_load_remove() {
        let s = MemorySnapshotStore::new();
        assert!(s.is_empty());
        s.save("a", &[1, 2, 3]).unwrap();
        s.save("a", &[9]).unwrap(); // overwrite
        assert_eq!(s.load("a"), Some(vec![9]));
        assert!(s.contains("a"));
        assert_eq!(s.len(), 1);
        assert!(s.remove("a"));
        assert!(!s.remove("a"));
        assert_eq!(s.load("a"), None);
        assert_eq!(s.lost(), 0);
    }

    #[test]
    fn disk_store_roundtrips_and_sanitizes_keys() {
        let dir = tmp("roundtrip");
        let s = DiskSnapshotStore::new(dir.clone());
        s.save("ckpt/0:1", &[7, 8]).unwrap();
        assert_eq!(s.load("ckpt/0:1"), Some(vec![7, 8]));
        assert_eq!(s.len(), 1);
        // The file landed under the sanitized name.
        assert!(dir.join("ckpt_0_1.bin").exists());
        assert!(s.remove("ckpt/0:1"));
        assert!(!dir.join("ckpt_0_1.bin").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_store_restores_across_instances_like_a_restart() {
        let dir = tmp("restart");
        let first = DiskSnapshotStore::new(dir.clone());
        first.save("survivor", &[4, 5, 6]).unwrap();
        drop(first);
        // A fresh instance (fresh process, in the restart story) must
        // find the fsynced snapshot on disk.
        let second = DiskSnapshotStore::new(dir.clone());
        assert!(second.contains("survivor"));
        assert_eq!(second.load("survivor"), Some(vec![4, 5, 6]));
        assert!(second.remove("survivor"));
        assert_eq!(second.load("survivor"), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_store_enumerates_keys() {
        let s = MemorySnapshotStore::new();
        s.save("job_1", &[1]).unwrap();
        s.save("job_2", &[2]).unwrap();
        let mut keys = s.keys();
        keys.sort();
        assert_eq!(keys, vec!["job_1", "job_2"]);
    }

    #[test]
    fn disk_store_enumerates_keys_across_instances() {
        let dir = tmp("enumerate");
        let first = DiskSnapshotStore::new(dir.clone());
        first.save("job_1", &[1]).unwrap();
        first.save("job_2", &[2]).unwrap();
        drop(first);
        // A fresh instance (the restart story) recovers the key set from
        // the directory alone.
        let second = DiskSnapshotStore::new(dir.clone());
        let mut keys = second.keys();
        keys.sort();
        assert_eq!(keys, vec!["job_1", "job_2"]);
        // New saves and directory contents merge without duplicates.
        second.save("job_2", &[22]).unwrap();
        second.save("job_3", &[3]).unwrap();
        assert_eq!(second.keys().len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unique_temp_dirs_do_not_collide_within_a_process() {
        let a = unique_temp_dir("rhpx_store_unique");
        let b = unique_temp_dir("rhpx_store_unique");
        assert_ne!(a, b);
    }

    #[test]
    fn disk_store_cleans_up_partial_file_on_write_failure() {
        let dir = tmp("partial");
        let s = DiskSnapshotStore::new(dir.clone());
        FAIL_DISK_WRITES.with(|h| h.set(true));
        let err = s.save("half", &[1; 64]);
        FAIL_DISK_WRITES.with(|h| h.set(false));
        assert!(err.is_err(), "injected write failure must surface");
        assert!(
            !dir.join("half.bin").exists(),
            "partially written snapshot file must be removed"
        );
        assert!(!s.contains("half"), "a failed save must not be indexed");
        // The store still works after the failure.
        s.save("half", &[2, 2]).unwrap();
        assert_eq!(s.load("half"), Some(vec![2, 2]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_store_unwritable_directory_errors_without_stray_files() {
        // A *file* where the store directory should be: every create
        // fails with NotADirectory, for any uid (chmod-based unwritable
        // dirs are bypassed by root, which test environments may be).
        let blocker = tmp("blocker");
        std::fs::write(&blocker, b"not a directory").unwrap();
        let s = DiskSnapshotStore::new(blocker.join("sub"));
        assert!(s.save("k", &[1]).is_err());
        assert!(!s.contains("k"));
        assert_eq!(s.len(), 0);

        // Where permissions *can* be enforced (non-root), also check the
        // classic unwritable-directory case end to end.
        #[cfg(unix)]
        {
            use std::os::unix::fs::PermissionsExt;
            let dir = tmp("readonly");
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o555)).unwrap();
            if std::fs::write(dir.join("probe"), b"x").is_err() {
                let s = DiskSnapshotStore::new(dir.clone());
                assert!(s.save("k", &[1]).is_err(), "unwritable dir must error");
                std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o755)).unwrap();
                assert_eq!(
                    std::fs::read_dir(&dir).unwrap().count(),
                    0,
                    "no partial snapshot files may be left behind"
                );
            }
            let _ = std::fs::set_permissions(&dir, std::fs::Permissions::from_mode(0o755));
            let _ = std::fs::remove_dir_all(&dir);
        }
        let _ = std::fs::remove_file(&blocker);
    }
}
