//! Coordinated Checkpoint/Restart — the baseline the paper argues
//! against (§I). Reproduced here so the ablation benches can put numbers
//! on the comparison (no paper table of its own).
//!
//! "Generating snapshots involves global communication and coordination
//! and is achieved by synchronizing all running processes … On failure
//! detection, the runtime initiates a global rollback to the most recent
//! previously saved checkpoint," aborting and restarting everything.
//!
//! This module implements that scheme over the same task abstractions so
//! the ablation benches (`cargo bench --bench ablations`, `rhpx bench
//! table_ckpt`) can measure task-replay and task-level
//! checkpoint/restart against coordinated-C/R on identical workloads: a
//! [`CheckpointStore`] holds serialized global snapshots, and
//! [`run_with_checkpoints`] drives an iterative application with global
//! barrier + snapshot every `interval` iterations and global rollback on
//! failure.
//!
//! Persistence goes through the shared [`store::SnapshotStore`]
//! abstraction — the same backends (memory, disk, AGAS-replicated) that
//! power the *task-level* strategy in [`crate::resilience::checkpoint`],
//! so the global-vs-task-level ablation differs only in checkpoint
//! grain, never in storage machinery.

pub mod store;

pub use store::{DiskSnapshotStore, MemorySnapshotStore, SnapshotData, SnapshotStore};

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::error::{TaskError, TaskResult};

/// Where snapshots are persisted.
pub enum Storage {
    /// In-memory (lower bound on C/R cost).
    Memory,
    /// On-disk under the given directory (models global I/O cost).
    Disk(PathBuf),
    /// Any shared snapshot backend (e.g. the AGAS-replicated store).
    Backend(Arc<dyn SnapshotStore>),
}

/// A store of global snapshots of an application state `S`.
///
/// The latest snapshot is kept typed in memory (rollback never
/// deserializes on the hot path); every snapshot is also persisted
/// through the configured [`SnapshotStore`] backend, from which
/// [`CheckpointStore::reload`] can round-trip any retained iteration.
pub struct CheckpointStore<S: Clone> {
    backend: Arc<dyn SnapshotStore>,
    /// Drop the previous iteration's serialized bytes after each save
    /// (the in-memory storage mode: rollback only ever needs the latest
    /// snapshot, and a long run must not accumulate every past state).
    prune_old: bool,
    latest: Mutex<Option<(u64, S)>>,
    written: Mutex<u64>,
}

fn iteration_key(iteration: u64) -> String {
    format!("ckpt_{iteration:012}")
}

impl<S: Clone + SnapshotData> CheckpointStore<S> {
    pub fn new(storage: Storage) -> Self {
        let (backend, prune_old): (Arc<dyn SnapshotStore>, bool) = match storage {
            Storage::Memory => (Arc::new(MemorySnapshotStore::new()), true),
            Storage::Disk(dir) => (Arc::new(DiskSnapshotStore::new(dir)), false),
            Storage::Backend(backend) => (backend, false),
        };
        CheckpointStore { backend, prune_old, latest: Mutex::new(None), written: Mutex::new(0) }
    }

    /// Persist a coordinated snapshot taken at `iteration`. On a
    /// persistence failure nothing is retained for `iteration` — the
    /// disk backend removes partially written `ckpt_*.bin` files before
    /// the error surfaces, and the typed rollback copy is only replaced
    /// after the backend accepted the bytes. In-memory storage retains
    /// only the latest snapshot's bytes; disk (and custom backends)
    /// retain the full history for restart/inspection.
    pub fn save(&self, iteration: u64, state: &S) -> TaskResult<()> {
        self.backend.save(&iteration_key(iteration), &state.to_bytes())?;
        let prev = self.latest.lock().unwrap().replace((iteration, state.clone()));
        if self.prune_old {
            if let Some((prev_iter, _)) = prev {
                if prev_iter != iteration {
                    self.backend.remove(&iteration_key(prev_iter));
                }
            }
        }
        *self.written.lock().unwrap() += 1;
        Ok(())
    }

    /// Roll back: return the most recent snapshot (iteration, state).
    pub fn restore(&self) -> Option<(u64, S)> {
        self.latest.lock().unwrap().clone()
    }

    /// Round-trip a snapshot through the persistence backend (restart
    /// path: a fresh process would have no typed copy).
    pub fn reload(&self, iteration: u64) -> Option<S> {
        S::from_bytes(&self.backend.load(&iteration_key(iteration))?)
    }

    /// Number of snapshots persisted.
    pub fn count(&self) -> u64 {
        *self.written.lock().unwrap()
    }

    /// The shared persistence backend.
    pub fn backend(&self) -> &Arc<dyn SnapshotStore> {
        &self.backend
    }
}

/// Outcome of a coordinated-C/R driven run.
#[derive(Debug, Clone)]
pub struct CrReport {
    /// Iterations the application needed (logical progress).
    pub iterations: u64,
    /// Total iterations *executed* including re-execution after rollbacks.
    pub executed: u64,
    /// Number of global rollbacks triggered.
    pub rollbacks: u64,
    /// Number of snapshots taken.
    pub checkpoints: u64,
    /// Iterations of work lost and redone.
    pub redone: u64,
}

/// Run an iterative application under coordinated C/R.
///
/// `step(iter, &mut state)` advances the global state by one iteration
/// and may fail (a failure anywhere is a *global* failure: the whole
/// state rolls back to the last snapshot — this is exactly the cost
/// structure the paper's task replay avoids).
pub fn run_with_checkpoints<S, F>(
    state: &mut S,
    iterations: u64,
    interval: u64,
    store: &CheckpointStore<S>,
    mut step: F,
) -> TaskResult<CrReport>
where
    S: Clone + SnapshotData,
    F: FnMut(u64, &mut S) -> TaskResult<()>,
{
    assert!(interval >= 1);
    let mut iter: u64 = 0;
    let mut executed: u64 = 0;
    let mut rollbacks: u64 = 0;
    let mut redone: u64 = 0;
    // Initial coordinated snapshot (iteration 0).
    store.save(0, state)?;
    while iter < iterations {
        executed += 1;
        match step(iter, state) {
            Ok(()) => {
                iter += 1;
                if iter % interval == 0 && iter < iterations {
                    store.save(iter, state)?;
                }
            }
            Err(_) => {
                // Global rollback + restart from the last snapshot.
                let (snap_iter, snap_state) =
                    store.restore().ok_or(TaskError::App("no checkpoint".into()))?;
                redone += iter - snap_iter;
                iter = snap_iter;
                *state = snap_state;
                rollbacks += 1;
            }
        }
    }
    Ok(CrReport {
        iterations,
        executed,
        rollbacks,
        checkpoints: store.count(),
        redone,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::FaultInjector;

    #[test]
    fn no_failures_no_rollbacks() {
        let store = CheckpointStore::new(Storage::Memory);
        let mut state = vec![0.0f64];
        let rep = run_with_checkpoints(&mut state, 100, 10, &store, |_, s| {
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        assert_eq!(state[0], 100.0);
        assert_eq!(rep.rollbacks, 0);
        assert_eq!(rep.executed, 100);
        assert_eq!(rep.redone, 0);
        // initial + every 10 iters except the final boundary
        assert!(rep.checkpoints >= 10);
    }

    #[test]
    fn failure_rolls_back_whole_state() {
        let store = CheckpointStore::new(Storage::Memory);
        let mut state = vec![0.0f64];
        let mut failed_once = false;
        let rep = run_with_checkpoints(&mut state, 20, 5, &store, |i, s| {
            if i == 12 && !failed_once {
                failed_once = true;
                return Err("crash".into());
            }
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        // Final state is still exactly 20 increments despite the rollback.
        assert_eq!(state[0], 20.0);
        assert_eq!(rep.rollbacks, 1);
        // Rolled back from iter 12 to the snapshot at 10: 2 redone.
        assert_eq!(rep.redone, 2);
        assert_eq!(rep.executed, 20 + 2 + 1); // +1 for the failed attempt
    }

    #[test]
    fn disk_storage_persists_files() {
        let dir = std::env::temp_dir().join(format!("rhpx_ckpt_test_{}", std::process::id()));
        let store = CheckpointStore::new(Storage::Disk(dir.clone()));
        let mut state = vec![1.0f64, 2.0];
        let _ = run_with_checkpoints(&mut state, 10, 2, &store, |_, s| {
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        let files = std::fs::read_dir(&dir).unwrap().count();
        assert!(files >= 4, "expected several checkpoint files, got {files}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_storage_retains_only_the_latest_snapshot_bytes() {
        let store = CheckpointStore::new(Storage::Memory);
        for i in 0..10u64 {
            store.save(i, &vec![i as f64]).unwrap();
        }
        assert_eq!(store.backend().len(), 1, "memory mode must not accumulate history");
        assert_eq!(store.reload(9), Some(vec![9.0]));
        assert_eq!(store.reload(3), None, "older snapshots are pruned");
        assert_eq!(store.count(), 10);
        assert_eq!(store.restore(), Some((9, vec![9.0])));
    }

    #[test]
    fn disk_snapshots_reload_bit_identically() {
        let dir = std::env::temp_dir().join(format!("rhpx_ckpt_reload_{}", std::process::id()));
        let store = CheckpointStore::new(Storage::Disk(dir.clone()));
        let state = vec![vec![1.5f64, -2.0], vec![3.25]];
        store.save(4, &state).unwrap();
        assert_eq!(store.reload(4), Some(state), "restart path must round-trip");
        assert_eq!(store.reload(5), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unwritable_disk_path_errors_and_keeps_no_rollback_state() {
        // The store directory is a regular file: every snapshot create
        // fails regardless of uid (see store.rs for why not chmod).
        let blocker =
            std::env::temp_dir().join(format!("rhpx_ckpt_unwritable_{}", std::process::id()));
        std::fs::write(&blocker, b"not a directory").unwrap();
        let store: CheckpointStore<Vec<f64>> =
            CheckpointStore::new(Storage::Disk(blocker.join("ckpts")));
        let err = store.save(3, &vec![1.0f64]);
        assert!(err.is_err(), "save into an unwritable path must error");
        assert_eq!(store.count(), 0);
        assert!(
            store.restore().is_none(),
            "a failed persist must not install a rollback point"
        );
        let _ = std::fs::remove_file(&blocker);
    }

    #[test]
    fn injected_failures_still_reach_completion() {
        let store = CheckpointStore::new(Storage::Memory);
        let inj = FaultInjector::with_probability(0.10, 99);
        let mut state = vec![0.0f64];
        let rep = run_with_checkpoints(&mut state, 200, 10, &store, |_, s| {
            inj.draw("cr-step")?;
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        assert_eq!(state[0], 200.0, "state must be exact despite rollbacks");
        assert!(rep.rollbacks > 0, "10% failure rate must trigger rollbacks");
        assert!(rep.executed > 200);
    }

    #[test]
    fn vec_vec_snapshot_roundtrip_format() {
        let v = vec![vec![1.0f64, 2.0], vec![3.0]];
        let bytes = v.to_bytes();
        // 8 (outer len) + 8+16 (row 0) + 8+8 (row 1)
        assert_eq!(bytes.len(), 8 + 8 + 16 + 8 + 8);
        assert_eq!(Vec::<Vec<f64>>::from_bytes(&bytes), Some(v));
    }
}
