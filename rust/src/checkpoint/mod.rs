//! Coordinated Checkpoint/Restart — the baseline the paper argues
//! against (§I). Reproduced here so the ablation bench can put numbers
//! on the comparison (no paper table of its own).
//!
//! "Generating snapshots involves global communication and coordination
//! and is achieved by synchronizing all running processes … On failure
//! detection, the runtime initiates a global rollback to the most recent
//! previously saved checkpoint," aborting and restarting everything.
//!
//! This module implements that scheme over the same task abstractions so
//! the ablation bench (`cargo bench --bench ablations`) can measure
//! task-replay vs. coordinated-C/R on identical workloads: a
//! [`CheckpointStore`] holds serialized global snapshots (in memory or on
//! disk, modeling the paper's "persistent storage" with its I/O cost),
//! and [`run_with_checkpoints`] drives an iterative application with
//! global barrier + snapshot every `interval` iterations and global
//! rollback on failure.

use std::io::Write;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::error::{TaskError, TaskResult};

/// Where snapshots are persisted.
pub enum Storage {
    /// In-memory (lower bound on C/R cost).
    Memory,
    /// On-disk under the given directory (models global I/O cost).
    Disk(PathBuf),
}

/// A store of global snapshots of an application state `S`.
pub struct CheckpointStore<S: Clone> {
    storage: Storage,
    latest: Mutex<Option<(u64, S)>>,
    written: Mutex<u64>,
}

impl<S: Clone + Snapshot> CheckpointStore<S> {
    pub fn new(storage: Storage) -> Self {
        if let Storage::Disk(dir) = &storage {
            let _ = std::fs::create_dir_all(dir);
        }
        CheckpointStore { storage, latest: Mutex::new(None), written: Mutex::new(0) }
    }

    /// Persist a coordinated snapshot taken at `iteration`.
    pub fn save(&self, iteration: u64, state: &S) -> TaskResult<()> {
        if let Storage::Disk(dir) = &self.storage {
            let bytes = state.serialize();
            let path = dir.join(format!("ckpt_{iteration:012}.bin"));
            let mut f = std::fs::File::create(&path)
                .map_err(|e| TaskError::Runtime(format!("checkpoint create: {e}")))?;
            f.write_all(&bytes)
                .map_err(|e| TaskError::Runtime(format!("checkpoint write: {e}")))?;
            f.sync_all()
                .map_err(|e| TaskError::Runtime(format!("checkpoint sync: {e}")))?;
        }
        *self.latest.lock().unwrap() = Some((iteration, state.clone()));
        *self.written.lock().unwrap() += 1;
        Ok(())
    }

    /// Roll back: return the most recent snapshot (iteration, state).
    pub fn restore(&self) -> Option<(u64, S)> {
        self.latest.lock().unwrap().clone()
    }

    /// Number of snapshots persisted.
    pub fn count(&self) -> u64 {
        *self.written.lock().unwrap()
    }
}

/// State that can be serialized for disk persistence.
pub trait Snapshot {
    fn serialize(&self) -> Vec<u8>;
}

impl Snapshot for Vec<f64> {
    fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len() * 8);
        for v in self {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }
}

impl Snapshot for Vec<Vec<f64>> {
    fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.len() as u64).to_le_bytes());
        for row in self {
            out.extend_from_slice(&(row.len() as u64).to_le_bytes());
            for v in row {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }
}

/// Outcome of a coordinated-C/R driven run.
#[derive(Debug, Clone)]
pub struct CrReport {
    /// Iterations the application needed (logical progress).
    pub iterations: u64,
    /// Total iterations *executed* including re-execution after rollbacks.
    pub executed: u64,
    /// Number of global rollbacks triggered.
    pub rollbacks: u64,
    /// Number of snapshots taken.
    pub checkpoints: u64,
    /// Iterations of work lost and redone.
    pub redone: u64,
}

/// Run an iterative application under coordinated C/R.
///
/// `step(iter, &mut state)` advances the global state by one iteration
/// and may fail (a failure anywhere is a *global* failure: the whole
/// state rolls back to the last snapshot — this is exactly the cost
/// structure the paper's task replay avoids).
pub fn run_with_checkpoints<S, F>(
    state: &mut S,
    iterations: u64,
    interval: u64,
    store: &CheckpointStore<S>,
    mut step: F,
) -> TaskResult<CrReport>
where
    S: Clone + Snapshot,
    F: FnMut(u64, &mut S) -> TaskResult<()>,
{
    assert!(interval >= 1);
    let mut iter: u64 = 0;
    let mut executed: u64 = 0;
    let mut rollbacks: u64 = 0;
    let mut redone: u64 = 0;
    // Initial coordinated snapshot (iteration 0).
    store.save(0, state)?;
    while iter < iterations {
        executed += 1;
        match step(iter, state) {
            Ok(()) => {
                iter += 1;
                if iter % interval == 0 && iter < iterations {
                    store.save(iter, state)?;
                }
            }
            Err(_) => {
                // Global rollback + restart from the last snapshot.
                let (snap_iter, snap_state) =
                    store.restore().ok_or(TaskError::App("no checkpoint".into()))?;
                redone += iter - snap_iter;
                iter = snap_iter;
                *state = snap_state;
                rollbacks += 1;
            }
        }
    }
    Ok(CrReport {
        iterations,
        executed,
        rollbacks,
        checkpoints: store.count(),
        redone,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::FaultInjector;

    #[test]
    fn no_failures_no_rollbacks() {
        let store = CheckpointStore::new(Storage::Memory);
        let mut state = vec![0.0f64];
        let rep = run_with_checkpoints(&mut state, 100, 10, &store, |_, s| {
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        assert_eq!(state[0], 100.0);
        assert_eq!(rep.rollbacks, 0);
        assert_eq!(rep.executed, 100);
        assert_eq!(rep.redone, 0);
        // initial + every 10 iters except the final boundary
        assert!(rep.checkpoints >= 10);
    }

    #[test]
    fn failure_rolls_back_whole_state() {
        let store = CheckpointStore::new(Storage::Memory);
        let mut state = vec![0.0f64];
        let mut failed_once = false;
        let rep = run_with_checkpoints(&mut state, 20, 5, &store, |i, s| {
            if i == 12 && !failed_once {
                failed_once = true;
                return Err("crash".into());
            }
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        // Final state is still exactly 20 increments despite the rollback.
        assert_eq!(state[0], 20.0);
        assert_eq!(rep.rollbacks, 1);
        // Rolled back from iter 12 to the snapshot at 10: 2 redone.
        assert_eq!(rep.redone, 2);
        assert_eq!(rep.executed, 20 + 2 + 1); // +1 for the failed attempt
    }

    #[test]
    fn disk_storage_persists_files() {
        let dir = std::env::temp_dir().join(format!("rhpx_ckpt_test_{}", std::process::id()));
        let store = CheckpointStore::new(Storage::Disk(dir.clone()));
        let mut state = vec![1.0f64, 2.0];
        let _ = run_with_checkpoints(&mut state, 10, 2, &store, |_, s| {
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        let files = std::fs::read_dir(&dir).unwrap().count();
        assert!(files >= 4, "expected several checkpoint files, got {files}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_failures_still_reach_completion() {
        let store = CheckpointStore::new(Storage::Memory);
        let inj = FaultInjector::with_probability(0.10, 99);
        let mut state = vec![0.0f64];
        let rep = run_with_checkpoints(&mut state, 200, 10, &store, |_, s| {
            inj.draw("cr-step")?;
            s[0] += 1.0;
            Ok(())
        })
        .unwrap();
        assert_eq!(state[0], 200.0, "state must be exact despite rollbacks");
        assert!(rep.rollbacks > 0, "10% failure rate must trigger rollbacks");
        assert!(rep.executed > 200);
    }

    #[test]
    fn vec_vec_snapshot_roundtrip_format() {
        let v = vec![vec![1.0f64, 2.0], vec![3.0]];
        let bytes = v.serialize();
        // 8 (outer len) + 8+16 (row 0) + 8+8 (row 1)
        assert_eq!(bytes.len(), 8 + 8 + 16 + 8 + 8);
    }
}
