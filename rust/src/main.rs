//! `rhpx` — the launcher binary. See `rhpx help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(rhpx::cli::run(&argv));
}
