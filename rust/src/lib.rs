//! # rhpx — software resiliency for asynchronous many-task runtimes
//!
//! A Rust reproduction of *"Implementing Software Resiliency in HPX for
//! Extreme Scale Computing"* (Gupta, Mayo, Lemoine, Kaiser; SAND2020-3975).
//!
//! The crate contains a complete HPX-like AMT substrate — a work-stealing
//! lightweight task [`scheduler`], eager [`future`]s with continuations
//! and `when_all`, channels, an AGAS-style object registry ([`agas`]),
//! and simulated multi-locality distribution ([`distributed`]) — plus the
//! paper's contribution as [`resilience`]: **task replay** and **task
//! replicate** in every variant of Listings 1 and 2, implemented as
//! drop-in extensions of [`async_`](api::async_)/[`dataflow`](api::dataflow).
//!
//! The 1D Lax-Wendroff stencil application of §V-B lives in [`stencil`];
//! its numeric kernel is authored in JAX/Pallas, AOT-lowered to HLO at
//! build time (`make artifacts`), and executed from Rust through PJRT by
//! [`runtime`]. Python never runs on the task path.
//!
//! ```no_run
//! use rhpx::{Runtime, resilience};
//!
//! let rt = Runtime::builder().workers(4).build();
//! let f = resilience::async_replay(&rt, 3, || {
//!     // flaky computation
//!     Ok::<_, rhpx::TaskError>(42)
//! });
//! assert_eq!(f.get().unwrap(), 42);
//! ```

pub mod agas;
pub mod algorithms;
pub mod api;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod distributed;
pub mod error;
pub mod executor;
pub mod failure;
pub mod future;
pub mod harness;
pub mod metrics;
pub mod perfcounters;
pub mod resilience;
pub mod runtime;
mod runtime_handle;
pub mod scheduler;
pub mod stencil;
pub mod testing;
pub mod workload;

pub use api::{apply, async_, dataflow, dataflow_results};
pub use error::{ResilienceError, TaskError, TaskResult};
pub use future::{channel, when_all, when_all_results, Future, Promise};
pub use runtime_handle::{Runtime, RuntimeBuilder};

/// Crate version string.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
