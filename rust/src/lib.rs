//! # rhpx — software resiliency for asynchronous many-task runtimes
//!
//! A Rust reproduction of *"Implementing Software Resiliency in HPX for
//! Extreme Scale Computing"* (Gupta, Mayo, Lemoine, Kaiser; SAND2020-3975).
//!
//! The crate contains a complete HPX-like AMT substrate — a work-stealing
//! lightweight task [`scheduler`], eager [`future`]s with continuations
//! and `when_all`, channels, an AGAS-style object registry ([`agas`]),
//! and simulated multi-locality distribution ([`distributed`]) — plus the
//! paper's contribution as [`resilience`]: **task replay** and **task
//! replicate** in every variant of Listings 1 and 2, implemented as
//! drop-in extensions of [`async_`](api::async_)/[`dataflow`](api::dataflow),
//! and as transparent *executor decorators* ([`resilience::executor`])
//! that make whole launch paths resilient without call-site changes.
//!
//! The 1D Lax-Wendroff stencil application of §V-B lives in [`stencil`];
//! its numeric kernel is authored in JAX/Pallas, AOT-lowered to HLO at
//! build time (`make artifacts`), and executed from Rust through PJRT by
//! [`runtime`]. Python never runs on the task path.
//!
//! ## Paper → module map
//!
//! | Paper section | Reproduced by |
//! |---|---|
//! | §I motivation: C/R rollback vs localized recovery | [`checkpoint`] (the coordinated-C/R baseline + shared [`checkpoint::store`] backends), [`resilience::checkpoint`] (task-level checkpoint/restart with AGAS-replicated snapshots — the middle ground; compared by [`harness::table_ckpt`]) |
//! | §II/§III HPX runtime components (scheduler, futures, AGAS, networking) | [`scheduler`], [`future`], [`agas`], [`distributed`] (active-message layer), [`config`], [`perfcounters`] |
//! | §III-B failure definition (thrown errors, rejected validations) | [`error`] ([`TaskError`], [`ResilienceError`]) |
//! | §IV-A task replay (Listing 1) | [`resilience`] `async_replay*`/`dataflow_replay*` |
//! | §IV-B task replicate (Listing 2), voting, validation | [`resilience`] `async_replicate*`, [`resilience::vote`] |
//! | §V-A artificial workload (Listing 3), Table I, Fig 2 | [`workload`], [`harness::table1`], [`harness::fig2`] |
//! | §V-B dataflow stencil, Table II, Fig 3 | [`stencil`], [`harness::table2`], [`harness::fig3`] |
//! | §V-B distributed: tasks surviving locality death (Fig 4–5 scenario) | [`stencil`] cluster route ([`stencil::StencilParams::cluster`], [`distributed::ClusterSpec`]), [`harness::table_dist`], [`fault_model`] |
//! | §V-C failure injection | [`failure`] (transient errors), [`failure::SilentCorruptor`] / [`failure::SdcInjector`] (silent corruption / bit-flip SDC), [`distributed::FaultSchedule`] (scheduled locality kills) |
//! | Scenario diversity beyond §V-B (fork-join, global reduction, streaming; arXiv 1611.02717, 1710.09074) | [`workloads`] (the `Workload` trait + zoo), [`workloads::engine`] (the generic resilient engine), [`harness::table_zoo`] |
//! | §Future-Work: distributed resiliency, "special executors", replay-in-replicate | [`distributed`], [`resilience::executor`] (decorators + adaptive budgets/width), [`executor`] (algorithm-facing policies), `*_replicate_replay` |
//! | Service-level resilience: detection, containment, recovery for a long-running daemon (arXiv 1611.02717 pattern catalogue) | [`serve`] (`rhpx serve`: framed protocol, admission control, circuit breaker, journaled crash-restart), [`harness::table_serve`] |
//! | Observability: task-lifecycle forensics for every layer above | [`trace`] (lock-free flight recorder, Chrome-trace export, crash-surviving spool), [`harness::table_obs`] |
//!
//! Each harness module's header states exactly which table/figure it
//! regenerates; the bench binaries under `rust/benches/` emit the same
//! data as machine-readable `BENCH_*.json` (see [`metrics::bench_json`]).
//!
//! ## Quickstart
//!
//! ```
//! use rhpx::{Runtime, resilience};
//!
//! let rt = Runtime::builder().workers(4).build();
//! let f = resilience::async_replay(&rt, 3, || {
//!     // flaky computation
//!     Ok::<_, rhpx::TaskError>(42)
//! });
//! assert_eq!(f.get().unwrap(), 42);
//! ```
//!
//! The same task through the executor surface — the call site no longer
//! names a policy; swapping the executor swaps the resiliency:
//!
//! ```
//! use rhpx::resilience::executor::ReplayExecutor;
//! use rhpx::{async_on, Runtime};
//!
//! let rt = Runtime::builder().workers(4).build();
//! let exec = ReplayExecutor::new(rt.executor(), 3);
//! let f = async_on(&exec, || 42i32);
//! assert_eq!(f.get().unwrap(), 42);
//! ```
//!
//! See `docs/ARCHITECTURE.md` in the repository for the full task
//! lifecycle (submit → decorator → scheduler → validate/vote → result)
//! and a worked example of swapping resilient executors into the stencil
//! driver, and [`fault_model`] (also `docs/FAULT_MODEL.md`) for the
//! detect → contain → recover walkthrough of every injectable fault.

pub mod agas;
pub mod algorithms;
pub mod api;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod distributed;
pub mod error;
pub mod executor;
pub mod failure;
#[doc = include_str!("../../docs/FAULT_MODEL.md")]
pub mod fault_model {}
pub mod future;
pub mod harness;
pub mod metrics;
pub mod perfcounters;
pub mod resilience;
pub mod runtime;
mod runtime_handle;
pub mod scheduler;
pub mod serve;
pub mod stencil;
pub mod testing;
pub mod trace;
pub mod workload;
pub mod workloads;

pub use api::{apply, async_, async_on, dataflow, dataflow_on, dataflow_results};
pub use error::{ResilienceError, TaskError, TaskResult};
pub use future::{channel, when_all, when_all_results, Future, Promise};
pub use runtime_handle::{Runtime, RuntimeBuilder};

/// Crate version string.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
