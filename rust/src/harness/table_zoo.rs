//! Bench harness: the workload zoo through one fault model — per-workload
//! overhead vs. survival (the cross-workload generalization of
//! [`table_dist`](super::table_dist), which runs the same experiment for
//! the 1D stencil only).
//!
//! Every registered [`Workload`](crate::workloads::Workload) runs five
//! arms that differ only in substrate, fault schedule, and resilience
//! policy:
//!
//! 1. single-runtime pool, fault-free — the wall-time and checksum
//!    reference the other arms are compared against;
//! 2. cluster, one scheduled kill, no resilience — the negative
//!    control: the failure cone must reach the final wavefront
//!    (survival < 1);
//! 3. cluster, same kill, `replay:3` — retries walk the locality ring
//!    off the corpse;
//! 4. cluster, same kill, `adaptive_replicate:4` — eager fan-out masks
//!    the death;
//! 5. cluster, same kill, `checkpoint:1` (AGAS-replicated snapshots) —
//!    windowed restore + cone repair.
//!
//! Emitted per (workload, policy) cell: wall time, poisoned slots,
//! survival rate, mean recovery latency, re-executed work, overhead vs.
//! the pool reference, and whether the final checksum bit-matched it.
//! The bench binary (`cargo run --release --bin table_zoo`) wraps this
//! as `BENCH_table_zoo.json`.

use crate::metrics::{JsonValue, Stats, Table};
use crate::runtime_handle::Runtime;
use crate::stencil::{ClusterSpec, ExecPolicy, SnapshotBackend};
use crate::workloads::{self, RunParams};

use super::HarnessOpts;

/// Localities in the cluster arms.
const LOCALITIES: usize = 4;
/// Which locality the schedule kills.
const KILL_LOC: usize = 2;

/// One measured (workload, policy) cell of the zoo matrix.
#[derive(Debug, Clone)]
pub struct ZooRow {
    /// Workload registry name (`stencil1d`, `forkjoin`, …).
    pub workload: String,
    /// Resilience policy label (`none` for the control arms).
    pub policy: String,
    /// Scheduled kills that fired.
    pub kills: usize,
    pub wall_secs: f64,
    /// Poisoned final-wavefront slots.
    pub poisoned: u64,
    /// `1 - poisoned / subdomains`.
    pub survival_rate: f64,
    /// Mean kill → recovery drain time, when kills fired.
    pub recovery_latency_secs: Option<f64>,
    /// Percent extra wall time vs. this workload's pool reference arm.
    pub overhead_pct_vs_pool: f64,
    /// Work beyond one execution per DAG node (retries, replicas,
    /// repairs, dead-locality rejections).
    pub tasks_reexecuted: u64,
    /// Final checksum bit-matches the fault-free pool run.
    pub checksum_matches_pool: bool,
}

/// The workload scale shared by every arm: the harness scale is a
/// fraction of "paper scale" (0.01 default), the zoo workloads take a
/// multiplier around 1 — map one onto the other with a floor so smoke
/// runs still have enough layers for the kill to land mid-run.
fn zoo_scale(opts: &HarnessOpts) -> f64 {
    (100.0 * opts.scale).max(1.0)
}

/// The kill schedule shared by the faulty arms: locality [`KILL_LOC`]
/// dies an eighth of the way through the task stream — early enough
/// that most of the run executes degraded, late enough that the
/// round-robin has warmed every locality.
fn kill_spec(total_tasks: usize) -> String {
    format!("{LOCALITIES}:kill={}@{KILL_LOC}", (total_tasks / 8).max(1))
}

/// Run the zoo matrix: every registered workload through all five arms.
/// Each arm repeats `opts.repeats` times; wall time is the mean,
/// survival/checksum come from the last repeat. As in `table_dist`, the
/// recovered-vs-poisoned *outcome* of every arm is deterministic while
/// the control arm's exact poisoned count varies with execution timing.
pub fn run_table_zoo(opts: &HarnessOpts) -> Vec<ZooRow> {
    let wpl = (opts.workers / LOCALITIES).max(1);
    let rt = Runtime::builder().workers(LOCALITIES * wpl).build();
    let scale = zoo_scale(opts);

    let mut rows = Vec::new();
    for (name, _) in workloads::WORKLOADS {
        let w = workloads::by_name(name, scale).expect("registry names resolve");
        let total_tasks: usize = (0..w.layers()).map(|l| w.layer_tasks(l).len()).sum();
        let faulty = kill_spec(total_tasks);

        let arms: Vec<(bool, Option<ExecPolicy>)> = vec![
            (false, None),
            (true, None),
            (true, Some(ExecPolicy::Replay { n: 3 })),
            (true, Some(ExecPolicy::AdaptiveReplicate { ceiling: 4 })),
            (
                true,
                Some(ExecPolicy::Checkpoint { every: 1, backend: SnapshotBackend::Auto }),
            ),
        ];

        // Arm 1 is this workload's reference: remember wall + checksum.
        let mut reference_wall = 0.0f64;
        let mut reference_checksum = 0.0f64;
        let mut first = true;
        for (on_cluster, resilience) in arms {
            let params = RunParams {
                resilience,
                cluster: on_cluster.then(|| {
                    let mut spec = ClusterSpec::parse(&faulty).expect("arm spec parses");
                    spec.workers_per_locality = wpl;
                    spec
                }),
                ..RunParams::default()
            };
            let mut wall = Stats::new();
            let mut last = None;
            for _ in 0..opts.repeats.max(1) {
                let (_, rep) =
                    workloads::run(&rt, w.as_ref(), &params).expect("zoo arm failed to run");
                wall.push(rep.wall_secs);
                last = Some(rep);
            }
            let rep = last.expect("at least one repeat");
            if first {
                reference_wall = wall.mean();
                reference_checksum = rep.final_checksum;
                first = false;
            }
            rows.push(ZooRow {
                workload: name.to_string(),
                policy: resilience.map(|r| r.label()).unwrap_or_else(|| "none".into()),
                kills: rep.kills_applied,
                wall_secs: wall.mean(),
                poisoned: rep.launch_errors,
                survival_rate: rep.survival_rate(),
                recovery_latency_secs: rep.recovery_latency_secs,
                overhead_pct_vs_pool: 100.0 * (wall.mean() - reference_wall)
                    / reference_wall.max(f64::MIN_POSITIVE),
                tasks_reexecuted: rep.tasks_reexecuted,
                checksum_matches_pool: rep.final_checksum == reference_checksum,
            });
        }
    }
    rows
}

/// Render the rows as the printable harness table.
pub fn to_table(rows: &[ZooRow]) -> Table {
    let mut t = Table::new(
        "Table-Zoo: workload zoo under one fault model (overhead vs survival)",
        &[
            "workload", "policy", "kills", "wall_s", "poisoned", "survival_pct",
            "recovery_ms", "overhead_pct", "reexec", "checksum_ok",
        ],
    );
    for r in rows {
        t.add([
            r.workload.clone(),
            r.policy.clone(),
            r.kills.to_string(),
            format!("{:.3}", r.wall_secs),
            r.poisoned.to_string(),
            format!("{:.1}", 100.0 * r.survival_rate),
            r.recovery_latency_secs
                .map(|s| format!("{:.2}", s * 1e3))
                .unwrap_or_else(|| "-".into()),
            format!("{:+.1}", r.overhead_pct_vs_pool),
            r.tasks_reexecuted.to_string(),
            r.checksum_matches_pool.to_string(),
        ]);
    }
    t
}

/// The machine-readable payload for `BENCH_table_zoo.json`: explicit
/// typed fields per cell plus the rendered table for human diffing.
pub fn to_json(rows: &[ZooRow]) -> JsonValue {
    JsonValue::obj([
        (
            "rows".to_string(),
            JsonValue::Arr(
                rows.iter()
                    .map(|r| {
                        JsonValue::obj([
                            ("workload".to_string(), JsonValue::from(r.workload.clone())),
                            ("policy".to_string(), JsonValue::from(r.policy.clone())),
                            ("kills".to_string(), JsonValue::from(r.kills)),
                            ("wall_secs".to_string(), JsonValue::from(r.wall_secs)),
                            ("poisoned".to_string(), JsonValue::from(r.poisoned)),
                            (
                                "survival_rate".to_string(),
                                JsonValue::from(r.survival_rate),
                            ),
                            (
                                "recovery_latency_secs".to_string(),
                                r.recovery_latency_secs
                                    .map(JsonValue::from)
                                    .unwrap_or(JsonValue::Null),
                            ),
                            (
                                "overhead_pct_vs_pool".to_string(),
                                JsonValue::from(r.overhead_pct_vs_pool),
                            ),
                            (
                                "tasks_reexecuted".to_string(),
                                JsonValue::from(r.tasks_reexecuted),
                            ),
                            (
                                "checksum_matches_pool".to_string(),
                                JsonValue::from(r.checksum_matches_pool),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("table".to_string(), to_table(rows).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_zoo_smoke_tells_the_survival_story_for_every_workload() {
        let opts = HarnessOpts { scale: 0.01, repeats: 1, workers: 2, ..Default::default() };
        let rows = run_table_zoo(&opts);
        assert_eq!(rows.len(), workloads::WORKLOADS.len() * 5);

        for (i, (name, _)) in workloads::WORKLOADS.iter().enumerate() {
            let cells = &rows[i * 5..(i + 1) * 5];
            assert!(cells.iter().all(|r| r.workload == *name));

            // Reference arm: fault-free pool, everything survives.
            assert_eq!(cells[0].policy, "none");
            assert_eq!(cells[0].kills, 0);
            assert_eq!(cells[0].survival_rate, 1.0, "{name} reference");
            assert!(cells[0].checksum_matches_pool);

            // Negative control: the unrecovered kill must poison slots.
            assert_eq!(cells[1].kills, 1, "{name} control");
            assert!(cells[1].poisoned > 0, "{name}: kill without resilience must poison");
            assert!(cells[1].survival_rate < 1.0, "{name} control");

            // Every resilient arm fully recovers, bit-identical.
            for r in &cells[2..] {
                assert_eq!(r.kills, 1, "{name}/{}", r.policy);
                assert_eq!(r.poisoned, 0, "{name}/{} must recover", r.policy);
                assert_eq!(r.survival_rate, 1.0, "{name}/{}", r.policy);
                assert!(
                    r.checksum_matches_pool,
                    "{name}/{} diverged from the pool reference",
                    r.policy
                );
                assert!(r.recovery_latency_secs.is_some(), "{name}/{}", r.policy);
            }
        }

        let json = to_json(&rows).render();
        assert!(json.contains(r#""workload":"forkjoin""#), "{json}");
        assert!(json.contains(r#""policy":"exec_checkpoint(1)""#), "{json}");
        let t = to_table(&rows);
        assert_eq!(t.to_csv().lines().count(), 1 + rows.len(), "header + all cells");
    }
}
