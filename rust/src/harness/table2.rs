//! Table II: 1D stencil execution time with no failures.
//!
//! Paper columns: Pure Dataflow / Replay without checksums / Replay with
//! checksums / Replicate without checksums; rows: case A (128 × 16000)
//! and case B (256 × 8000), 8192 iterations × 128 steps.

use crate::metrics::{fmt_secs, Stats, Table};
use crate::runtime_handle::Runtime;
use crate::stencil::{run, Mode, StencilParams};

use super::{HarnessOpts, KernelBackend};

/// The four Table II configurations.
pub fn table2_modes(n: usize) -> Vec<Mode> {
    vec![
        Mode::Pure,
        Mode::Replay { n },
        Mode::ReplayChecksum { n },
        Mode::Replicate { n },
    ]
}

/// Run Table II. `backend` selects the kernel (native Rust or the PJRT
/// artifact, resolved per case geometry); the paper's relative overheads
/// are a property of the runtime, not the kernel, so both backends
/// reproduce the shape.
pub fn run_table2(opts: &HarnessOpts, backend: &KernelBackend, replicas: usize) -> Table {
    let mut table = Table::new(
        &format!(
            "Table II: 1D stencil wall time (s), no failures, scale {} of paper geometry",
            opts.scale
        ),
        &["case", "pure_dataflow", "replay", "replay_checksum", "replicate"],
    );
    let rt = Runtime::builder().workers(opts.workers).build();

    for (label, base) in cases(opts.scale) {
        let case_backend = backend.for_case(&base).expect("artifact for case geometry");
        // Warmup: compile PJRT executables on every worker before timing.
        let warm = StencilParams { iterations: 2, backend: case_backend.clone(), ..base.clone() };
        run(&rt, &warm).expect("warmup failed");
        let mut cells = vec![label.to_string()];
        for mode in table2_modes(replicas) {
            let params = StencilParams { mode, backend: case_backend.clone(), ..base.clone() };
            let mut s = Stats::new();
            for _ in 0..opts.repeats {
                let (_, rep) = run(&rt, &params).expect("stencil run failed");
                assert_eq!(rep.launch_errors, 0);
                s.push(rep.wall_secs);
            }
            cells.push(fmt_secs(s.mean()));
        }
        table.add_row(&cells);
    }
    table
}

/// Case A and B geometries scaled to the harness budget. Scaling reduces
/// the iteration count and the subdomain size while keeping the paper's
/// task *structure* (many more tasks than subdomains, 128 ghost steps at
/// full scale, proportionally fewer when scaled).
pub fn cases(scale: f64) -> Vec<(&'static str, StencilParams)> {
    if scale >= 1.0 {
        vec![
            ("case_A", StencilParams::case_a(1.0)),
            ("case_B", StencilParams::case_b(1.0)),
        ]
    } else {
        // Scaled-down: keep the A:B shape (A = fewer, larger subdomains;
        // B = 2x subdomains at half size => 2x tasks).
        let iters = ((8192.0 * scale) as usize).clamp(4, 8192);
        let a = StencilParams {
            n_sub: 16,
            nx: 1000,
            iterations: iters,
            steps: 16,
            courant: 0.9,
            ..StencilParams::tiny()
        };
        let b = StencilParams {
            n_sub: 32,
            nx: 500,
            iterations: iters,
            steps: 16,
            courant: 0.9,
            seed: 0xB,
            ..StencilParams::tiny()
        };
        vec![("case_A(scaled)", a), ("case_B(scaled)", b)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_smoke_native() {
        let opts = HarnessOpts { scale: 0.001, repeats: 1, workers: 2, ..Default::default() };
        let t = run_table2(&opts, &KernelBackend::Native, 3);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3, "{csv}");
    }

    #[test]
    fn scaled_cases_preserve_a_b_shape() {
        let cs = cases(0.01);
        assert_eq!(cs[1].1.n_sub, 2 * cs[0].1.n_sub);
        assert_eq!(cs[0].1.nx, 2 * cs[1].1.nx);
    }
}
