//! Bench harness: the distributed fault-surviving stencil (the paper's
//! §V-B headline scenario, Fig 4–5 — "task survives locality death").
//!
//! One stencil geometry is run through eight arms that differ only in
//! substrate, fault schedule, and resilience policy:
//!
//! 1. single-runtime pool, fault-free — the wall-time and checksum
//!    reference every other arm is compared against;
//! 2. cluster, fault-free, no resilience — the pure cost of
//!    distribution (active messages + per-locality pools);
//! 3. cluster, one scheduled kill, no resilience — the negative
//!    control: the failure cone must reach the final wavefront
//!    (survival < 1);
//! 4. cluster, same kill, `drain` — no decorator: live-only placement
//!    plus lineage re-materialization of the corpse's queued tasks
//!    (survival = 1, recovery latency is the direct drain measure);
//! 5. cluster, same kill, `replay:3` — retries walk the locality ring
//!    off the corpse (survival = 1, checksum matches the reference);
//! 6. cluster, same kill, `replicate:3` — eager run-to-completion
//!    replicas mask the death (the overhead baseline for the teams);
//! 7. cluster, same kill, `team:3` — first-result-wins replica teams:
//!    same fan-out, but losers retire through the shared cancel token,
//!    so team overhead must not exceed replicate overhead;
//! 8. cluster, same kill, `adaptive_replicate:4` — eager fan-out masks
//!    the death and widens under the observed failures (survival = 1).
//!
//! Emitted per arm: wall time, poisoned subdomains, survival rate, mean
//! recovery latency (kill → next window barrier), overhead vs. the
//! single-runtime reference, and whether the checksum matched it. The
//! bench binary (`cargo run --release --bin table_dist`) wraps this as
//! `BENCH_table_dist.json`.

use crate::metrics::{JsonValue, Stats, Table};
use crate::runtime_handle::Runtime;
use crate::stencil::{run, ClusterSpec, ExecPolicy, StencilParams};

use super::HarnessOpts;

/// Localities in the cluster arms.
const LOCALITIES: usize = 4;
/// Which locality the schedule kills.
const KILL_LOC: usize = 2;

/// One measured arm of the survival experiment.
#[derive(Debug, Clone)]
pub struct DistRow {
    /// Substrate: `pool(N)` or `cluster(N)`.
    pub route: String,
    /// Resilience policy label (`none` for the undecorated arms).
    pub policy: String,
    /// Scheduled kills that fired.
    pub kills: usize,
    pub wall_secs: f64,
    /// Poisoned final-wavefront subdomains.
    pub poisoned: u64,
    /// `1 - poisoned / subdomains`.
    pub survival_rate: f64,
    /// Mean kill → next-window-barrier drain time, when kills fired.
    pub recovery_latency_secs: Option<f64>,
    /// Percent extra wall time vs. the single-runtime reference arm.
    pub overhead_pct_vs_pool: f64,
    /// Final checksum bit-matches the fault-free single-runtime run.
    pub checksum_matches_pool: bool,
}

/// The geometry shared by every arm: tiny subdomain shape, iteration
/// count scaled from the harness scale (`opts.scale` 0.01 → 10
/// iterations, the floor).
fn params(opts: &HarnessOpts) -> StencilParams {
    StencilParams {
        iterations: ((1000.0 * opts.scale) as usize).max(10),
        ..StencilParams::tiny()
    }
}

/// The kill schedule shared by the faulty arms: locality [`KILL_LOC`]
/// dies an eighth of the way through the task stream — early enough
/// that most of the run executes degraded, late enough that the
/// round-robin has warmed every locality.
fn kill_spec(p: &StencilParams) -> String {
    format!("{LOCALITIES}:kill={}@{KILL_LOC}", (p.total_tasks() / 8).max(1))
}

/// Run the eight-arm experiment. Each arm repeats `opts.repeats` times;
/// wall time is the mean, survival/checksum come from the last repeat.
/// The recovered-vs-poisoned outcome of every arm is deterministic; the
/// control arm's exact poisoned *count* varies with execution timing
/// (tasks in flight when the kill fires execute asynchronously), which
/// is why the row records the survival story, not a poisoned-count
/// baseline to diff against.
///
/// Worker parity: the cluster arms get `opts.workers` spread across the
/// localities, and the pool reference runs on that same total
/// (`localities × workers_per_locality`), so `overhead_pct_vs_pool`
/// measures distribution cost (active messages, per-locality pools) at
/// equal parallelism rather than a thread-count drop.
pub fn run_table_dist(opts: &HarnessOpts) -> Vec<DistRow> {
    let wpl = (opts.workers / LOCALITIES).max(1);
    let rt = Runtime::builder().workers(LOCALITIES * wpl).build();
    let base = params(opts);
    let faulty = kill_spec(&base);
    let fault_free = format!("{LOCALITIES}");

    // Arm 1 is the reference: measure it first, remember its checksum.
    let mut reference_wall = 0.0f64;
    let mut reference_checksum = 0.0f64;

    let arms: Vec<(Option<&str>, Option<ExecPolicy>)> = vec![
        (None, None),
        (Some(&fault_free), None),
        (Some(&faulty), None),
        (Some(&faulty), Some(ExecPolicy::Drain)),
        (Some(&faulty), Some(ExecPolicy::Replay { n: 3 })),
        (Some(&faulty), Some(ExecPolicy::Replicate { n: 3 })),
        (Some(&faulty), Some(ExecPolicy::Team { n: 3 })),
        (Some(&faulty), Some(ExecPolicy::AdaptiveReplicate { ceiling: 4 })),
    ];

    let mut rows = Vec::with_capacity(arms.len());
    for (cluster, resilience) in arms {
        let p = StencilParams {
            cluster: cluster.map(|s| {
                let mut spec = ClusterSpec::parse(s).expect("arm spec parses");
                spec.workers_per_locality = wpl;
                spec
            }),
            resilience,
            ..base.clone()
        };
        let mut wall = Stats::new();
        let mut last = None;
        for _ in 0..opts.repeats.max(1) {
            let (_, rep) = run(&rt, &p).expect("table_dist arm failed to run");
            wall.push(rep.wall_secs);
            last = Some(rep);
        }
        let rep = last.expect("at least one repeat");
        if rows.is_empty() {
            reference_wall = wall.mean();
            reference_checksum = rep.final_checksum;
        }
        rows.push(DistRow {
            route: rep.launcher.clone(),
            policy: resilience.map(|r| r.label()).unwrap_or_else(|| "none".into()),
            kills: rep.kills_applied,
            wall_secs: wall.mean(),
            poisoned: rep.launch_errors,
            survival_rate: rep.survival_rate(),
            recovery_latency_secs: rep.recovery_latency_secs,
            overhead_pct_vs_pool: 100.0 * (wall.mean() - reference_wall)
                / reference_wall.max(f64::MIN_POSITIVE),
            checksum_matches_pool: rep.final_checksum == reference_checksum,
        });
    }
    rows
}

/// Render the rows as the printable harness table.
pub fn to_table(rows: &[DistRow]) -> Table {
    let mut t = Table::new(
        "Table-Dist: stencil survival under locality death",
        &[
            "route", "policy", "kills", "wall_s", "poisoned", "survival_pct",
            "recovery_ms", "overhead_pct", "checksum_ok",
        ],
    );
    for r in rows {
        t.add([
            r.route.clone(),
            r.policy.clone(),
            r.kills.to_string(),
            format!("{:.3}", r.wall_secs),
            r.poisoned.to_string(),
            format!("{:.1}", 100.0 * r.survival_rate),
            r.recovery_latency_secs
                .map(|s| format!("{:.2}", s * 1e3))
                .unwrap_or_else(|| "-".into()),
            format!("{:+.1}", r.overhead_pct_vs_pool),
            r.checksum_matches_pool.to_string(),
        ]);
    }
    t
}

/// The machine-readable payload for `BENCH_table_dist.json`: explicit
/// typed fields per arm (survival rate, recovery latency, overhead)
/// plus the rendered table for human diffing.
pub fn to_json(rows: &[DistRow]) -> JsonValue {
    JsonValue::obj([
        (
            "rows".to_string(),
            JsonValue::Arr(
                rows.iter()
                    .map(|r| {
                        JsonValue::obj([
                            ("route".to_string(), JsonValue::from(r.route.clone())),
                            ("policy".to_string(), JsonValue::from(r.policy.clone())),
                            ("kills".to_string(), JsonValue::from(r.kills)),
                            ("wall_secs".to_string(), JsonValue::from(r.wall_secs)),
                            ("poisoned".to_string(), JsonValue::from(r.poisoned)),
                            (
                                "survival_rate".to_string(),
                                JsonValue::from(r.survival_rate),
                            ),
                            (
                                "recovery_latency_secs".to_string(),
                                r.recovery_latency_secs
                                    .map(JsonValue::from)
                                    .unwrap_or(JsonValue::Null),
                            ),
                            (
                                "overhead_pct_vs_pool".to_string(),
                                JsonValue::from(r.overhead_pct_vs_pool),
                            ),
                            (
                                "checksum_matches_pool".to_string(),
                                JsonValue::from(r.checksum_matches_pool),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("table".to_string(), to_table(rows).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_dist_smoke_demonstrates_the_survival_story() {
        let opts = HarnessOpts { scale: 0.01, repeats: 1, workers: 2, ..Default::default() };
        let rows = run_table_dist(&opts);
        assert_eq!(rows.len(), 8);

        // Reference and fault-free cluster arms: everything survives and
        // matches.
        assert!(rows[0].route.starts_with("pool("));
        assert_eq!(rows[0].survival_rate, 1.0);
        assert!(rows[1].route.starts_with("cluster("));
        assert_eq!(rows[1].poisoned, 0);
        assert!(rows[1].checksum_matches_pool, "fault-free cluster must match pool");

        // Negative control: the kill with no resilience poisons
        // subdomains.
        assert_eq!(rows[2].kills, 1);
        assert!(rows[2].poisoned > 0, "unrecovered kill must poison subdomains");
        assert!(rows[2].survival_rate < 1.0);

        // Every resilient arm (drain, replay, replicate, team, adaptive
        // replicate) fully recovers and reproduces the reference
        // checksum.
        for r in &rows[3..] {
            assert_eq!(r.kills, 1, "{}", r.policy);
            assert_eq!(r.poisoned, 0, "{} must recover every subdomain", r.policy);
            assert_eq!(r.survival_rate, 1.0);
            assert!(r.checksum_matches_pool, "{} diverged from reference", r.policy);
            assert!(r.recovery_latency_secs.is_some());
        }

        // First-result-wins teams shed loser work that replicate runs to
        // completion, so the team arm must not cost more wall time than
        // the replicate arm at the same fan-out (25% tolerance: one
        // smoke repeat at tiny scale is noisy).
        let replicate = &rows[5];
        let team = &rows[6];
        assert_eq!(replicate.policy, "exec_replicate(3)");
        assert_eq!(team.policy, "exec_team(3)");
        assert!(
            team.wall_secs <= replicate.wall_secs * 1.25,
            "team:3 ({:.4}s) must not exceed replicate:3 ({:.4}s) by >25%",
            team.wall_secs,
            replicate.wall_secs,
        );

        let json = to_json(&rows).render();
        assert!(json.contains(r#""survival_rate":1"#), "{json}");
        assert!(json.contains(r#""policy":"exec_replay(3)""#), "{json}");
        assert!(json.contains(r#""policy":"exec_team(3)""#), "{json}");
        assert!(json.contains(r#""policy":"exec_drain""#), "{json}");
        let t = to_table(&rows);
        assert_eq!(t.to_csv().lines().count(), 9, "header + 8 arms");
    }
}
