//! Benchmark harnesses — one per paper table/figure (DESIGN.md §3).
//!
//! Shared between the `cargo bench` targets (`rust/benches/*.rs`) and the
//! `rhpx bench` CLI subcommand, so a result can always be regenerated
//! both ways. Each harness prints the same rows/series the paper
//! reports and can emit CSV for the plotting scripts.

pub mod fig2;
pub mod fig3;
pub mod table1;
pub mod table2;
pub mod table_ckpt;
pub mod table_dist;
pub mod table_obs;
pub mod table_proc;
pub mod table_serve;
pub mod table_zoo;

/// The bench registry: every `rhpx bench <mode>` the CLI accepts, with
/// what it regenerates. `rhpx bench --list` prints exactly this list;
/// the CLI dispatch, Makefile `BENCHES`, and the CI bench-smoke loop
/// must name the same set (the CLI test pins the registry contents so
/// an addition to either side forces the other to follow).
pub const BENCH_MODES: &[(&str, &str)] = &[
    ("table1", "Table I — resiliency API overheads (free functions)"),
    ("table1_exec", "Table I-E — the same workload through the executor decorators"),
    ("fig2", "Fig 2 — overhead vs error rate sweep"),
    ("table2", "Table II — stencil wall time per resilient variant"),
    ("fig3", "Fig 3 — stencil under swept error rates"),
    ("table_dist", "distributed stencil survival under locality death"),
    (
        "table_ckpt",
        "checkpoint/restart vs replay vs global C/R — re-executed work, snapshot bytes, \
         recovery latency",
    ),
    (
        "table_zoo",
        "workload zoo under one fault model — per-workload overhead vs survival",
    ),
    (
        "table_serve",
        "rhpx serve under sustained load — throughput/latency, overload shedding, \
         crash-restart recovery",
    ),
    (
        "table_proc",
        "process-backed localities — SIGKILL survival, heartbeat detection and \
         recovery latency",
    ),
    (
        "table_obs",
        "flight-recorder overhead — ns/task at trace-off/on/on+export across the \
         200 µs grain boundary",
    ),
];

use crate::error::TaskResult;
use crate::metrics::Table;
use crate::runtime::ArtifactStore;
use crate::stencil::{Backend, StencilParams};

/// Kernel selection for stencil harnesses. `Pjrt` resolves the artifact
/// *per case geometry* (each (nx, steps) pair has its own AOT module).
pub enum KernelBackend {
    Native,
    Pjrt(ArtifactStore),
}

impl KernelBackend {
    /// Resolve the concrete backend for one case's geometry.
    pub fn for_case(&self, params: &StencilParams) -> TaskResult<Backend> {
        match self {
            KernelBackend::Native => Ok(Backend::Native),
            KernelBackend::Pjrt(store) => Backend::pjrt(store, params.nx, params.steps),
        }
    }
}

/// Common scale/IO options for a harness run.
#[derive(Debug, Clone)]
pub struct HarnessOpts {
    /// Fraction of the paper's full workload (1.0 = paper scale).
    pub scale: f64,
    /// Repetitions per cell (paper: 10; scaled default: 3).
    pub repeats: usize,
    /// Also emit CSV to this path.
    pub csv: Option<String>,
    /// Worker threads for the runtime under test.
    pub workers: usize,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        HarnessOpts {
            scale: 0.01,
            repeats: 3,
            csv: None,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }
}

/// Print a harness table and optionally write its CSV.
pub fn emit(table: &Table, opts: &HarnessOpts) {
    print!("{}", table.render());
    if let Some(path) = &opts.csv {
        if let Err(e) = std::fs::write(path, table.to_csv()) {
            eprintln!("warning: failed to write {path}: {e}");
        } else {
            println!("(csv written to {path})");
        }
    }
}
