//! Fig 3: 1D stencil percentage extra execution time vs. probability of
//! error occurrence, cases A and B.
//!
//! Series per case: replay without checksums and replay with checksums
//! (the paper's 5.9%/6.9% at case A and 8.5%/9.6% at case B for the
//! largest error rates).

use crate::metrics::{Stats, Table};
use crate::runtime_handle::Runtime;
use crate::stencil::{run, Mode, StencilParams};

use super::table2::cases;
use super::{HarnessOpts, KernelBackend};

/// Error probabilities swept (percent).
pub fn default_probabilities() -> Vec<f64> {
    vec![0.0, 1.0, 2.0, 5.0]
}

/// Run Fig 3 for both cases; overhead is % extra wall time over the
/// pure-dataflow zero-error baseline of the same case.
pub fn run_fig3(
    opts: &HarnessOpts,
    backend: &KernelBackend,
    probs_pct: &[f64],
    replays: usize,
) -> Table {
    let rt = Runtime::builder().workers(opts.workers).build();
    let mut table = Table::new(
        "Fig 3: stencil % extra execution time vs error probability",
        &["case", "error_prob_pct", "replay_pct", "replay_checksum_pct", "injected"],
    );

    for (label, base) in cases(opts.scale) {
        let case_backend = backend.for_case(&base).expect("artifact for case geometry");
        // Warmup: compile PJRT executables on every worker before timing.
        let warm = StencilParams { iterations: 2, backend: case_backend.clone(), ..base.clone() };
        run(&rt, &warm).expect("warmup failed");
        // Zero-error pure baseline for this case.
        let mut b = Stats::new();
        for _ in 0..opts.repeats {
            let params = StencilParams { backend: case_backend.clone(), ..base.clone() };
            let (_, rep) = run(&rt, &params).expect("baseline run failed");
            b.push(rep.wall_secs);
        }
        let base_secs = b.mean();

        for &p_pct in probs_pct {
            let p = p_pct / 100.0;
            let error_rate = if p > 0.0 { Some(-p.ln()) } else { None };
            let mut injected = 0u64;
            let mut pct = |mode: Mode| -> f64 {
                let params = StencilParams {
                    mode,
                    error_rate,
                    backend: case_backend.clone(),
                    ..base.clone()
                };
                let mut s = Stats::new();
                for _ in 0..opts.repeats {
                    let (_, rep) = run(&rt, &params).expect("fig3 run failed");
                    injected = injected.max(rep.failures_injected);
                    s.push(100.0 * (rep.wall_secs - base_secs) / base_secs);
                }
                s.mean()
            };
            let replay = pct(Mode::Replay { n: replays });
            let replay_ck = pct(Mode::ReplayChecksum { n: replays });
            table.add_row(&[
                label.to_string(),
                format!("{p_pct:.1}"),
                format!("{replay:.1}"),
                format!("{replay_ck:.1}"),
                injected.to_string(),
            ]);
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_smoke() {
        let opts = HarnessOpts { scale: 0.0005, repeats: 1, workers: 2, ..Default::default() };
        let t = run_fig3(&opts, &KernelBackend::Native, &[0.0, 5.0], 5);
        let csv = t.to_csv();
        // 2 cases x 2 probabilities
        assert_eq!(csv.lines().count(), 5, "{csv}");
    }
}
