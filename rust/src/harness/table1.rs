//! Table I: amortized per-task overheads of the resilient `async`
//! variants vs. core count, 200 µs grain, no failures.
//!
//! Paper columns: {Replay, Replay Validate} and {Replicate, Replicate
//! Validate, Replicate Vote, Replicate Vote Validate} (×3 replicas),
//! rows = 1/4/8/16/32 cores. The paper reports amortized overhead per
//! task in µs against the plain-`async` baseline at the same core count.

use crate::metrics::{fmt_micros, Stats, Table};
use crate::runtime_handle::Runtime;
use crate::workload::{run, Variant, WorkloadParams};

use super::HarnessOpts;

/// Core counts to sweep. The paper uses {1,4,8,16,32} on a 32-core
/// Haswell node; on smaller testbeds pass fewer.
pub fn default_cores() -> Vec<usize> {
    vec![1, 2, 4]
}

/// Run Table I and return it.
///
/// Overhead is measured exactly as the paper does: wall time per task of
/// the resilient variant minus wall time per task of plain `async` at
/// the same core count (replicate variants additionally discount the
/// n× duplicated compute, which the paper treats as inherent cost, not
/// API overhead).
pub fn run_table1(opts: &HarnessOpts, cores: &[usize], replicas: usize) -> Table {
    let tasks = ((1_000_000.0 * opts.scale) as usize).max(1_000);
    let grain_ns = 200_000;

    let mut table = Table::new(
        &format!(
            "Table I: amortized overhead per task (µs), grain 200µs, {tasks} tasks, no failures"
        ),
        &[
            "cores",
            "replay",
            "replay_validate",
            "replicate",
            "replicate_validate",
            "replicate_vote",
            "replicate_vote_validate",
        ],
    );

    for &n_cores in cores {
        let rt = Runtime::builder().workers(n_cores).build();
        let params = WorkloadParams { tasks, grain_ns, ..Default::default() };

        // Baseline: plain async per-task time at this core count.
        let mut base = Stats::new();
        for _ in 0..opts.repeats {
            base.push(run(&rt, Variant::Plain, &params).per_task_us);
        }
        let base_us = base.mean();

        // Packing discount for replicate's inherent n× compute: divide by
        // the parallelism that can *actually* run (worker threads beyond
        // the physical core count don't speed up duplicated work — on the
        // paper's 32-core node effective == requested, on a CI container
        // it is capped by the hardware).
        let physical = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let effective = n_cores.min(physical) as f64;

        let mut cells = vec![n_cores.to_string()];
        for v in Variant::table1_variants(replicas) {
            let mut s = Stats::new();
            for _ in 0..opts.repeats {
                let rep = run(&rt, v, &params);
                let mult = if v.is_replicate() { replicas as f64 } else { 1.0 };
                let ideal_extra = (mult - 1.0) * grain_ns as f64 / 1e3 / effective;
                s.push(rep.per_task_us - base_us - ideal_extra);
            }
            cells.push(fmt_micros(s.mean()));
        }
        table.add_row(&cells);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke() {
        let opts = HarnessOpts { scale: 0.002, repeats: 1, ..Default::default() };
        let t = run_table1(&opts, &[1], 3);
        assert!(!t.is_empty());
        let csv = t.to_csv();
        assert!(csv.starts_with("cores,replay"));
        assert_eq!(csv.lines().count(), 2);
    }
}
