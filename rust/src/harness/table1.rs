//! Table I: amortized per-task overheads of the resilient `async`
//! variants vs. core count, 200 µs grain, no failures.
//!
//! Paper columns: {Replay, Replay Validate} and {Replicate, Replicate
//! Validate, Replicate Vote, Replicate Vote Validate} (×3 replicas),
//! rows = 1/4/8/16/32 cores. The paper reports amortized overhead per
//! task in µs against the plain-`async` baseline at the same core count.
//!
//! [`run_table1_executor`] is this repo's extension of the same
//! methodology: the same workload routed through the
//! [`crate::resilience::executor`] decorators, side by side with the
//! free-function path, so the decorator tax (and the adaptive policy's
//! bookkeeping) is measured rather than assumed.

use crate::metrics::{fmt_micros, Stats, Table};
use crate::runtime_handle::Runtime;
use crate::workload::{run, run_executor, ExecVariant, Variant, WorkloadParams};

use super::HarnessOpts;

/// Core counts to sweep. The paper uses {1,4,8,16,32} on a 32-core
/// Haswell node; on smaller testbeds pass fewer.
pub fn default_cores() -> Vec<usize> {
    vec![1, 2, 4]
}

/// Measure the plain-`async` per-task baseline at this core count.
/// Shared by both Table I variants; each table re-measures rather than
/// caching a baseline, so machine drift between tables shows up as
/// baseline noise instead of phantom overhead.
fn plain_baseline_us(rt: &Runtime, opts: &HarnessOpts, params: &WorkloadParams) -> f64 {
    let mut base = Stats::new();
    for _ in 0..opts.repeats {
        base.push(run(rt, Variant::Plain, params).per_task_us);
    }
    base.mean()
}

/// Amortized overhead vs. the baseline, exactly as the paper computes it:
/// per-task time minus baseline, additionally discounting the ideal cost
/// of a `mult`× duplicated grain over the parallelism that can *actually*
/// run (worker threads beyond the physical core count don't speed up
/// duplicated work — on the paper's 32-core node effective == requested,
/// on a CI container it is capped by the hardware).
fn overhead_us(per_task_us: f64, base_us: f64, mult: f64, n_cores: usize, grain_ns: u64) -> f64 {
    let physical = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let effective = n_cores.min(physical) as f64;
    per_task_us - base_us - (mult - 1.0) * grain_ns as f64 / 1e3 / effective
}

/// Run Table I and return it.
///
/// Overhead is measured exactly as the paper does: wall time per task of
/// the resilient variant minus wall time per task of plain `async` at
/// the same core count (replicate variants additionally discount the
/// n× duplicated compute, which the paper treats as inherent cost, not
/// API overhead).
pub fn run_table1(opts: &HarnessOpts, cores: &[usize], replicas: usize) -> Table {
    let tasks = ((1_000_000.0 * opts.scale) as usize).max(1_000);
    let grain_ns = 200_000;

    let mut table = Table::new(
        &format!(
            "Table I: amortized overhead per task (µs), grain 200µs, {tasks} tasks, no failures"
        ),
        &[
            "cores",
            "replay",
            "replay_validate",
            "replicate",
            "replicate_validate",
            "replicate_vote",
            "replicate_vote_validate",
        ],
    );

    for &n_cores in cores {
        let rt = Runtime::builder().workers(n_cores).build();
        let params = WorkloadParams { tasks, grain_ns, ..Default::default() };
        let base_us = plain_baseline_us(&rt, opts, &params);

        let mut cells = vec![n_cores.to_string()];
        for v in Variant::table1_variants(replicas) {
            let mut s = Stats::new();
            for _ in 0..opts.repeats {
                let rep = run(&rt, v, &params);
                let mult = if v.is_replicate() { replicas as f64 } else { 1.0 };
                s.push(overhead_us(rep.per_task_us, base_us, mult, n_cores, grain_ns));
            }
            cells.push(fmt_micros(s.mean()));
        }
        table.add_row(&cells);
    }
    table
}

/// The executor-path bench mode (`rhpx bench table1_exec`): amortized
/// per-task overhead of the decorator-routed launches vs. the resilient
/// free functions, against the same plain-`async` baseline. Columns pair
/// each free-function variant with its executor twin; `adaptive_exec` has
/// no free-function twin (budget tuning exists only on the executor
/// path).
pub fn run_table1_executor(opts: &HarnessOpts, cores: &[usize], replicas: usize) -> Table {
    let tasks = ((1_000_000.0 * opts.scale) as usize).max(1_000);
    let grain_ns = 200_000;

    let mut table = Table::new(
        &format!(
            "Table I-E: executor path vs free functions — amortized overhead per task (µs), \
             grain 200µs, {tasks} tasks, no failures"
        ),
        &[
            "cores",
            "replay_free",
            "replay_exec",
            "replicate_free",
            "replicate_exec",
            "adaptive_exec",
        ],
    );

    for &n_cores in cores {
        let rt = Runtime::builder().workers(n_cores).build();
        let params = WorkloadParams { tasks, grain_ns, ..Default::default() };
        let base_us = plain_baseline_us(&rt, opts, &params);

        let mult = replicas as f64;
        let mut cells = vec![n_cores.to_string()];
        let cell = |per_task: &mut dyn FnMut() -> f64, m: f64| {
            let mut s = Stats::new();
            for _ in 0..opts.repeats {
                s.push(overhead_us(per_task(), base_us, m, n_cores, grain_ns));
            }
            fmt_micros(s.mean())
        };
        cells.push(cell(
            &mut || run(&rt, Variant::Replay { n: replicas }, &params).per_task_us,
            1.0,
        ));
        cells.push(cell(
            &mut || run_executor(&rt, ExecVariant::Replay { n: replicas }, &params).per_task_us,
            1.0,
        ));
        cells.push(cell(
            &mut || run(&rt, Variant::Replicate { n: replicas }, &params).per_task_us,
            mult,
        ));
        cells.push(cell(
            &mut || run_executor(&rt, ExecVariant::Replicate { n: replicas }, &params).per_task_us,
            mult,
        ));
        cells.push(cell(
            &mut || {
                run_executor(&rt, ExecVariant::Adaptive { ceiling: replicas.max(2) }, &params)
                    .per_task_us
            },
            1.0,
        ));
        table.add_row(&cells);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_executor_smoke() {
        let opts = HarnessOpts { scale: 0.002, repeats: 1, ..Default::default() };
        let t = run_table1_executor(&opts, &[1], 3);
        assert!(!t.is_empty());
        let csv = t.to_csv();
        assert!(csv.starts_with("cores,replay_free,replay_exec"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn table1_smoke() {
        let opts = HarnessOpts { scale: 0.002, repeats: 1, ..Default::default() };
        let t = run_table1(&opts, &[1], 3);
        assert!(!t.is_empty());
        let csv = t.to_csv();
        assert!(csv.starts_with("cores,replay"));
        assert_eq!(csv.lines().count(), 2);
    }
}
