//! Bench harness: the *process-backed* survival experiment — the
//! honest version of `table_dist`, run over real spawned worker
//! processes ([`crate::distributed::proc`]) instead of the simulated
//! cluster.
//!
//! One zoo workload (the 1D stencil) is run through six arms that
//! differ only in substrate, fault, and resilience policy:
//!
//! 1. single-runtime pool, fault-free — the wall-time and checksum
//!    reference every other arm is compared against;
//! 2. `proc:3`, fault-free, no resilience — the pure cost of process
//!    distribution (frame encode/decode, TCP, thread-per-call);
//! 3. `proc:3`, one scheduled `SIGKILL`, no resilience — the negative
//!    control: in-flight tasks on the corpse die with it and dispatch
//!    to it is rejected, so the run completes with survival < 1;
//! 4. `proc:3`, same kill, `replay:3` — lineage re-materialization:
//!    drained in-flight descriptors re-execute on survivors;
//! 5. `proc:3`, same kill, `team:3` — first-result-wins replica teams
//!    over the process substrate;
//! 6. `proc:3`, same kill, `checkpoint:2` — windowed snapshots mirrored
//!    onto workers, eager barrier + cone repair on the kill.
//!
//! Unlike the simulated table, the kill arms report **detection
//! latency**: the measured wall-clock time from the `SIGKILL` to the
//! heartbeat monitor's death verdict — a number the simulation cannot
//! produce honestly, because its kills are bookkeeping the substrate
//! observes instantly. The bench binary
//! (`cargo run --release --bin table_proc`) wraps this as
//! `BENCH_table_proc.json`.

use crate::distributed::ProcSpec;
use crate::metrics::{JsonValue, Stats, Table};
use crate::resilience::executor::{PolicySpec, SnapshotBackend};
use crate::runtime_handle::Runtime;
use crate::workloads::{self, run, RunParams};

use super::HarnessOpts;

/// Worker processes in the proc arms.
const WORKERS: usize = 3;
/// Which worker the schedule SIGKILLs.
const KILL_LOC: usize = 1;
/// The workload every arm runs.
const WORKLOAD: &str = "stencil1d";

/// One measured arm of the process-backed survival experiment.
#[derive(Debug, Clone)]
pub struct ProcRow {
    /// Substrate: `pool(N)` or `proc(N)`.
    pub route: String,
    /// Resilience policy label (`none` for the undecorated arms).
    pub policy: String,
    /// Scheduled SIGKILLs that fired.
    pub kills: usize,
    pub wall_secs: f64,
    /// Poisoned final-wavefront slots.
    pub poisoned: u64,
    pub survival_rate: f64,
    /// Mean SIGKILL → heartbeat-verdict time (kill arms only).
    pub detection_latency_secs: Option<f64>,
    /// Mean recovery time (verdict → re-materialized task completed, or
    /// kill → next barrier when nothing was in flight).
    pub recovery_latency_secs: Option<f64>,
    /// In-flight tasks drained off the corpse at the verdict.
    pub lost: usize,
    /// Attempts beyond one execution per DAG node.
    pub reexecuted: u64,
    /// Percent extra wall time vs. the single-runtime reference arm.
    pub overhead_pct_vs_pool: f64,
    /// Final checksum bit-matches the fault-free single-runtime run.
    pub checksum_matches_pool: bool,
}

/// Milli-quantized workload scale — the geometry authority shared by
/// the parent DAG, the pool reference, and every worker process.
fn scale_milli(opts: &HarnessOpts) -> u32 {
    ((opts.scale * 1000.0).round() as u32).max(1)
}

/// The SIGKILL schedule shared by the kill arms: worker [`KILL_LOC`]
/// dies a quarter of the way through the task stream — late enough that
/// the round-robin has placed work everywhere, early enough that most
/// of the run executes degraded.
fn proc_spec(kill: bool, sm: u32, tasks: usize) -> ProcSpec {
    let base = if kill {
        ProcSpec::parse(&format!("{WORKERS}:kill={}@{KILL_LOC}", (tasks / 4).max(1)))
            .expect("arm spec parses")
    } else {
        ProcSpec::new(WORKERS)
    };
    ProcSpec { scale_milli: sm, ..base }
}

/// Run the six-arm experiment. Each arm repeats `opts.repeats` times;
/// wall time is the mean, survival/latency/checksum come from the last
/// repeat. The recovered-vs-poisoned outcome is deterministic per arm;
/// the control arm's exact poisoned *count* varies with timing (tasks
/// in flight when the SIGKILL lands die with the worker), which is why
/// rows record the survival story rather than a poisoned-count
/// baseline.
pub fn run_table_proc(opts: &HarnessOpts) -> Vec<ProcRow> {
    let sm = scale_milli(opts);
    let scale = sm as f64 / 1000.0;
    let w = workloads::by_name(WORKLOAD, scale).expect("stencil1d is registered");
    let tasks: usize = (0..w.layers()).map(|l| w.layer_tasks(l).len()).sum();
    let rt = Runtime::builder().workers(opts.workers.max(2)).build();

    let arms: Vec<(bool, bool, Option<PolicySpec>)> = vec![
        // (proc substrate?, kill?, policy)
        (false, false, None),
        (true, false, None),
        (true, true, None),
        (true, true, Some(PolicySpec::Replay { n: 3 })),
        (true, true, Some(PolicySpec::Team { n: 3 })),
        (
            true,
            true,
            Some(PolicySpec::Checkpoint { every: 2, backend: SnapshotBackend::Auto }),
        ),
    ];

    let mut reference_wall = 0.0f64;
    let mut reference_checksum = 0.0f64;
    let mut rows = Vec::with_capacity(arms.len());
    for (on_proc, kill, resilience) in arms {
        let params = RunParams {
            resilience,
            proc: on_proc.then(|| proc_spec(kill, sm, tasks)),
            ..RunParams::default()
        };
        let mut wall = Stats::new();
        let mut last = None;
        for _ in 0..opts.repeats.max(1) {
            let (_, rep) = run(&rt, w.as_ref(), &params).expect("table_proc arm failed to run");
            wall.push(rep.wall_secs);
            last = Some(rep);
        }
        let rep = last.expect("at least one repeat");
        if rows.is_empty() {
            reference_wall = wall.mean();
            reference_checksum = rep.final_checksum;
        }
        rows.push(ProcRow {
            route: rep.launcher.clone(),
            policy: resilience.map(|r| r.label()).unwrap_or_else(|| "none".into()),
            kills: rep.kills_applied,
            wall_secs: wall.mean(),
            poisoned: rep.launch_errors,
            survival_rate: rep.survival_rate(),
            detection_latency_secs: rep.detection_latency_secs,
            recovery_latency_secs: rep.recovery_latency_secs,
            lost: rep.localities.iter().map(|l| l.tasks_lost).sum(),
            reexecuted: rep.tasks_reexecuted,
            overhead_pct_vs_pool: 100.0 * (wall.mean() - reference_wall)
                / reference_wall.max(f64::MIN_POSITIVE),
            checksum_matches_pool: rep.final_checksum == reference_checksum,
        });
    }
    rows
}

/// Render the rows as the printable harness table.
pub fn to_table(rows: &[ProcRow]) -> Table {
    let mut t = Table::new(
        "Table-Proc: survival under real process SIGKILL (heartbeat detection)",
        &[
            "route", "policy", "kills", "wall_s", "poisoned", "survival_pct",
            "detect_ms", "recovery_ms", "lost", "reexec", "overhead_pct", "checksum_ok",
        ],
    );
    for r in rows {
        t.add([
            r.route.clone(),
            r.policy.clone(),
            r.kills.to_string(),
            format!("{:.3}", r.wall_secs),
            r.poisoned.to_string(),
            format!("{:.1}", 100.0 * r.survival_rate),
            r.detection_latency_secs
                .map(|s| format!("{:.2}", s * 1e3))
                .unwrap_or_else(|| "-".into()),
            r.recovery_latency_secs
                .map(|s| format!("{:.2}", s * 1e3))
                .unwrap_or_else(|| "-".into()),
            r.lost.to_string(),
            r.reexecuted.to_string(),
            format!("{:+.1}", r.overhead_pct_vs_pool),
            r.checksum_matches_pool.to_string(),
        ]);
    }
    t
}

/// The machine-readable payload for `BENCH_table_proc.json`: explicit
/// typed fields per arm plus the rendered table for human diffing. CI
/// asserts the kill arms report `detection_latency_secs > 0` and the
/// resilient kill arms report `poisoned == 0` / `survival_rate == 1`.
pub fn to_json(rows: &[ProcRow]) -> JsonValue {
    JsonValue::obj([
        (
            "rows".to_string(),
            JsonValue::Arr(
                rows.iter()
                    .map(|r| {
                        JsonValue::obj([
                            ("route".to_string(), JsonValue::from(r.route.clone())),
                            ("policy".to_string(), JsonValue::from(r.policy.clone())),
                            ("kills".to_string(), JsonValue::from(r.kills)),
                            ("wall_secs".to_string(), JsonValue::from(r.wall_secs)),
                            ("poisoned".to_string(), JsonValue::from(r.poisoned)),
                            (
                                "survival_rate".to_string(),
                                JsonValue::from(r.survival_rate),
                            ),
                            (
                                "detection_latency_secs".to_string(),
                                r.detection_latency_secs
                                    .map(JsonValue::from)
                                    .unwrap_or(JsonValue::Null),
                            ),
                            (
                                "recovery_latency_secs".to_string(),
                                r.recovery_latency_secs
                                    .map(JsonValue::from)
                                    .unwrap_or(JsonValue::Null),
                            ),
                            ("lost".to_string(), JsonValue::from(r.lost)),
                            ("reexecuted".to_string(), JsonValue::from(r.reexecuted)),
                            (
                                "overhead_pct_vs_pool".to_string(),
                                JsonValue::from(r.overhead_pct_vs_pool),
                            ),
                            (
                                "checksum_matches_pool".to_string(),
                                JsonValue::from(r.checksum_matches_pool),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("table".to_string(), to_table(rows).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // The full six-arm smoke (which spawns real worker processes) lives
    // in tests/integration_proc.rs, where RHPX_WORKER_BIN is pinned to
    // the freshly built CLI binary; here we cover the pure pieces.

    fn sample_row(policy: &str, kill: bool) -> ProcRow {
        ProcRow {
            route: "proc(3)".into(),
            policy: policy.into(),
            kills: kill as usize,
            wall_secs: 0.5,
            poisoned: 0,
            survival_rate: 1.0,
            detection_latency_secs: kill.then_some(0.081),
            recovery_latency_secs: kill.then_some(0.012),
            lost: kill as usize,
            reexecuted: kill as u64,
            overhead_pct_vs_pool: 12.0,
            checksum_matches_pool: true,
        }
    }

    #[test]
    fn table_and_json_round_the_detection_story() {
        let rows = vec![sample_row("none", false), sample_row("exec_replay(3)", true)];
        let t = to_table(&rows);
        assert_eq!(t.to_csv().lines().count(), 3, "header + 2 arms");
        let text = t.render();
        assert!(text.contains("detect_ms"), "{text}");
        assert!(text.contains("81.00"), "{text}");
        let json = to_json(&rows).render();
        assert!(json.contains(r#""detection_latency_secs":null"#), "{json}");
        assert!(json.contains(r#""detection_latency_secs":0.081"#), "{json}");
        assert!(json.contains(r#""policy":"exec_replay(3)""#), "{json}");
    }

    #[test]
    fn kill_step_lands_mid_stream_and_scale_is_quantized() {
        let sm = scale_milli(&HarnessOpts { scale: 0.0104, ..Default::default() });
        assert_eq!(sm, 10, "scale rounds to milli");
        let spec = proc_spec(true, sm, 40);
        assert_eq!(spec.localities, WORKERS);
        assert_eq!(spec.schedule.events().len(), 1);
        assert_eq!(spec.schedule.events()[0].step, 10);
        assert_eq!(spec.scale_milli, 10);
        assert!(proc_spec(false, sm, 40).schedule.is_empty());
    }
}
