//! Bench harness: `rhpx serve` under sustained multi-client load — the
//! service-level resilience story (admission control, circuit breaking,
//! journaled crash-restart) measured end to end.
//!
//! Three phases:
//!
//! 1. **steady** — N closed-loop client threads (submit, await the
//!    result, repeat) against a capacity-matched server: the
//!    throughput/latency reference. Zero rejects by construction, and
//!    the p50/p99/p999 figures come from the fixed-memory
//!    [`LatencyHistogram`] (per-client histograms merged at the end —
//!    the merge path is load-bearing, not decorative).
//! 2. **overload** — the same clients burst-submit with no pacing at a
//!    server whose queue bound is a quarter of the offered jobs
//!    (offered ≥ 4× capacity): graceful degradation means a bounded
//!    queue, explicit rejects with retry hints, and *zero lost accepted
//!    jobs* — everything acked completes.
//! 3. **recovery** — K jobs are accepted and journaled but never run,
//!    the daemon is dropped mid-flight, and a fresh server over the same
//!    journal must complete all K exactly once; the recovery figure is
//!    restart → queue drained.
//!
//! The bench binary (`cargo run --release --bin table_serve`) wraps the
//! output as `BENCH_table_serve.json`; CI's bench-smoke job asserts the
//! overload and recovery invariants on that JSON.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::checkpoint::{MemorySnapshotStore, SnapshotStore};
use crate::metrics::{JsonValue, LatencyHistogram, Table, Timer};
use crate::serve::{BreakerConfig, JobSpec, ServeConfig, Server, SubmitResponse};

use super::HarnessOpts;

/// Client threads in both load arms.
const CLIENTS: usize = 4;
/// Jobs accepted-then-abandoned in the recovery phase.
const RECOVERY_JOBS: u64 = 8;
/// Per-job workload scale (stencil1d at 0.15 ⇒ 2 layers × 8 tasks).
const JOB_SCALE_MILLI: u32 = 150;

/// One measured load arm.
#[derive(Debug, Clone)]
pub struct ServeRow {
    pub arm: String,
    pub clients: usize,
    /// Jobs the clients tried to submit.
    pub offered: u64,
    pub accepted: u64,
    pub rejected: u64,
    /// Accepted jobs that finished (ok or failed) by drain time.
    pub completed: u64,
    /// Accepted jobs with no outcome after the drain — must be 0.
    pub lost_accepted: u64,
    pub wall_secs: f64,
    pub throughput_jobs_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// rejected / offered.
    pub reject_rate: f64,
    /// Deepest the admission gate got (≤ capacity: the bound held).
    pub queue_high_water: u64,
    pub queue_capacity: u64,
}

/// The crash-restart measurement.
#[derive(Debug, Clone)]
pub struct RecoveryRow {
    pub accepted_before_crash: u64,
    pub pending_at_crash: u64,
    /// Pending jobs the restarted server found in the journal.
    pub recovered: u64,
    /// Executions after restart — exactly the pending count when the
    /// ledger holds.
    pub completed_after_restart: u64,
    /// Restart (journal scan) → queue drained.
    pub recovery_secs: f64,
    /// Every accepted job completed exactly once across both lives.
    pub completed_exactly_once: bool,
}

/// Full bench output.
#[derive(Debug, Clone)]
pub struct ServeBench {
    pub rows: Vec<ServeRow>,
    pub recovery: RecoveryRow,
}

fn job(job_id: u64) -> JobSpec {
    JobSpec {
        job_id,
        workload: "stencil1d".into(),
        policy: String::new(),
        scale_milli: JOB_SCALE_MILLI,
        error_prob_pct: 0,
    }
}

fn quantile_ms(h: &LatencyHistogram, q: f64) -> f64 {
    h.quantile(q).map(|ns| ns as f64 / 1e6).unwrap_or(f64::NAN)
}

/// Drive one load arm. `paced` = closed loop (each client waits for its
/// result before the next submit); unpaced clients burst every job and
/// wait afterwards.
fn run_arm(
    name: &str,
    cfg: ServeConfig,
    jobs_per_client: u64,
    paced: bool,
) -> ServeRow {
    let capacity = cfg.queue_capacity as u64;
    let server = Arc::new(Server::start(cfg, Arc::new(MemorySnapshotStore::new())));
    let latencies = Arc::new(Mutex::new(LatencyHistogram::new()));
    let timer = Timer::start();

    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let server = Arc::clone(&server);
        let latencies = Arc::clone(&latencies);
        handles.push(std::thread::spawn(move || {
            let mut hist = LatencyHistogram::new();
            let mut accepted_ids = Vec::new();
            let mut pending = Vec::new();
            for j in 0..jobs_per_client {
                let job_id = (c as u64) * 1_000_000 + j + 1;
                let t = Timer::start();
                match server.submit(job(job_id)) {
                    SubmitResponse::Accepted { future } => {
                        accepted_ids.push(job_id);
                        if paced {
                            let _ = future.get();
                            hist.record_duration(t.elapsed());
                        } else {
                            pending.push((t, future));
                        }
                    }
                    SubmitResponse::AlreadyDone { .. } | SubmitResponse::Rejected { .. } => {}
                }
            }
            for (t, future) in pending {
                // Accurate per-job latency: the continuation fires at
                // resolution time, not when this loop reaches the job.
                let hist_ref = Arc::clone(&latencies);
                future.on_ready(move |_| {
                    hist_ref.lock().unwrap().record_duration(t.elapsed());
                });
                future.wait();
            }
            latencies.lock().unwrap().merge(&hist);
            accepted_ids
        }));
    }
    let accepted_ids: Vec<u64> = handles
        .into_iter()
        .flat_map(|h| h.join().expect("client thread"))
        .collect();
    assert!(server.drain(Duration::from_secs(120)), "arm {name}: queue failed to drain");
    let wall = timer.elapsed_secs();
    server.stop();

    let stats = server.stats();
    let lost = accepted_ids.iter().filter(|id| server.outcome(**id).is_none()).count() as u64;
    let completed = stats.completed_ok + stats.failed + stats.deduped;
    let hist = latencies.lock().unwrap();
    let offered = (CLIENTS as u64) * jobs_per_client;
    ServeRow {
        arm: name.to_string(),
        clients: CLIENTS,
        offered,
        accepted: stats.accepted,
        rejected: stats.rejected(),
        completed,
        lost_accepted: lost,
        wall_secs: wall,
        throughput_jobs_per_sec: completed as f64 / wall.max(f64::MIN_POSITIVE),
        p50_ms: quantile_ms(&hist, 0.50),
        p99_ms: quantile_ms(&hist, 0.99),
        p999_ms: quantile_ms(&hist, 0.999),
        reject_rate: stats.rejected() as f64 / (offered as f64).max(1.0),
        queue_high_water: stats.queue_high_water,
        queue_capacity: capacity,
    }
}

/// The crash-restart phase: accept K jobs on a server with no executor
/// threads (so they journal but never run), drop it mid-flight, restart
/// over the same journal, and time the drain.
fn run_recovery(workers: usize) -> RecoveryRow {
    let journal: Arc<MemorySnapshotStore> = Arc::new(MemorySnapshotStore::new());
    let base = ServeConfig {
        queue_capacity: RECOVERY_JOBS as usize * 2,
        workers,
        breaker: BreakerConfig::default(),
        ..ServeConfig::default()
    };

    let first = Server::start(
        ServeConfig { executors: 0, ..base.clone() },
        Arc::clone(&journal) as Arc<dyn SnapshotStore>,
    );
    let mut accepted = 0u64;
    for id in 1..=RECOVERY_JOBS {
        if matches!(first.submit(job(id)), SubmitResponse::Accepted { .. }) {
            accepted += 1;
        }
    }
    let pending_at_crash = first.pending() as u64;
    let executions_before = first.stats().executions;
    first.stop(); // the "kill": queued jobs survive only in the journal
    drop(first);

    let timer = Timer::start();
    let second = Server::start(
        ServeConfig { executors: 2, ..base },
        journal as Arc<dyn SnapshotStore>,
    );
    let drained = second.drain(Duration::from_secs(120));
    let recovery_secs = timer.elapsed_secs();
    let stats = second.stats();
    let all_done = (1..=RECOVERY_JOBS).all(|id| second.outcome(id).is_some());
    let exactly_once = drained
        && all_done
        && executions_before == 0
        && stats.executions == accepted
        && stats.deduped == 0;
    second.stop();

    RecoveryRow {
        accepted_before_crash: accepted,
        pending_at_crash,
        recovered: stats.recovered_pending,
        completed_after_restart: stats.executions,
        recovery_secs,
        completed_exactly_once: exactly_once,
    }
}

/// Run the full service bench: steady arm, overload arm, recovery.
pub fn run_table_serve(opts: &HarnessOpts) -> ServeBench {
    let jobs_per_client = ((100.0 * opts.scale).round() as u64).clamp(4, 200);
    let workers = opts.workers.clamp(2, 8);

    let steady = run_arm(
        "steady",
        ServeConfig {
            queue_capacity: 64,
            executors: 2,
            workers,
            ..ServeConfig::default()
        },
        jobs_per_client,
        true,
    );
    // Offered = CLIENTS × jobs_per_client ≥ 16; capacity 4 ⇒ ≥ 4×.
    let overload = run_arm(
        "overload",
        ServeConfig {
            queue_capacity: 4,
            executors: 1,
            workers,
            ..ServeConfig::default()
        },
        jobs_per_client,
        false,
    );
    let recovery = run_recovery(workers);
    ServeBench { rows: vec![steady, overload], recovery }
}

/// Render as the printable harness table.
pub fn to_table(bench: &ServeBench) -> Table {
    let mut t = Table::new(
        "Table-Serve: rhpx serve under sustained load",
        &[
            "arm", "offered", "accepted", "rejected", "completed", "lost",
            "jobs_per_s", "p50_ms", "p99_ms", "p999_ms", "reject_rate",
        ],
    );
    for r in &bench.rows {
        t.add([
            r.arm.clone(),
            r.offered.to_string(),
            r.accepted.to_string(),
            r.rejected.to_string(),
            r.completed.to_string(),
            r.lost_accepted.to_string(),
            format!("{:.1}", r.throughput_jobs_per_sec),
            format!("{:.3}", r.p50_ms),
            format!("{:.3}", r.p99_ms),
            format!("{:.3}", r.p999_ms),
            format!("{:.3}", r.reject_rate),
        ]);
    }
    let rec = &bench.recovery;
    t.add([
        "recovery".into(),
        rec.accepted_before_crash.to_string(),
        rec.accepted_before_crash.to_string(),
        "0".into(),
        rec.completed_after_restart.to_string(),
        "0".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("recov {:.3}s once={}", rec.recovery_secs, rec.completed_exactly_once),
    ]);
    t
}

/// The machine-readable payload for `BENCH_table_serve.json` — the CI
/// assert step parses exactly this shape.
pub fn to_json(bench: &ServeBench) -> JsonValue {
    let rec = &bench.recovery;
    JsonValue::obj([
        (
            "arms".to_string(),
            JsonValue::Arr(
                bench
                    .rows
                    .iter()
                    .map(|r| {
                        JsonValue::obj([
                            ("arm".to_string(), JsonValue::from(r.arm.clone())),
                            ("clients".to_string(), JsonValue::from(r.clients)),
                            ("offered".to_string(), JsonValue::from(r.offered)),
                            ("accepted".to_string(), JsonValue::from(r.accepted)),
                            ("rejected".to_string(), JsonValue::from(r.rejected)),
                            ("completed".to_string(), JsonValue::from(r.completed)),
                            ("lost_accepted".to_string(), JsonValue::from(r.lost_accepted)),
                            ("wall_secs".to_string(), JsonValue::from(r.wall_secs)),
                            (
                                "throughput_jobs_per_sec".to_string(),
                                JsonValue::from(r.throughput_jobs_per_sec),
                            ),
                            ("p50_ms".to_string(), JsonValue::from(r.p50_ms)),
                            ("p99_ms".to_string(), JsonValue::from(r.p99_ms)),
                            ("p999_ms".to_string(), JsonValue::from(r.p999_ms)),
                            ("reject_rate".to_string(), JsonValue::from(r.reject_rate)),
                            (
                                "queue_high_water".to_string(),
                                JsonValue::from(r.queue_high_water),
                            ),
                            (
                                "queue_capacity".to_string(),
                                JsonValue::from(r.queue_capacity),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "recovery".to_string(),
            JsonValue::obj([
                (
                    "accepted_before_crash".to_string(),
                    JsonValue::from(rec.accepted_before_crash),
                ),
                ("pending_at_crash".to_string(), JsonValue::from(rec.pending_at_crash)),
                ("recovered".to_string(), JsonValue::from(rec.recovered)),
                (
                    "completed_after_restart".to_string(),
                    JsonValue::from(rec.completed_after_restart),
                ),
                ("recovery_secs".to_string(), JsonValue::from(rec.recovery_secs)),
                (
                    "completed_exactly_once".to_string(),
                    JsonValue::from(rec.completed_exactly_once),
                ),
            ]),
        ),
        ("table".to_string(), to_table(bench).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_serve_smoke_shows_graceful_degradation_and_recovery() {
        let opts = HarnessOpts { scale: 0.04, repeats: 1, workers: 2, ..Default::default() };
        let bench = run_table_serve(&opts);
        assert_eq!(bench.rows.len(), 2);

        // Steady arm: closed-loop clients never outrun the queue bound.
        let steady = &bench.rows[0];
        assert_eq!(steady.arm, "steady");
        assert_eq!(steady.rejected, 0, "paced load must not be rejected");
        assert_eq!(steady.lost_accepted, 0);
        assert_eq!(steady.completed, steady.accepted);
        assert!(steady.throughput_jobs_per_sec > 0.0);
        assert!(steady.p50_ms.is_finite() && steady.p50_ms > 0.0);
        assert!(steady.p99_ms >= steady.p50_ms);
        assert!(steady.p999_ms >= steady.p99_ms);

        // Overload arm: offered ≥ 4× capacity degrades gracefully —
        // explicit rejects, bounded queue, nothing accepted is lost.
        let overload = &bench.rows[1];
        assert_eq!(overload.arm, "overload");
        assert!(overload.offered >= 4 * overload.queue_capacity, "arm must truly overload");
        assert!(overload.rejected > 0, "overload must shed load explicitly");
        assert_eq!(overload.lost_accepted, 0, "no accepted job may vanish");
        assert_eq!(overload.completed, overload.accepted);
        assert!(
            overload.queue_high_water <= overload.queue_capacity,
            "admission bound held: {} > {}",
            overload.queue_high_water,
            overload.queue_capacity,
        );
        // "p99 of accepted work within budget": accepted jobs finish in
        // interactive time even under 4× offered load.
        assert!(overload.p99_ms < 30_000.0, "p99 {}ms", overload.p99_ms);

        // Recovery: every job accepted before the crash completes
        // exactly once after the restart.
        let rec = &bench.recovery;
        assert_eq!(rec.accepted_before_crash, RECOVERY_JOBS);
        assert_eq!(rec.pending_at_crash, RECOVERY_JOBS);
        assert_eq!(rec.recovered, RECOVERY_JOBS);
        assert_eq!(rec.completed_after_restart, RECOVERY_JOBS);
        assert!(rec.completed_exactly_once);
        assert!(rec.recovery_secs > 0.0);

        let json = to_json(&bench).render();
        assert!(json.contains(r#""arm":"overload""#), "{json}");
        assert!(json.contains(r#""completed_exactly_once":true"#), "{json}");
        assert!(json.contains(r#""lost_accepted":0"#), "{json}");
        let t = to_table(&bench);
        assert_eq!(t.to_csv().lines().count(), 4, "header + 2 arms + recovery row");
    }
}
