//! Bench harness: what does the flight recorder cost? ([`crate::trace`])
//!
//! Observability that perturbs the system it observes is worse than
//! none — a recorder priced at microseconds per task would change every
//! overhead number this repo reports. This table prices the record path
//! directly: the same spawn/execute stream is run with the recorder
//! off, on, and on-with-export, at two task grains straddling the
//! paper's 200 µs operating point:
//!
//! * **20 µs grain** — tasks so small that scheduler overhead (and any
//!   recorder cost) is a visible fraction of the work;
//! * **200 µs grain** — the paper's grain, where the recorder must be
//!   invisible (CI asserts the trace-on arm within 5% of trace-off).
//!
//! Each arm reports ns/task, the delta vs. the trace-off arm at the
//! same grain, and the events recorded/dropped — the ring is
//! fixed-capacity overwrite-oldest, so a drop count here is the honest
//! price of the no-allocation record path, never a silent loss. The
//! bench binary (`cargo run --release --bin table_obs`) wraps this as
//! `BENCH_table_obs.json`.

use std::time::Instant;

use crate::metrics::{busy_wait_ns, JsonValue, Stats, Table};
use crate::runtime_handle::Runtime;

use super::HarnessOpts;

/// Task grains (ns) straddling the paper's 200 µs operating point.
const GRAINS_NS: &[u64] = &[20_000, 200_000];

/// One measured arm: a (grain, recorder mode) cell.
#[derive(Debug, Clone)]
pub struct ObsRow {
    /// Task grain in µs.
    pub grain_us: u64,
    /// `off`, `on`, or `on_export`.
    pub mode: String,
    /// Tasks spawned per repeat.
    pub tasks: usize,
    /// Mean wall time per task (ns) — wall / tasks, so the number
    /// prices throughput, and the vs-off delta isolates the recorder.
    pub ns_per_task: f64,
    /// `ns_per_task` minus the trace-off arm at the same grain.
    pub overhead_ns_vs_off: f64,
    /// Same delta as a percentage of the trace-off arm.
    pub overhead_pct_vs_off: f64,
    /// Events the rings accepted during the arm (last repeat).
    pub events_recorded: u64,
    /// Events lost to ring overwrite during the arm (last repeat).
    pub events_dropped: u64,
}

/// Tasks per repeat: enough that per-task cost dominates pool spin-up,
/// scaled with the harness knob but floored for tiny smoke scales.
fn tasks_for(opts: &HarnessOpts) -> usize {
    ((20_000.0 * opts.scale) as usize).max(200)
}

/// One timed pass: spawn `tasks` grain-sized bodies through the real
/// scheduler (the instrumented path: Spawn + ExecBegin/ExecEnd per
/// task), wait for all, optionally export the accumulated trace —
/// export inside the timed window, since "on + export" prices exactly
/// that.
fn run_arm(rt: &Runtime, tasks: usize, grain_ns: u64, export_to: Option<&str>) -> f64 {
    let t0 = Instant::now();
    let futs: Vec<_> = (0..tasks)
        .map(|_| {
            crate::api::async_(rt, move || {
                busy_wait_ns(grain_ns);
                42i32
            })
        })
        .collect();
    for f in futs {
        let _ = f.get();
    }
    if let Some(path) = export_to {
        let _ = crate::trace::chrome::export(path);
    }
    t0.elapsed().as_secs_f64()
}

/// Run the six-arm grid (2 grains × {off, on, on_export}).
///
/// This toggles the process-global trace session, so nothing else in
/// the process should be tracing concurrently (true in the bench
/// binaries and the CLI). The session is left disabled and drained.
pub fn run_table_obs(opts: &HarnessOpts) -> Vec<ObsRow> {
    let tasks = tasks_for(opts);
    let rt = Runtime::builder().workers(opts.workers.max(1)).build();
    let export_path = std::env::temp_dir().join("rhpx_table_obs_trace.json");
    let export_path = export_path.to_string_lossy().into_owned();

    let mut rows = Vec::new();
    for &grain_ns in GRAINS_NS {
        let mut off_ns_per_task = 0.0f64;
        for mode in ["off", "on", "on_export"] {
            match mode {
                "off" => crate::trace::disable(),
                _ => crate::trace::enable(),
            }
            let mut wall = Stats::new();
            let mut recorded = 0u64;
            let mut dropped = 0u64;
            for _ in 0..opts.repeats.max(1) {
                let (rec0, drop0) = crate::trace::totals();
                let secs = run_arm(
                    &rt,
                    tasks,
                    grain_ns,
                    (mode == "on_export").then_some(export_path.as_str()),
                );
                wall.push(secs);
                let (rec1, drop1) = crate::trace::totals();
                recorded = rec1 - rec0;
                dropped = drop1 - drop0;
            }
            let ns_per_task = wall.mean() * 1e9 / tasks as f64;
            if mode == "off" {
                off_ns_per_task = ns_per_task;
            }
            rows.push(ObsRow {
                grain_us: grain_ns / 1000,
                mode: mode.to_string(),
                tasks,
                ns_per_task,
                overhead_ns_vs_off: ns_per_task - off_ns_per_task,
                overhead_pct_vs_off: 100.0 * (ns_per_task - off_ns_per_task)
                    / off_ns_per_task.max(f64::MIN_POSITIVE),
                events_recorded: recorded,
                events_dropped: dropped,
            });
        }
    }
    crate::trace::disable();
    let _ = crate::trace::drain_all(); // leave the session empty
    let _ = std::fs::remove_file(&export_path);
    rows
}

/// Render the rows as the printable harness table.
pub fn to_table(rows: &[ObsRow]) -> Table {
    let mut t = Table::new(
        "Table-Obs: flight-recorder overhead (ns/task, off vs on vs on+export)",
        &[
            "grain_us", "mode", "tasks", "ns_per_task", "overhead_ns", "overhead_pct",
            "events", "dropped",
        ],
    );
    for r in rows {
        t.add([
            r.grain_us.to_string(),
            r.mode.clone(),
            r.tasks.to_string(),
            format!("{:.0}", r.ns_per_task),
            format!("{:+.0}", r.overhead_ns_vs_off),
            format!("{:+.2}", r.overhead_pct_vs_off),
            r.events_recorded.to_string(),
            r.events_dropped.to_string(),
        ]);
    }
    t
}

/// The machine-readable payload for `BENCH_table_obs.json`. CI asserts
/// the 200 µs trace-on arm's `ns_per_task` is within 5% (plus a small
/// absolute floor for timer noise) of the trace-off arm.
pub fn to_json(rows: &[ObsRow]) -> JsonValue {
    JsonValue::obj([
        (
            "rows".to_string(),
            JsonValue::Arr(
                rows.iter()
                    .map(|r| {
                        JsonValue::obj([
                            ("grain_us".to_string(), JsonValue::from(r.grain_us)),
                            ("mode".to_string(), JsonValue::from(r.mode.clone())),
                            ("tasks".to_string(), JsonValue::from(r.tasks)),
                            ("ns_per_task".to_string(), JsonValue::from(r.ns_per_task)),
                            (
                                "overhead_ns_vs_off".to_string(),
                                JsonValue::from(r.overhead_ns_vs_off),
                            ),
                            (
                                "overhead_pct_vs_off".to_string(),
                                JsonValue::from(r.overhead_pct_vs_off),
                            ),
                            (
                                "events_recorded".to_string(),
                                JsonValue::from(r.events_recorded),
                            ),
                            (
                                "events_dropped".to_string(),
                                JsonValue::from(r.events_dropped),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("table".to_string(), to_table(rows).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // The timed grid (which toggles the process-global trace session)
    // runs only in the bench binary; here we cover the pure pieces.

    fn sample_row(mode: &str, ns: f64, off: f64) -> ObsRow {
        ObsRow {
            grain_us: 200,
            mode: mode.into(),
            tasks: 200,
            ns_per_task: ns,
            overhead_ns_vs_off: ns - off,
            overhead_pct_vs_off: 100.0 * (ns - off) / off,
            events_recorded: if mode == "off" { 0 } else { 600 },
            events_dropped: 0,
        }
    }

    #[test]
    fn table_and_json_carry_the_overhead_story() {
        let rows = vec![
            sample_row("off", 201_000.0, 201_000.0),
            sample_row("on", 201_400.0, 201_000.0),
            sample_row("on_export", 203_000.0, 201_000.0),
        ];
        let t = to_table(&rows);
        assert_eq!(t.to_csv().lines().count(), 4, "header + 3 arms");
        let text = t.render();
        assert!(text.contains("ns_per_task"), "{text}");
        assert!(text.contains("on_export"), "{text}");
        let json = to_json(&rows).render();
        assert!(json.contains(r#""mode":"off""#), "{json}");
        assert!(json.contains(r#""events_recorded":600"#), "{json}");
        assert!(json.contains(r#""ns_per_task":"#), "{json}");
    }

    #[test]
    fn task_count_scales_and_floors() {
        assert_eq!(tasks_for(&HarnessOpts { scale: 0.001, ..Default::default() }), 200);
        assert_eq!(tasks_for(&HarnessOpts { scale: 1.0, ..Default::default() }), 20_000);
    }
}
