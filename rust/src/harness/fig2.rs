//! Fig 2: extra execution time per task vs. probability of error
//! occurrence, task grain 200 µs.
//!
//! * Fig 2a — `async_replay` (n = 3): overhead grows with the error
//!   probability because failing tasks re-run (≈ p·grain extra per task
//!   at small p).
//! * Fig 2b — `async_replicate` (×3): a flat line — every task runs
//!   three replicas regardless of errors, so error probability does not
//!   change the (already ×3) cost.
//!
//! The paper sweeps error probabilities up to 5% (error-rate factors
//! x = -ln(p)).

use crate::metrics::{fmt_micros, Stats, Table};
use crate::runtime_handle::Runtime;
use crate::workload::{run, Variant, WorkloadParams};

use super::HarnessOpts;

/// The paper's x-axis: probability of error occurrence per task (%).
pub fn default_probabilities() -> Vec<f64> {
    vec![0.0, 0.5, 1.0, 2.0, 3.0, 4.0, 5.0]
}

/// Run both Fig 2 series; rows are error probabilities, columns the
/// extra per-task time of replay(3) and replicate(3) over the
/// zero-error plain baseline.
pub fn run_fig2(opts: &HarnessOpts, probs_pct: &[f64]) -> Table {
    let tasks = ((1_000_000.0 * opts.scale) as usize).max(1_000);
    let grain_ns = 200_000;
    let rt = Runtime::builder().workers(opts.workers).build();

    let base_params = WorkloadParams { tasks, grain_ns, ..Default::default() };
    let mut base = Stats::new();
    for _ in 0..opts.repeats {
        base.push(run(&rt, Variant::Plain, &base_params).per_task_us);
    }
    let base_us = base.mean();
    let grain_us = grain_ns as f64 / 1e3;
    // (3-1)×grain of inherent duplicated compute, packed over the
    // parallelism that can actually run (capped by physical cores).
    let physical = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let ideal_replicate_extra = 2.0 * grain_us / (opts.workers.min(physical)) as f64;

    let mut table = Table::new(
        &format!(
            "Fig 2: extra time per task (µs) vs error probability, grain 200µs, {tasks} tasks"
        ),
        &[
            "error_prob_pct",
            "replay3_extra_us",
            "replicate3_extra_us",
            "injected_replay",
            "injected_replicate",
        ],
    );

    for &p_pct in probs_pct {
        let p = p_pct / 100.0;
        let error_rate = if p > 0.0 { Some(-p.ln()) } else { None };
        let params = WorkloadParams { error_rate, ..base_params.clone() };

        let mut replay = Stats::new();
        let mut injected_replay = 0u64;
        for _ in 0..opts.repeats {
            let rep = run(&rt, Variant::Replay { n: 3 }, &params);
            replay.push(rep.per_task_us - base_us);
            injected_replay = rep.failures_injected;
        }
        let mut replicate = Stats::new();
        let mut injected_repl = 0u64;
        for _ in 0..opts.repeats {
            let rep = run(&rt, Variant::Replicate { n: 3 }, &params);
            replicate.push(rep.per_task_us - base_us - ideal_replicate_extra);
            injected_repl = rep.failures_injected;
        }
        table.add_row(&[
            format!("{p_pct:.1}"),
            fmt_micros(replay.mean()),
            fmt_micros(replicate.mean()),
            injected_replay.to_string(),
            injected_repl.to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_smoke() {
        let opts = HarnessOpts { scale: 0.002, repeats: 1, workers: 2, ..Default::default() };
        let t = run_fig2(&opts, &[0.0, 5.0]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        // the 5% row must actually inject failures
        let last = csv.lines().last().unwrap();
        let injected: u64 = last.split(',').nth(3).unwrap().parse().unwrap();
        assert!(injected > 0, "row: {last}");
    }
}
