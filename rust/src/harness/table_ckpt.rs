//! Bench harness: the resilience-strategy ablation around task-level
//! checkpoint/restart — the number replay alone cannot produce is
//! *re-executed work*: how many task executions a kill costs under each
//! strategy, next to the snapshot bytes paid to get there.
//!
//! Five arms over one stencil geometry and one scheduled locality kill:
//!
//! 1. single-runtime pool, fault-free — wall-time and checksum
//!    reference;
//! 2. cluster + kill, `replay:3` — the paper's strategy: every
//!    post-kill launch that lands on the corpse burns an attempt and
//!    retries on the next locality;
//! 3. cluster + kill, `checkpoint:K` sweep (K = 1, 2, 4; AGAS-replicated
//!    snapshots) — snapshot cadence vs repair depth;
//! 4. cluster + kill, `checkpoint:2` on the *disk* backend — the same
//!    strategy paying persistent-storage I/O instead of AGAS
//!    replication;
//! 5. coordinated global C/R (`checkpoint::run_with_checkpoints`, §I's
//!    strawman) — the same kill as a *global* failure: whole-state
//!    rollback, every subdomain of every rolled-back iteration redone.
//!
//! Emitted per arm: wall time, re-executed tasks, snapshots
//! saved/restored/lost, snapshot bytes, recovery latency, survival, and
//! whether the checksum matches the reference. The bench binary
//! (`cargo run --release --bin table_ckpt`) wraps this as
//! `BENCH_table_ckpt.json`.

use crate::checkpoint::{run_with_checkpoints, CheckpointStore, SnapshotData, Storage};
use crate::metrics::{JsonValue, Stats, Table};
use crate::runtime_handle::Runtime;
use crate::stencil::{
    build_extended, kernel, run, Chunk, ClusterSpec, Domain, ExecPolicy, SnapshotBackend,
    StencilParams,
};

use super::HarnessOpts;

/// Localities in the cluster arms.
const LOCALITIES: usize = 4;
/// Which locality the schedule kills.
const KILL_LOC: usize = 2;
/// Snapshot cadence of the global-C/R and disk arms (windows).
const BASE_EVERY: usize = 2;

/// One measured arm of the strategy ablation.
#[derive(Debug, Clone)]
pub struct CkptRow {
    /// Arm id: `pool_ref`, `replay`, `checkpoint:K`, `checkpoint_disk`,
    /// `global_cr`.
    pub arm: String,
    /// Substrate: `pool(N)`, `cluster(N)`, or `serial` (global C/R).
    pub route: String,
    /// Policy label.
    pub policy: String,
    /// Failures applied (scheduled kills, or the global failure).
    pub kills: usize,
    pub wall_secs: f64,
    /// Work beyond one execution per DAG node (retries, repairs, redone
    /// rollback iterations × subdomains).
    pub tasks_reexecuted: u64,
    pub snapshots_saved: u64,
    pub snapshots_restored: u64,
    pub snapshot_bytes: u64,
    pub snapshots_lost: u64,
    pub recovery_latency_secs: Option<f64>,
    pub survival_rate: f64,
    /// Final checksum bit-matches the fault-free reference run.
    pub checksum_matches_pool: bool,
    /// Percent extra wall time vs. the reference arm.
    pub overhead_pct_vs_pool: f64,
}

/// The geometry shared by every arm (mirrors `table_dist`).
fn params(opts: &HarnessOpts) -> StencilParams {
    StencilParams {
        iterations: ((1000.0 * opts.scale) as usize).max(10),
        ..StencilParams::tiny()
    }
}

/// Kill schedule shared by the faulty arms: locality [`KILL_LOC`] dies
/// an eighth of the way through the task stream.
fn kill_task(p: &StencilParams) -> usize {
    (p.total_tasks() / 8).max(1)
}

fn kill_spec(p: &StencilParams) -> String {
    format!("{LOCALITIES}:kill={}@{KILL_LOC}", kill_task(p))
}

/// Run one stencil arm `repeats` times; mean wall, last report.
fn stencil_arm(
    rt: &Runtime,
    p: &StencilParams,
    repeats: usize,
    arm: &str,
    ref_wall: f64,
    ref_checksum: f64,
) -> (CkptRow, f64, f64) {
    let mut wall = Stats::new();
    let mut last = None;
    for _ in 0..repeats.max(1) {
        let (_, rep) = run(rt, p).expect("table_ckpt arm failed to run");
        wall.push(rep.wall_secs);
        last = Some(rep);
    }
    let rep = last.expect("at least one repeat");
    let mean = wall.mean();
    let denom = if ref_wall > 0.0 { ref_wall } else { f64::MIN_POSITIVE };
    let row = CkptRow {
        arm: arm.to_string(),
        route: rep.launcher.clone(),
        policy: p.resilience.map(|r| r.label()).unwrap_or_else(|| "none".into()),
        kills: rep.kills_applied,
        wall_secs: mean,
        tasks_reexecuted: rep.tasks_reexecuted,
        snapshots_saved: rep.snapshots.saved,
        snapshots_restored: rep.snapshots.restored,
        snapshot_bytes: rep.snapshots.bytes,
        snapshots_lost: rep.snapshots.lost,
        recovery_latency_secs: rep.recovery_latency_secs,
        survival_rate: rep.survival_rate(),
        checksum_matches_pool: ref_wall == 0.0 || rep.final_checksum == ref_checksum,
        overhead_pct_vs_pool: if ref_wall > 0.0 { 100.0 * (mean - ref_wall) / denom } else { 0.0 },
    };
    (row, mean, rep.final_checksum)
}

/// The coordinated global-C/R arm: the same geometry advanced serially
/// under `run_with_checkpoints`, with the kill surfacing as a *global*
/// failure at the iteration the cluster arms' kill task falls into.
fn global_cr_arm(p: &StencilParams, ref_out: &[f64], ref_wall: f64) -> CkptRow {
    let domain = Domain::sine(p.n_sub, p.nx);
    let mut state: Vec<Vec<f64>> = domain.subdomains.iter().map(|c| (*c.data).clone()).collect();
    let state_bytes = state.to_bytes().len() as u64;
    let store = CheckpointStore::new(Storage::Memory);
    let interval = (BASE_EVERY * p.window).max(1) as u64;
    let fail_iter = (kill_task(p) / p.n_sub) as u64;
    let steps = p.steps;
    let courant = p.courant;
    let n = p.n_sub;
    let mut failed_once = false;

    let timer = crate::metrics::Timer::start();
    let rep = run_with_checkpoints(&mut state, p.iterations as u64, interval, &store, |i, s| {
        if i == fail_iter && !failed_once {
            failed_once = true;
            // Under coordinated C/R a locality death is a *global*
            // failure: everything rolls back.
            return Err("locality death (global under coordinated C/R)".into());
        }
        let chunks: Vec<Chunk> = s.iter().map(|d| Chunk::new(d.clone())).collect();
        let mut next = Vec::with_capacity(n);
        for j in 0..n {
            let ext = build_extended(
                &chunks[(j + n - 1) % n],
                &chunks[j],
                &chunks[(j + 1) % n],
                steps,
            );
            next.push(kernel::lax_wendroff_multistep_owned(ext, steps, courant));
        }
        *s = next;
        Ok(())
    })
    .expect("global C/R arm failed to run");
    let wall = timer.elapsed_secs();

    let out: Vec<f64> = state.iter().flatten().copied().collect();
    CkptRow {
        arm: "global_cr".to_string(),
        route: "serial".to_string(),
        policy: format!("global_cr(interval {interval})"),
        kills: 1,
        wall_secs: wall,
        // Every redone rollback iteration re-executes all subdomains —
        // the cost structure task-level checkpointing avoids.
        tasks_reexecuted: rep.redone * n as u64,
        snapshots_saved: rep.checkpoints,
        snapshots_restored: rep.rollbacks,
        snapshot_bytes: rep.checkpoints * state_bytes,
        snapshots_lost: 0,
        recovery_latency_secs: None,
        survival_rate: 1.0,
        checksum_matches_pool: out == ref_out,
        overhead_pct_vs_pool: if ref_wall > 0.0 {
            100.0 * (wall - ref_wall) / ref_wall
        } else {
            0.0
        },
    }
}

/// Run the five-arm (seven-row) ablation. Worker parity follows
/// `table_dist`: the cluster arms spread `opts.workers` across
/// localities and the pool reference runs on the same total.
pub fn run_table_ckpt(opts: &HarnessOpts) -> Vec<CkptRow> {
    let wpl = (opts.workers / LOCALITIES).max(1);
    let rt = Runtime::builder().workers(LOCALITIES * wpl).build();
    let base = params(opts);
    let faulty = kill_spec(&base);
    let clustered = |resilience: Option<ExecPolicy>| -> StencilParams {
        let mut spec = ClusterSpec::parse(&faulty).expect("arm spec parses");
        spec.workers_per_locality = wpl;
        StencilParams { cluster: Some(spec), resilience, ..base.clone() }
    };

    let mut rows = Vec::new();

    // Arm 1: the fault-free pool reference.
    let (mut ref_row, ref_wall, ref_checksum) =
        stencil_arm(&rt, &base, opts.repeats, "pool_ref", 0.0, 0.0);
    ref_row.checksum_matches_pool = true;
    rows.push(ref_row);
    let (ref_out, _) = run(&rt, &base).expect("reference gather");

    // Arm 2: replay — the comparator checkpointing must beat on
    // re-executed work.
    let p = clustered(Some(ExecPolicy::Replay { n: 3 }));
    rows.push(stencil_arm(&rt, &p, opts.repeats, "replay", ref_wall, ref_checksum).0);

    // Arm 3: the checkpoint:K cadence sweep (AGAS-replicated snapshots).
    for every in [1usize, 2, 4] {
        let p = clustered(Some(ExecPolicy::Checkpoint { every, backend: SnapshotBackend::Auto }));
        let arm = format!("checkpoint:{every}");
        rows.push(stencil_arm(&rt, &p, opts.repeats, &arm, ref_wall, ref_checksum).0);
    }

    // Arm 4: the disk backend at the base cadence.
    let p = clustered(Some(ExecPolicy::Checkpoint {
        every: BASE_EVERY,
        backend: SnapshotBackend::Disk,
    }));
    rows.push(stencil_arm(&rt, &p, opts.repeats, "checkpoint_disk", ref_wall, ref_checksum).0);

    // Arm 5: the coordinated global-C/R strawman.
    rows.push(global_cr_arm(&base, &ref_out, ref_wall));

    rows
}

/// Render the rows as the printable harness table.
pub fn to_table(rows: &[CkptRow]) -> Table {
    let mut t = Table::new(
        "Table-Ckpt: replay vs task-level checkpoint/restart vs global C/R",
        &[
            "arm", "route", "policy", "kills", "wall_s", "reexec", "snap_saved",
            "snap_restored", "snap_bytes", "snap_lost", "recovery_ms", "survival_pct",
            "checksum_ok", "overhead_pct",
        ],
    );
    for r in rows {
        t.add([
            r.arm.clone(),
            r.route.clone(),
            r.policy.clone(),
            r.kills.to_string(),
            format!("{:.3}", r.wall_secs),
            r.tasks_reexecuted.to_string(),
            r.snapshots_saved.to_string(),
            r.snapshots_restored.to_string(),
            r.snapshot_bytes.to_string(),
            r.snapshots_lost.to_string(),
            r.recovery_latency_secs
                .map(|s| format!("{:.2}", s * 1e3))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}", 100.0 * r.survival_rate),
            r.checksum_matches_pool.to_string(),
            format!("{:+.1}", r.overhead_pct_vs_pool),
        ]);
    }
    t
}

/// The machine-readable payload for `BENCH_table_ckpt.json`.
pub fn to_json(rows: &[CkptRow]) -> JsonValue {
    JsonValue::obj([
        (
            "rows".to_string(),
            JsonValue::Arr(
                rows.iter()
                    .map(|r| {
                        JsonValue::obj([
                            ("arm".to_string(), JsonValue::from(r.arm.clone())),
                            ("route".to_string(), JsonValue::from(r.route.clone())),
                            ("policy".to_string(), JsonValue::from(r.policy.clone())),
                            ("kills".to_string(), JsonValue::from(r.kills)),
                            ("wall_secs".to_string(), JsonValue::from(r.wall_secs)),
                            (
                                "tasks_reexecuted".to_string(),
                                JsonValue::from(r.tasks_reexecuted),
                            ),
                            (
                                "snapshots_saved".to_string(),
                                JsonValue::from(r.snapshots_saved),
                            ),
                            (
                                "snapshots_restored".to_string(),
                                JsonValue::from(r.snapshots_restored),
                            ),
                            ("snapshot_bytes".to_string(), JsonValue::from(r.snapshot_bytes)),
                            ("snapshots_lost".to_string(), JsonValue::from(r.snapshots_lost)),
                            (
                                "recovery_latency_secs".to_string(),
                                r.recovery_latency_secs
                                    .map(JsonValue::from)
                                    .unwrap_or(JsonValue::Null),
                            ),
                            ("survival_rate".to_string(), JsonValue::from(r.survival_rate)),
                            (
                                "checksum_matches_pool".to_string(),
                                JsonValue::from(r.checksum_matches_pool),
                            ),
                            (
                                "overhead_pct_vs_pool".to_string(),
                                JsonValue::from(r.overhead_pct_vs_pool),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("table".to_string(), to_table(rows).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_ckpt_smoke_tells_the_strategy_story() {
        let opts = HarnessOpts { scale: 0.01, repeats: 1, workers: 2, ..Default::default() };
        let rows = run_table_ckpt(&opts);
        assert_eq!(rows.len(), 7, "5 arms = 7 rows (checkpoint sweep is 3)");

        let reference = &rows[0];
        assert!(reference.route.starts_with("pool("));
        assert_eq!(reference.survival_rate, 1.0);

        let replay = &rows[1];
        assert_eq!(replay.kills, 1);
        assert_eq!(replay.survival_rate, 1.0);
        assert!(replay.checksum_matches_pool, "replay must reproduce the reference");
        assert!(replay.tasks_reexecuted > 0, "replay must pay re-routed attempts");
        assert_eq!(replay.snapshots_saved, 0, "replay persists nothing");

        // Every checkpoint row: survived, checksum-identical, snapshots
        // paid, and strictly less re-executed work than replay — the
        // headline number of the subsystem.
        for r in &rows[2..=5] {
            assert_eq!(r.kills, 1, "{}", r.arm);
            assert_eq!(r.survival_rate, 1.0, "{}", r.arm);
            assert!(r.checksum_matches_pool, "{} diverged from reference", r.arm);
            assert!(r.snapshots_saved > 0, "{} must snapshot", r.arm);
            assert!(
                r.tasks_reexecuted < replay.tasks_reexecuted,
                "{} re-executed {} vs replay {}",
                r.arm,
                r.tasks_reexecuted,
                replay.tasks_reexecuted
            );
            assert_eq!(r.snapshots_lost, 0, "{}: replicated/disk snapshots survive", r.arm);
        }
        // Cadence: snapshotting every window persists at least as much
        // as every 4 windows.
        assert!(rows[2].snapshot_bytes >= rows[4].snapshot_bytes);
        assert!(rows[5].policy.contains("disk"));

        let cr = &rows[6];
        assert_eq!(cr.arm, "global_cr");
        assert!(cr.checksum_matches_pool, "global C/R must still be exact");
        assert!(
            cr.tasks_reexecuted > 0,
            "the global rollback must redo whole iterations"
        );
        assert!(cr.snapshot_bytes > 0);

        let json = to_json(&rows).render();
        assert!(json.contains(r#""arm":"checkpoint:2""#), "{json}");
        assert!(json.contains(r#""tasks_reexecuted""#), "{json}");
        assert!(json.contains(r#""snapshot_bytes""#), "{json}");
        let t = to_table(&rows);
        assert_eq!(t.to_csv().lines().count(), 8, "header + 7 rows");
    }
}
