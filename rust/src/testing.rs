//! Testing substrates: a miniature property-based harness and a
//! deterministic interleaving scheduler.
//!
//! The offline build has no `proptest`/`quickcheck`, so the crate carries
//! its own: generate many random cases from a seeded [`Rng`]
//! (deterministic → reproducible failures), run the property, and on
//! failure report the case number and seed so the exact case can be
//! replayed.
//!
//! The second substrate, [`det`], is a virtual-time, script-driven
//! single-thread scheduler: concurrency protocols (the Chase–Lev deque,
//! the lineage ledger, replica-team cancellation) are decomposed into
//! named logical threads of discrete steps, and a *script* chooses the
//! exact interleaving to replay. Where `tests/stress_concurrency.rs`
//! hammers real threads and hopes the schedule of interest occurs, a
//! `det` script *forces* it, every run, as a plain `cargo test` case.
//!
//! Paper mapping: verification substrate only (no table/figure); backs
//! the property suites in `rust/tests/properties.rs` and the scripted
//! interleavings in `rust/tests/deterministic_schedules.rs`.

use crate::failure::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `property` for each of `cfg.cases` seeded RNGs; panic with a
/// replayable diagnostic on the first failure.
///
/// The property returns `Result<(), String>`: `Err` describes the
/// violated invariant.
pub fn check<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::seeded(case_seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{} (seed {case_seed:#x}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Convenience: run with default config.
pub fn check_default<F>(name: &str, property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, PropConfig::default(), property);
}

/// Generators for common test inputs.
pub mod gen {
    use crate::failure::Rng;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        lo + rng.next_f64() * (hi - lo)
    }

    /// Vector of f64 with the given length range and value range.
    pub fn vec_f64(rng: &mut Rng, len_lo: usize, len_hi: usize, lo: f64, hi: f64) -> Vec<f64> {
        let len = usize_in(rng, len_lo, len_hi);
        (0..len).map(|_| f64_in(rng, lo, hi)).collect()
    }

    /// Vector of i64 in a value range.
    pub fn vec_i64(rng: &mut Rng, len_lo: usize, len_hi: usize, lo: i64, hi: i64) -> Vec<i64> {
        let len = usize_in(rng, len_lo, len_hi);
        (0..len)
            .map(|_| lo + rng.next_below((hi - lo + 1) as u64) as i64)
            .collect()
    }

    /// Bernoulli draw.
    pub fn bool_with(rng: &mut Rng, p: f64) -> bool {
        rng.next_f64() < p
    }
}

/// Deterministic interleaving harness: a virtual-time, script-driven
/// single-thread scheduler.
///
/// A test registers *logical threads* — named queues of discrete steps
/// (closures) — then replays a chosen interleaving by naming which
/// thread takes the next step. All steps run on the calling OS thread,
/// so there are no data races to win or lose: what is exercised is the
/// *protocol logic* (index arbitration, claim-exactly-once, cancel
/// ordering) under an interleaving the script pins down exactly.
///
/// ```
/// use rhpx::testing::det::{step, Interleaver};
/// use std::cell::Cell;
///
/// let hits = Cell::new(0u64);
/// let mut il = Interleaver::new();
/// il.spawn("a", vec![step(|clk| { clk.advance(5); hits.set(hits.get() + 1) })]);
/// il.spawn("b", vec![step(|_| hits.set(hits.get() + 10))]);
/// il.run_script("b a").unwrap();
/// assert_eq!(hits.get(), 11);
/// assert_eq!(il.now(), 7); // 1 tick per step + the explicit advance(5)
/// assert!(il.is_drained());
/// ```
pub mod det {
    use std::collections::VecDeque;
    use std::fmt;

    /// Virtual time: advances one tick per scheduled step, plus whatever
    /// a step adds explicitly via [`VirtualClock::advance`]. No wall
    /// clock is ever consulted, so traces replay identically.
    #[derive(Debug, Default)]
    pub struct VirtualClock {
        now: u64,
    }

    impl VirtualClock {
        /// Current virtual time in ticks.
        pub fn now(&self) -> u64 {
            self.now
        }

        /// Model a step taking `ticks` of virtual time.
        pub fn advance(&mut self, ticks: u64) {
            self.now += ticks;
        }
    }

    /// One discrete step of a logical thread.
    pub type Step<'a> = Box<dyn FnOnce(&mut VirtualClock) + 'a>;

    /// Build a [`Step`] from any closure (saves the `Box::new` at every
    /// call site and fixes the closure's argument type).
    pub fn step<'a, F: FnOnce(&mut VirtualClock) + 'a>(f: F) -> Step<'a> {
        Box::new(f)
    }

    /// A script referenced a thread that cannot take a step.
    #[derive(Debug, Clone, PartialEq, Eq)]
    pub enum ScheduleError {
        /// No thread with this name was spawned.
        UnknownThread { name: String },
        /// The named thread has no steps left.
        Exhausted { name: String },
    }

    impl fmt::Display for ScheduleError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            match self {
                ScheduleError::UnknownThread { name } => {
                    write!(f, "script names unknown thread {name:?}")
                }
                ScheduleError::Exhausted { name } => {
                    write!(f, "thread {name:?} has no steps left")
                }
            }
        }
    }

    impl std::error::Error for ScheduleError {}

    /// The deterministic scheduler: named step queues + a trace of which
    /// thread ran at which virtual time.
    #[derive(Default)]
    pub struct Interleaver<'a> {
        threads: Vec<(&'static str, VecDeque<Step<'a>>)>,
        clock: VirtualClock,
        trace: Vec<(u64, &'static str)>,
    }

    impl<'a> Interleaver<'a> {
        pub fn new() -> Self {
            Self::default()
        }

        /// Register a logical thread. Spawning an existing name appends
        /// to that thread's queue (handy for phased scripts).
        pub fn spawn<I>(&mut self, name: &'static str, steps: I)
        where
            I: IntoIterator<Item = Step<'a>>,
        {
            if let Some((_, q)) = self.threads.iter_mut().find(|(n, _)| *n == name) {
                q.extend(steps);
            } else {
                self.threads.push((name, steps.into_iter().collect()));
            }
        }

        /// Run the next step of the named thread.
        pub fn run_step(&mut self, name: &str) -> Result<(), ScheduleError> {
            let idx = self
                .threads
                .iter()
                .position(|(n, _)| *n == name)
                .ok_or_else(|| ScheduleError::UnknownThread { name: name.to_string() })?;
            let tname = self.threads[idx].0;
            let step = self.threads[idx]
                .1
                .pop_front()
                .ok_or_else(|| ScheduleError::Exhausted { name: name.to_string() })?;
            self.clock.advance(1);
            self.trace.push((self.clock.now, tname));
            step(&mut self.clock);
            Ok(())
        }

        /// Replay a whitespace-separated script of thread names, e.g.
        /// `"owner owner thief owner"`. Each token runs one step.
        pub fn run_script(&mut self, script: &str) -> Result<(), ScheduleError> {
            for name in script.split_whitespace() {
                self.run_step(name)?;
            }
            Ok(())
        }

        /// Run every remaining step, round-robin across threads in spawn
        /// order — the canonical "and then everything else finishes"
        /// tail after the interesting prefix has been scripted.
        pub fn run_remaining(&mut self) {
            loop {
                let names: Vec<&'static str> = self
                    .threads
                    .iter()
                    .filter(|(_, q)| !q.is_empty())
                    .map(|(n, _)| *n)
                    .collect();
                if names.is_empty() {
                    return;
                }
                for n in names {
                    // Step queues only shrink here, so this cannot fail.
                    let _ = self.run_step(n);
                }
            }
        }

        /// Steps left on the named thread (0 for unknown names).
        pub fn remaining(&self, name: &str) -> usize {
            self.threads
                .iter()
                .find(|(n, _)| *n == name)
                .map_or(0, |(_, q)| q.len())
        }

        /// True once every thread's queue is empty.
        pub fn is_drained(&self) -> bool {
            self.threads.iter().all(|(_, q)| q.is_empty())
        }

        /// Current virtual time.
        pub fn now(&self) -> u64 {
            self.clock.now()
        }

        /// The `(virtual time, thread)` execution trace so far.
        pub fn trace(&self) -> &[(u64, &'static str)] {
            &self.trace
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default("sum-commutes", |rng| {
            let v = gen::vec_i64(rng, 0, 20, -100, 100);
            let mut r = v.clone();
            r.reverse();
            let a: i64 = v.iter().sum();
            let b: i64 = r.iter().sum();
            if a == b {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check(
            "always-fails",
            PropConfig { cases: 3, seed: 1 },
            |_rng| Err("nope".to_string()),
        );
    }

    #[test]
    fn generators_respect_bounds() {
        check_default("gen-bounds", |rng| {
            let n = gen::usize_in(rng, 3, 9);
            if !(3..=9).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let x = gen::f64_in(rng, -1.0, 1.0);
            if !(-1.0..1.0).contains(&x) {
                return Err(format!("f64_in out of range: {x}"));
            }
            let v = gen::vec_f64(rng, 1, 5, 0.0, 10.0);
            if v.is_empty() || v.len() > 5 || v.iter().any(|x| !(0.0..10.0).contains(x)) {
                return Err(format!("vec_f64 bad: {v:?}"));
            }
            Ok(())
        });
    }

    mod det_harness {
        use crate::testing::det::{step, Interleaver, ScheduleError};
        use std::cell::RefCell;

        #[test]
        fn script_runs_steps_in_scripted_order() {
            let log = RefCell::new(Vec::new());
            let mut il = Interleaver::new();
            il.spawn(
                "a",
                vec![
                    step(|_| log.borrow_mut().push("a0")),
                    step(|_| log.borrow_mut().push("a1")),
                ],
            );
            il.spawn("b", vec![step(|_| log.borrow_mut().push("b0"))]);
            il.run_script("a b a").unwrap();
            assert_eq!(*log.borrow(), vec!["a0", "b0", "a1"]);
            assert!(il.is_drained());
            assert_eq!(il.now(), 3, "one tick per step");
            let trace: Vec<&str> = il.trace().iter().map(|(_, n)| *n).collect();
            assert_eq!(trace, vec!["a", "b", "a"]);
        }

        #[test]
        fn virtual_time_is_step_controlled() {
            let mut il = Interleaver::new();
            il.spawn("t", vec![step(|clk| clk.advance(41))]);
            il.run_script("t").unwrap();
            assert_eq!(il.now(), 42); // 1 scheduling tick + 41 explicit
        }

        #[test]
        fn bad_scripts_report_typed_errors() {
            let mut il = Interleaver::new();
            il.spawn("only", vec![step(|_| {})]);
            assert_eq!(
                il.run_script("ghost"),
                Err(ScheduleError::UnknownThread { name: "ghost".to_string() })
            );
            il.run_script("only").unwrap();
            assert_eq!(
                il.run_script("only"),
                Err(ScheduleError::Exhausted { name: "only".to_string() })
            );
        }

        #[test]
        fn run_remaining_drains_every_thread() {
            let log = RefCell::new(Vec::new());
            let log = &log;
            let mut il = Interleaver::new();
            il.spawn(
                "x",
                (0..3).map(|i| step(move |_| log.borrow_mut().push(("x", i)))).collect::<Vec<_>>(),
            );
            il.spawn("y", vec![step(|_| log.borrow_mut().push(("y", 0)))]);
            il.run_script("y").unwrap();
            il.run_remaining();
            assert!(il.is_drained());
            assert_eq!(log.borrow().len(), 4);
            assert_eq!(il.remaining("x"), 0);
        }

        #[test]
        fn respawning_a_name_appends_steps() {
            let log = RefCell::new(Vec::new());
            let mut il = Interleaver::new();
            il.spawn("t", vec![step(|_| log.borrow_mut().push(1))]);
            il.spawn("t", vec![step(|_| log.borrow_mut().push(2))]);
            il.run_script("t t").unwrap();
            assert_eq!(*log.borrow(), vec![1, 2]);
        }
    }
}
