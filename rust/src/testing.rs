//! A miniature property-based testing harness.
//!
//! The offline build has no `proptest`/`quickcheck`, so the crate carries
//! its own: generate many random cases from a seeded [`Rng`]
//! (deterministic → reproducible failures), run the property, and on
//! failure report the case number and seed so the exact case can be
//! replayed.
//!
//! Paper mapping: verification substrate only (no table/figure); backs
//! the property suites in `rust/tests/properties.rs`.

use crate::failure::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `property` for each of `cfg.cases` seeded RNGs; panic with a
/// replayable diagnostic on the first failure.
///
/// The property returns `Result<(), String>`: `Err` describes the
/// violated invariant.
pub fn check<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::seeded(case_seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{} (seed {case_seed:#x}): {msg}",
                cfg.cases
            );
        }
    }
}

/// Convenience: run with default config.
pub fn check_default<F>(name: &str, property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, PropConfig::default(), property);
}

/// Generators for common test inputs.
pub mod gen {
    use crate::failure::Rng;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        lo + rng.next_f64() * (hi - lo)
    }

    /// Vector of f64 with the given length range and value range.
    pub fn vec_f64(rng: &mut Rng, len_lo: usize, len_hi: usize, lo: f64, hi: f64) -> Vec<f64> {
        let len = usize_in(rng, len_lo, len_hi);
        (0..len).map(|_| f64_in(rng, lo, hi)).collect()
    }

    /// Vector of i64 in a value range.
    pub fn vec_i64(rng: &mut Rng, len_lo: usize, len_hi: usize, lo: i64, hi: i64) -> Vec<i64> {
        let len = usize_in(rng, len_lo, len_hi);
        (0..len)
            .map(|_| lo + rng.next_below((hi - lo + 1) as u64) as i64)
            .collect()
    }

    /// Bernoulli draw.
    pub fn bool_with(rng: &mut Rng, p: f64) -> bool {
        rng.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default("sum-commutes", |rng| {
            let v = gen::vec_i64(rng, 0, 20, -100, 100);
            let mut r = v.clone();
            r.reverse();
            let a: i64 = v.iter().sum();
            let b: i64 = r.iter().sum();
            if a == b {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check(
            "always-fails",
            PropConfig { cases: 3, seed: 1 },
            |_rng| Err("nope".to_string()),
        );
    }

    #[test]
    fn generators_respect_bounds() {
        check_default("gen-bounds", |rng| {
            let n = gen::usize_in(rng, 3, 9);
            if !(3..=9).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let x = gen::f64_in(rng, -1.0, 1.0);
            if !(-1.0..1.0).contains(&x) {
                return Err(format!("f64_in out of range: {x}"));
            }
            let v = gen::vec_f64(rng, 1, 5, 0.0, 10.0);
            if v.is_empty() || v.len() > 5 || v.iter().any(|x| !(0.0..10.0).contains(x)) {
                return Err(format!("vec_f64 bad: {v:?}"));
            }
            Ok(())
        });
    }
}
