//! Failure injection (§V-C) and failure accounting.
//!
//! "Errors injected within the applications are artificial … We use an
//! exponential distribution function to generate an exponential curve
//! signature such that the probability of errors is equal to e^{-x},
//! where x is the error rate factor."

pub mod rng;

pub use rng::Rng;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::TaskError;

/// Shared counters kept by an injector (the paper's "atomic counter to
/// count the total number of failed tasks").
#[derive(Debug, Default)]
pub struct FailureCounters {
    pub injected: AtomicU64,
    pub evaluated: AtomicU64,
}

impl FailureCounters {
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
    pub fn evaluated(&self) -> u64 {
        self.evaluated.load(Ordering::Relaxed)
    }
    /// Observed failure fraction.
    pub fn rate(&self) -> f64 {
        let e = self.evaluated();
        if e == 0 {
            0.0
        } else {
            self.injected() as f64 / e as f64
        }
    }
}

/// Probabilistic fault injector with the paper's exponential model.
///
/// `error_rate` is the paper's *x*: each draw samples `Exp(x)` and
/// injects a failure when the sample exceeds 1.0, giving
/// P(failure) = e^{-x}. `error_rate <= 0` disables injection entirely
/// (P = 0), mirroring the benchmarks' no-failure baseline.
#[derive(Clone)]
pub struct FaultInjector {
    error_rate: f64,
    seed: u64,
    counters: Arc<FailureCounters>,
}

thread_local! {
    /// Per-thread RNG stream so concurrent tasks don't contend on a lock;
    /// streams are derived from (seed, thread id counter).
    static TL_RNG: RefCell<Option<(u64, Rng)>> = const { RefCell::new(None) };
}

static THREAD_COUNTER: AtomicU64 = AtomicU64::new(1);

impl FaultInjector {
    /// Injector with P(failure per draw) = e^{-error_rate}.
    pub fn new(error_rate: f64, seed: u64) -> Self {
        FaultInjector { error_rate, seed, counters: Arc::new(FailureCounters::default()) }
    }

    /// Injector from a target failure *probability* p: rate = -ln(p).
    pub fn with_probability(p: f64, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&p), "probability must be in [0,1)");
        if p <= 0.0 {
            Self::new(0.0, seed) // disabled
        } else {
            Self::new(-p.ln(), seed)
        }
    }

    /// The probability a single draw injects a failure.
    pub fn probability(&self) -> f64 {
        if self.error_rate <= 0.0 {
            0.0
        } else {
            (-self.error_rate).exp()
        }
    }

    pub fn counters(&self) -> &Arc<FailureCounters> {
        &self.counters
    }

    /// Decide whether this draw fails (paper Listing 3's criterion:
    /// `Exp(rate) > 1.0`).
    pub fn should_fail(&self) -> bool {
        self.counters.evaluated.fetch_add(1, Ordering::Relaxed);
        if self.error_rate <= 0.0 {
            return false;
        }
        let fail = TL_RNG.with(|cell| {
            let mut slot = cell.borrow_mut();
            let entry = slot.get_or_insert_with(|| {
                let tid = THREAD_COUNTER.fetch_add(1, Ordering::Relaxed);
                (self.seed, Rng::seeded(self.seed ^ tid.wrapping_mul(0xa076_1d64_78bd_642f)))
            });
            if entry.0 != self.seed {
                let tid = THREAD_COUNTER.fetch_add(1, Ordering::Relaxed);
                let mixed = self.seed ^ tid.wrapping_mul(0xa076_1d64_78bd_642f);
                *entry = (self.seed, Rng::seeded(mixed));
            }
            entry.1.exponential(self.error_rate) > 1.0
        });
        if fail {
            self.counters.injected.fetch_add(1, Ordering::Relaxed);
        }
        fail
    }

    /// Draw and return an injected error, or `Ok(())`.
    pub fn draw(&self, site: &'static str) -> Result<(), TaskError> {
        if self.should_fail() {
            Err(TaskError::Injected { site })
        } else {
            Ok(())
        }
    }
}

/// Injects *silent* errors: corrupts one element of a task's output
/// without updating the checksum, so only checksum validation (or
/// replica voting) can catch it.
#[derive(Clone)]
pub struct SilentCorruptor {
    injector: Option<FaultInjector>,
    count: Arc<AtomicU64>,
    seed: u64,
}

impl SilentCorruptor {
    pub fn new(probability: Option<f64>, seed: u64) -> Self {
        SilentCorruptor {
            injector: probability
                .filter(|p| *p > 0.0)
                .map(|p| FaultInjector::with_probability(p, seed)),
            count: Arc::new(AtomicU64::new(0)),
            seed,
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// With the configured probability, perturb one element.
    pub fn maybe_corrupt(&self, data: &mut [f64]) {
        let Some(inj) = &self.injector else { return };
        if data.is_empty() || !inj.should_fail() {
            return;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        let idx = Rng::seeded(self.seed ^ n).next_below(data.len() as u64) as usize;
        data[idx] += 1.0; // large, checksum-visible corruption
    }
}

/// Silent-data-corruption injector of the bit-flip kind: with the
/// configured probability, XOR the top mantissa bit of one element of a
/// completed task's output. Unlike [`SilentCorruptor`]'s additive
/// perturbation, the flipped value keeps its sign and order of
/// magnitude — it looks entirely plausible to the happy path (no NaN,
/// no infinity, no range excursion) and is only caught by a validator
/// recomputing the checksum, or by replica voting. This is the §III-B
/// "completes successfully with wrong bits" failure at its most honest.
///
/// A flip on a value whose magnitude makes the perturbation smaller
/// than `min_delta` (e.g. an exact 0.0, whose mantissa flip lands in
/// the subnormals) falls back to an additive `+1.0` so an injected
/// corruption is never accidentally within a validator's tolerance
/// (`min_delta` sits three orders of magnitude above the drivers'
/// default checksum tolerance of 1e-6).
#[derive(Clone)]
pub struct SdcInjector {
    injector: Option<FaultInjector>,
    count: Arc<AtomicU64>,
    seed: u64,
    min_delta: f64,
}

/// The flipped bit: the mantissa MSB, perturbing a value by 12.5–25 %
/// of its own magnitude — far above any checksum tolerance, far below
/// anything a range check would notice.
const SDC_FLIP_BIT: u64 = 1 << 51;

impl SdcInjector {
    /// Injector corrupting each task's output with probability `p`
    /// (`None` or `0.0` disables it, mirroring [`SilentCorruptor`]).
    pub fn new(probability: Option<f64>, seed: u64) -> Self {
        SdcInjector {
            injector: probability
                .filter(|p| *p > 0.0)
                .map(|p| FaultInjector::with_probability(p, seed)),
            count: Arc::new(AtomicU64::new(0)),
            seed,
            min_delta: 1e-3,
        }
    }

    /// Corruptions injected so far (shared across clones).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// With the configured probability, bit-flip one element; returns
    /// `true` when a corruption landed.
    pub fn maybe_corrupt(&self, data: &mut [f64]) -> bool {
        let Some(inj) = &self.injector else { return false };
        if data.is_empty() || !inj.should_fail() {
            return false;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        let idx = Rng::seeded(self.seed ^ n).next_below(data.len() as u64) as usize;
        let orig = data[idx];
        let flipped = f64::from_bits(orig.to_bits() ^ SDC_FLIP_BIT);
        data[idx] = if flipped.is_finite() && (flipped - orig).abs() >= self.min_delta {
            flipped
        } else {
            // Tiny/zero/non-finite values: keep the corruption
            // checksum-visible rather than vanishing into the noise.
            orig + 1.0
        };
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_never_fails() {
        let inj = FaultInjector::new(0.0, 1);
        for _ in 0..10_000 {
            assert!(!inj.should_fail());
        }
        assert_eq!(inj.counters().injected(), 0);
        assert_eq!(inj.counters().evaluated(), 10_000);
        assert_eq!(inj.probability(), 0.0);
    }

    #[test]
    fn rate_one_fails_at_e_minus_one() {
        let inj = FaultInjector::new(1.0, 42);
        let n = 100_000;
        let fails = (0..n).filter(|_| inj.should_fail()).count();
        let p = fails as f64 / n as f64;
        assert!((p - 0.3679).abs() < 0.02, "p = {p}");
        assert_eq!(inj.counters().injected(), fails as u64);
    }

    #[test]
    fn with_probability_hits_target() {
        let inj = FaultInjector::with_probability(0.05, 7);
        assert!((inj.probability() - 0.05).abs() < 1e-12);
        let n = 200_000;
        let fails = (0..n).filter(|_| inj.should_fail()).count();
        let p = fails as f64 / n as f64;
        assert!((p - 0.05).abs() < 0.005, "p = {p}");
    }

    #[test]
    fn draw_returns_injected_error() {
        let inj = FaultInjector::with_probability(0.999_999, 3);
        // overwhelmingly likely to fail within a few draws
        let failed = (0..100).any(|_| inj.draw("here").is_err());
        assert!(failed);
    }

    #[test]
    fn counters_shared_across_clones() {
        let inj = FaultInjector::new(1.0, 5);
        let inj2 = inj.clone();
        for _ in 0..100 {
            let _ = inj2.should_fail();
        }
        assert_eq!(inj.counters().evaluated(), 100);
    }

    #[test]
    fn silent_corruptor_perturbs_one_element() {
        let c = SilentCorruptor::new(Some(0.999_999), 11);
        let orig = vec![1.0, 2.0, 3.0, 4.0];
        let mut corrupted = false;
        for _ in 0..50 {
            let mut data = orig.clone();
            c.maybe_corrupt(&mut data);
            let changed = data.iter().zip(&orig).filter(|(a, b)| a != b).count();
            assert!(changed <= 1, "at most one element per corruption");
            corrupted |= changed == 1;
        }
        assert!(corrupted, "corruptor should have fired within 50 draws");
        assert!(c.count() > 0);
        // Disabled injectors never touch the data.
        let off = SilentCorruptor::new(None, 11);
        let mut data = orig.clone();
        off.maybe_corrupt(&mut data);
        assert_eq!(data, orig);
        assert_eq!(off.count(), 0);
    }

    #[test]
    fn sdc_injector_flips_stay_finite_and_checksum_visible() {
        let sdc = SdcInjector::new(Some(0.999_999), 23);
        let orig = vec![0.75, -0.5, 0.0, 1e-12, 0.3];
        let mut landed = 0u64;
        for _ in 0..50 {
            let mut data = orig.clone();
            if !sdc.maybe_corrupt(&mut data) {
                continue;
            }
            landed += 1;
            assert!(data.iter().all(|v| v.is_finite()), "flip must pass the happy path");
            let delta: f64 =
                data.iter().zip(&orig).map(|(a, b)| (a - b).abs()).sum();
            assert!(delta >= 1e-3, "corruption below validator tolerance: {delta}");
            // Exactly one element changed.
            assert_eq!(data.iter().zip(&orig).filter(|(a, b)| a != b).count(), 1);
        }
        assert!(landed > 0, "injector should have fired within 50 draws");
        assert_eq!(sdc.count(), landed);
        // Disabled: a no-op that reports no corruption.
        let off = SdcInjector::new(Some(0.0), 23);
        let mut data = orig.clone();
        assert!(!off.maybe_corrupt(&mut data));
        assert_eq!(data, orig);
    }
}
