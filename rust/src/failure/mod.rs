//! Failure injection (§V-C) and failure accounting.
//!
//! "Errors injected within the applications are artificial … We use an
//! exponential distribution function to generate an exponential curve
//! signature such that the probability of errors is equal to e^{-x},
//! where x is the error rate factor."

pub mod rng;

pub use rng::Rng;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::TaskError;

/// Shared counters kept by an injector (the paper's "atomic counter to
/// count the total number of failed tasks").
#[derive(Debug, Default)]
pub struct FailureCounters {
    pub injected: AtomicU64,
    pub evaluated: AtomicU64,
}

impl FailureCounters {
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
    pub fn evaluated(&self) -> u64 {
        self.evaluated.load(Ordering::Relaxed)
    }
    /// Observed failure fraction.
    pub fn rate(&self) -> f64 {
        let e = self.evaluated();
        if e == 0 {
            0.0
        } else {
            self.injected() as f64 / e as f64
        }
    }
}

/// Probabilistic fault injector with the paper's exponential model.
///
/// `error_rate` is the paper's *x*: each draw samples `Exp(x)` and
/// injects a failure when the sample exceeds 1.0, giving
/// P(failure) = e^{-x}. `error_rate <= 0` disables injection entirely
/// (P = 0), mirroring the benchmarks' no-failure baseline.
#[derive(Clone)]
pub struct FaultInjector {
    error_rate: f64,
    seed: u64,
    counters: Arc<FailureCounters>,
}

thread_local! {
    /// Per-thread RNG stream so concurrent tasks don't contend on a lock;
    /// streams are derived from (seed, thread id counter).
    static TL_RNG: RefCell<Option<(u64, Rng)>> = const { RefCell::new(None) };
}

static THREAD_COUNTER: AtomicU64 = AtomicU64::new(1);

impl FaultInjector {
    /// Injector with P(failure per draw) = e^{-error_rate}.
    pub fn new(error_rate: f64, seed: u64) -> Self {
        FaultInjector { error_rate, seed, counters: Arc::new(FailureCounters::default()) }
    }

    /// Injector from a target failure *probability* p: rate = -ln(p).
    pub fn with_probability(p: f64, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&p), "probability must be in [0,1)");
        if p <= 0.0 {
            Self::new(0.0, seed) // disabled
        } else {
            Self::new(-p.ln(), seed)
        }
    }

    /// The probability a single draw injects a failure.
    pub fn probability(&self) -> f64 {
        if self.error_rate <= 0.0 {
            0.0
        } else {
            (-self.error_rate).exp()
        }
    }

    pub fn counters(&self) -> &Arc<FailureCounters> {
        &self.counters
    }

    /// Decide whether this draw fails (paper Listing 3's criterion:
    /// `Exp(rate) > 1.0`).
    pub fn should_fail(&self) -> bool {
        self.counters.evaluated.fetch_add(1, Ordering::Relaxed);
        if self.error_rate <= 0.0 {
            return false;
        }
        let fail = TL_RNG.with(|cell| {
            let mut slot = cell.borrow_mut();
            let entry = slot.get_or_insert_with(|| {
                let tid = THREAD_COUNTER.fetch_add(1, Ordering::Relaxed);
                (self.seed, Rng::seeded(self.seed ^ tid.wrapping_mul(0xa076_1d64_78bd_642f)))
            });
            if entry.0 != self.seed {
                let tid = THREAD_COUNTER.fetch_add(1, Ordering::Relaxed);
                let mixed = self.seed ^ tid.wrapping_mul(0xa076_1d64_78bd_642f);
                *entry = (self.seed, Rng::seeded(mixed));
            }
            entry.1.exponential(self.error_rate) > 1.0
        });
        if fail {
            self.counters.injected.fetch_add(1, Ordering::Relaxed);
        }
        fail
    }

    /// Draw and return an injected error, or `Ok(())`.
    pub fn draw(&self, site: &'static str) -> Result<(), TaskError> {
        if self.should_fail() {
            Err(TaskError::Injected { site })
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_never_fails() {
        let inj = FaultInjector::new(0.0, 1);
        for _ in 0..10_000 {
            assert!(!inj.should_fail());
        }
        assert_eq!(inj.counters().injected(), 0);
        assert_eq!(inj.counters().evaluated(), 10_000);
        assert_eq!(inj.probability(), 0.0);
    }

    #[test]
    fn rate_one_fails_at_e_minus_one() {
        let inj = FaultInjector::new(1.0, 42);
        let n = 100_000;
        let fails = (0..n).filter(|_| inj.should_fail()).count();
        let p = fails as f64 / n as f64;
        assert!((p - 0.3679).abs() < 0.02, "p = {p}");
        assert_eq!(inj.counters().injected(), fails as u64);
    }

    #[test]
    fn with_probability_hits_target() {
        let inj = FaultInjector::with_probability(0.05, 7);
        assert!((inj.probability() - 0.05).abs() < 1e-12);
        let n = 200_000;
        let fails = (0..n).filter(|_| inj.should_fail()).count();
        let p = fails as f64 / n as f64;
        assert!((p - 0.05).abs() < 0.005, "p = {p}");
    }

    #[test]
    fn draw_returns_injected_error() {
        let inj = FaultInjector::with_probability(0.999_999, 3);
        // overwhelmingly likely to fail within a few draws
        let failed = (0..100).any(|_| inj.draw("here").is_err());
        assert!(failed);
    }

    #[test]
    fn counters_shared_across_clones() {
        let inj = FaultInjector::new(1.0, 5);
        let inj2 = inj.clone();
        for _ in 0..100 {
            let _ = inj2.should_fail();
        }
        assert_eq!(inj.counters().evaluated(), 100);
    }
}
