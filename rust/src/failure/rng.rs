//! Deterministic PRNG + distributions (no `rand` crate offline).
//!
//! xoshiro256++ (Blackman & Vigna): fast, high-quality, trivially
//! seedable — the failure injector needs reproducible error sequences so
//! benchmark runs are comparable across API variants.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (as recommended by the xoshiro authors) so
    /// low-entropy seeds still produce well-mixed state.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Seed from the system clock (distinct per call).
    pub fn from_time() -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed);
        Self::seeded(nanos ^ (std::process::id() as u64) << 32)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Sample from Exp(rate) by inversion: -ln(U)/rate.
    ///
    /// This is `std::exponential_distribution<>(rate)` from the paper's
    /// Listing 3: the benchmark draws `num ~ Exp(error_rate)` and flags
    /// an error when `num > 1.0`, so P(error) = e^{-rate} (§V-C).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let mut u = self.next_f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -(1.0 - u).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seeded(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            assert!(r.next_below(10) < 10);
        }
    }

    #[test]
    fn exponential_matches_paper_error_model() {
        // §V-C: P(sample > 1.0) = e^{-rate}. Check empirically at rate 1:
        // e^{-1} ≈ 0.3679.
        let mut r = Rng::seeded(11);
        let n = 200_000;
        let over = (0..n).filter(|_| r.exponential(1.0) > 1.0).count();
        let p = over as f64 / n as f64;
        assert!((p - (-1.0f64).exp()).abs() < 0.01, "p = {p}");
        // rate 3 -> e^{-3} ≈ 0.0498
        let over = (0..n).filter(|_| r.exponential(3.0) > 1.0).count();
        let p = over as f64 / n as f64;
        assert!((p - (-3.0f64).exp()).abs() < 0.01, "p = {p}");
    }

    #[test]
    fn exponential_mean_is_inverse_rate() {
        let mut r = Rng::seeded(13);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }
}
